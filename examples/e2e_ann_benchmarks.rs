//! End-to-end driver (EXPERIMENTS.md §E2E): proves all layers compose on a
//! real small workload.
//!
//! Pipeline:
//!   1. generate the six Table-2-matched datasets (scaled) and verify their
//!      measured LID against the paper's column;
//!   2. compute ground truth **through the AOT Pallas scan artifact via
//!      PJRT** and cross-check it against the Rust scalar path (L1 ⇄ L3
//!      consistency);
//!   3. build CRINN + GLASS + the strongest baseline per dataset, sweep ef,
//!      and report QPS at recall 0.9 / window-AUC (the headline metric);
//!   4. serve one dataset through the batching coordinator (sharded) and
//!      report serving QPS + p99.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_ann_benchmarks
//! # scale up: CRINN_E2E_N=30000 cargo run --release --example e2e_ann_benchmarks
//! ```

use crinn::coordinator::{Server, ServerConfig, ShardedRouter};
use crinn::dataset::synth;
use crinn::eval::harness;
use crinn::runtime::Engine;
use crinn::variants::VariantConfig;
use std::sync::Arc;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> crinn::Result<()> {
    let engine = Engine::from_default_artifacts()?;
    let n = env_usize("CRINN_E2E_N", 6_000);
    let nq = env_usize("CRINN_E2E_QUERIES", 80);
    let ef_grid = [16usize, 24, 32, 48, 64, 96, 128, 192];
    let mut all_sweeps = Vec::new();

    println!("# E2E — CRINN full-stack driver ({n} base vectors/dataset)\n");

    for name in synth::paper_dataset_names() {
        let sp = synth::spec(name).unwrap();
        let mut ds = synth::generate_counts(sp, n, nq, 42);

        // (1) Table-2 stats check.
        let stats = ds.stats(20, 200, 7);
        println!(
            "## {name}: D={} LID(measured)={:.1} LID(paper)={:.1}",
            stats.dim, stats.lid, sp.paper_lid
        );

        // (2) Ground truth through PJRT (L1 Pallas kernel), cross-checked.
        if engine.manifest.has_dim(ds.dim) {
            let t = std::time::Instant::now();
            let gt = engine.brute_force_topk(ds.metric, &ds.queries, &ds.base, ds.dim, 10)?;
            let pjrt_s = t.elapsed().as_secs_f64();
            let rust_gt = crinn::dataset::gt::brute_force_topk(&ds.base, &ds.queries, ds.dim, ds.metric, 10);
            let mut agree = 0usize;
            for (a, b) in gt.iter().zip(&rust_gt) {
                if a == b {
                    agree += 1;
                }
            }
            println!(
                "  ground truth: PJRT/Pallas {pjrt_s:.2}s, {agree}/{} queries identical to Rust path",
                gt.len()
            );
            assert!(
                agree as f64 >= 0.98 * gt.len() as f64,
                "PJRT and Rust ground truth disagree"
            );
            ds.gt = gt;
            ds.gt_k = 10;
        } else {
            ds.compute_ground_truth(10);
        }

        // (3) Index comparison: CRINN vs GLASS vs ParlayANN.
        for (label, builder) in harness::algorithms()
            .into_iter()
            .filter(|(l, _)| matches!(*l, "crinn" | "glass" | "parlayann"))
        {
            let sweep = harness::run_algorithm(&ds, label, builder, &ef_grid);
            let q90 = crinn::eval::qps_at_recall(&sweep.points, 0.90);
            let auc = crinn::crinn::reward::window_auc(&sweep.points, 0.85, 0.95);
            println!(
                "  {label:<12} QPS@0.90 {}  window-AUC {auc:.0}",
                q90.map(|q| format!("{q:.0}")).unwrap_or_else(|| "—".into())
            );
            all_sweeps.push(sweep);
        }
        println!();
    }

    // (4) Serving path on the SIFT-like dataset.
    println!("## serving (sift-128-like through the batching coordinator)");
    let ds = Arc::new(synth::generate_with_gt("sift-128-euclidean", n, nq, 10, 44));
    // The router is itself an AnnIndex — batched shard fan-out, merge on
    // shard-carried exact distances — so it serves directly.
    let router = ShardedRouter::build_glass(&ds, &VariantConfig::crinn_full(), 2, 7);
    let server = Server::start(Arc::new(router), ServerConfig::default());
    let h = server.handle();
    let t = std::time::Instant::now();
    let total = 1_000;
    let mut recall = 0.0;
    let mut served = 0usize;
    for r in 0..total {
        let qi = r % ds.n_queries();
        if let Some(resp) = h.query(ds.query_vec(qi).to_vec(), 10, 64) {
            recall += crinn::dataset::gt::recall_at_k(&resp.ids, &ds.gt[qi], 10);
            served += 1;
        }
    }
    let elapsed = t.elapsed().as_secs_f64();
    let snap = server.shutdown();
    println!(
        "  served {served}/{total} in {elapsed:.2}s → {:.0} QPS, recall@10 {:.4}, p99 {}",
        served as f64 / elapsed,
        recall / served.max(1) as f64,
        crinn::util::bench::fmt_duration(snap.latency.p99)
    );

    // Persist the sweep data for EXPERIMENTS.md.
    let csv = crinn::eval::report::sweeps_to_csv(&all_sweeps);
    let path = harness::reports_dir().join("e2e_sweeps.csv");
    crinn::eval::report::save(&path, &csv)?;
    println!("\nwrote {}", path.display());
    println!("E2E OK");
    Ok(())
}
