//! RAG-style retrieval pipeline — the workload the paper's introduction
//! motivates: an embedded document corpus served through the CRINN index,
//! with the exact rerank stage running on the AOT Pallas artifact via
//! PJRT (the batch path a production retriever would use).
//!
//! The "corpus" is synthetic: documents are topic-clustered embedding
//! vectors (angular metric, like real sentence embeddings); queries are
//! perturbed documents, so each query's "relevant document" is known and
//! we can report retrieval hit-rate alongside latency.
//!
//! ```bash
//! make artifacts && cargo run --release --example rag_pipeline
//! ```

use crinn::anns::glass::GlassIndex;
use crinn::anns::VectorSet;
use crinn::dataset::synth;
use crinn::distance::Metric;
use crinn::runtime::Engine;
use crinn::util::rng::Rng;
use crinn::variants::VariantConfig;

fn main() -> crinn::Result<()> {
    let engine = Engine::from_default_artifacts()?;

    // --- Corpus: 20k "documents" as 100-dim angular embeddings.
    let sp = synth::spec("glove-100-angular").unwrap();
    let corpus = synth::generate_counts(sp, 20_000, 0, 1);
    let dim = corpus.dim;
    println!("corpus: {} docs, dim {dim} (angular)", corpus.n_base());

    // --- Queries: noisy copies of random documents (known answers).
    let mut rng = Rng::new(9);
    let n_queries = 64; // one PJRT rerank batch
    let mut queries = Vec::with_capacity(n_queries * dim);
    let mut truth = Vec::with_capacity(n_queries);
    for _ in 0..n_queries {
        let doc = rng.next_below(corpus.n_base());
        truth.push(doc as u32);
        let mut v: Vec<f32> = corpus.base_vec(doc).to_vec();
        for x in v.iter_mut() {
            *x += 0.05 * rng.next_gaussian_f32();
        }
        crinn::distance::normalize(&mut v);
        queries.extend_from_slice(&v);
    }

    // --- Index the corpus.
    let (build_s, index) = crinn::util::bench::time_once(|| {
        GlassIndex::build(
            VectorSet::new(corpus.base.clone(), dim, Metric::Angular),
            VariantConfig::crinn_full(),
            7,
        )
    });
    println!("index built in {build_s:.2}s");

    // --- Stage 1: quantized candidate generation (Rust hot path).
    let k = 10;
    let ef = 96;
    let t = std::time::Instant::now();
    let cand_per_q = engine.manifest.rerank_cands.min(64);
    let mut cand_ids: Vec<Vec<u32>> = Vec::with_capacity(n_queries);
    for qi in 0..n_queries {
        let q = &queries[qi * dim..(qi + 1) * dim];
        let mut c = index.candidates_for_rerank(q, k, ef.max(cand_per_q));
        c.truncate(cand_per_q);
        cand_ids.push(c);
    }
    let stage1 = t.elapsed();

    // --- Stage 2: exact rerank through the Pallas artifact (PJRT batch).
    let t = std::time::Instant::now();
    let c_max = cand_ids.iter().map(Vec::len).max().unwrap_or(1);
    let mut gathered = vec![0f32; n_queries * c_max * dim];
    for (qi, ids) in cand_ids.iter().enumerate() {
        for (ci, &id) in ids.iter().enumerate() {
            gathered[(qi * c_max + ci) * dim..(qi * c_max + ci + 1) * dim]
                .copy_from_slice(corpus.base_vec(id as usize));
        }
    }
    let dists = engine.rerank(Metric::Angular, &queries, n_queries, &gathered, c_max, dim)?;
    let stage2 = t.elapsed();

    // --- Merge + report.
    let mut hits = 0;
    for qi in 0..n_queries {
        let mut scored: Vec<(f32, u32)> = cand_ids[qi]
            .iter()
            .enumerate()
            .map(|(ci, &id)| (dists[qi][ci], id))
            .collect();
        scored.sort_by(crinn::anns::heap::dist_cmp);
        let top: Vec<u32> = scored.iter().take(k).map(|x| x.1).collect();
        if top.contains(&truth[qi]) {
            hits += 1;
        }
    }
    println!("\nretrieval hit-rate@{k}: {hits}/{n_queries}");
    println!(
        "stage 1 (graph search, rust): {:.2} ms total ({:.0} µs/query)",
        stage1.as_secs_f64() * 1e3,
        stage1.as_secs_f64() * 1e6 / n_queries as f64
    );
    println!(
        "stage 2 (exact rerank, PJRT/Pallas batch): {:.2} ms total",
        stage2.as_secs_f64() * 1e3
    );
    assert!(hits as f64 >= 0.9 * n_queries as f64, "retrieval degraded");
    Ok(())
}
