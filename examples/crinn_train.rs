//! The paper's experiment in miniature: contrastive-RL optimization of the
//! GLASS modules on a SIFT-like training dataset (§3, §3.5), with the GRPO
//! policy running through the AOT PJRT artifacts.
//!
//! Trains on sift-128-euclidean (as the paper does), then evaluates the
//! learned configuration on a *different* dataset (glove-25-like) to probe
//! the §4.1 generalization claim.
//!
//! ```bash
//! make artifacts && cargo run --release --example crinn_train
//! # faster smoke run:
//! CRINN_TRAIN_N=3000 CRINN_TRAIN_ITERS=2 cargo run --release --example crinn_train
//! ```

use crinn::crinn::{CrinnTrainer, TrainerOptions};
use crinn::dataset::synth;
use crinn::runtime::Engine;
use crinn::variants::VariantConfig;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() -> crinn::Result<()> {
    let engine = Engine::from_default_artifacts()?;
    let n = env_usize("CRINN_TRAIN_N", 6_000);
    let iters = env_usize("CRINN_TRAIN_ITERS", 4);

    // Train on the SIFT-like dataset (the paper trains only on SIFT-128).
    let train = synth::generate_with_gt("sift-128-euclidean", n, 100, 10, 42);
    let opts = TrainerOptions {
        iters_per_module: iters,
        dump_prompts: Some("reports/prompts".into()),
        ..Default::default()
    };
    let mut trainer = CrinnTrainer::new(&engine, train, opts);
    let res = trainer.train()?;

    println!("\n== training summary (sift-128-like) ==");
    println!("baseline AUC: {:.1}", res.baseline_auc);
    for (m, s) in &res.module_best {
        println!("  {:<20} best score {:.3} ({:+.1}%)", m.name(), s, (s - 1.0) * 100.0);
    }

    // Generalization probe: evaluate learned vs baseline on angular data.
    println!("\n== generalization: glove-25-like (angular) ==");
    let eval = synth::generate_with_gt("glove-25-angular", n, 100, 10, 43);
    let spec = crinn::crinn::RewardSpec::default();
    for (label, cfg) in [
        ("glass baseline", VariantConfig::glass_baseline()),
        ("crinn learned", res.best_config.clone()),
    ] {
        let (auc, _) = crinn::crinn::reward::evaluate_config(
            &eval,
            &cfg,
            crinn::variants::Module::Construction,
            None,
            &spec,
        );
        println!("  {label:<16} window-AUC {auc:.1}");
    }
    println!("\nlearned config:\n{:#?}", res.best_config);
    Ok(())
}
