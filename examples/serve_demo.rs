//! Serving demo: the L3 coordinator end to end — sharded GLASS indexes
//! behind the dynamic batcher, concurrent clients, backpressure, and a
//! latency/throughput report (the vLLM-router-shaped deployment story).
//!
//! ```bash
//! cargo run --release --example serve_demo
//! ```

use crinn::anns::AnnIndex;
use crinn::coordinator::{Server, ServerConfig, ShardedRouter};
use crinn::dataset::synth;
use crinn::variants::VariantConfig;
use std::sync::Arc;

fn main() -> crinn::Result<()> {
    let ds = Arc::new(synth::generate_with_gt("sift-128-euclidean", 15_000, 200, 10, 42));
    println!("dataset: {} base vectors", ds.n_base());

    // The router is itself an AnnIndex: dynamic batches fan out to every
    // shard in one `search_batch` call each, and the merge sorts on the
    // shard-carried exact distances — no wrapper/rescoring needed.
    let router = ShardedRouter::build_glass(&ds, &VariantConfig::crinn_full(), 2, 7);
    println!("router: {} shards", router.n_shards());
    let index: Arc<dyn AnnIndex> = Arc::new(router);

    let server = Server::start(index, ServerConfig::default());
    let n_clients = 4;
    let requests_per_client = 500;
    let t = std::time::Instant::now();
    let mut clients = Vec::new();
    for c in 0..n_clients {
        let h = server.handle();
        let ds = ds.clone();
        clients.push(std::thread::spawn(move || {
            let mut recall = 0.0;
            let mut served = 0;
            for r in 0..requests_per_client {
                let qi = (c * 131 + r) % ds.n_queries();
                if let Some(resp) = h.query(ds.query_vec(qi).to_vec(), 10, 64) {
                    recall += crinn::dataset::gt::recall_at_k(&resp.ids, &ds.gt[qi], 10);
                    served += 1;
                }
            }
            (recall, served)
        }));
    }
    let mut recall = 0.0;
    let mut served = 0usize;
    for c in clients {
        let (r, s) = c.join().unwrap();
        recall += r;
        served += s;
    }
    let elapsed = t.elapsed().as_secs_f64();
    let snap = server.shutdown();

    println!("\n== serving report ==");
    println!("served: {served}/{} requests in {elapsed:.2}s", n_clients * requests_per_client);
    println!("throughput: {:.0} QPS", served as f64 / elapsed);
    println!("recall@10: {:.4}", recall / served.max(1) as f64);
    println!(
        "latency p50 {}  p95 {}  p99 {}",
        crinn::util::bench::fmt_duration(snap.latency.p50),
        crinn::util::bench::fmt_duration(snap.latency.p95),
        crinn::util::bench::fmt_duration(snap.latency.p99),
    );
    println!(
        "batches: {} (mean size {:.1}), batched queries: {}, rejected: {}",
        snap.batches,
        snap.mean_batch_size(),
        snap.batched_queries,
        snap.rejected
    );
    Ok(())
}
