//! Quickstart: build a CRINN-optimized GLASS index on a synthetic dataset,
//! search it, and compare against exact ground truth.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use crinn::anns::glass::GlassIndex;
use crinn::anns::{AnnIndex, VectorSet};
use crinn::dataset::synth;
use crinn::variants::VariantConfig;

fn main() -> crinn::Result<()> {
    // 1. A small workload: 10k vectors, 64-dim, Euclidean.
    let ds = synth::generate_with_gt("demo-64", 10_000, 100, 10, 42);
    println!("dataset: {} ({} base, dim {})", ds.name, ds.n_base(), ds.dim);

    // 2. Build the index with the paper's discovered configuration.
    let (build_s, index) = crinn::util::bench::time_once(|| {
        GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 7)
            .with_label("crinn")
    });
    println!(
        "built {} in {build_s:.2}s ({:.1} MiB)",
        index.name(),
        index.memory_bytes() as f64 / 1048576.0
    );

    // 3. Search and measure recall@10 at a few ef settings.
    for ef in [16, 48, 128] {
        let point = crinn::eval::sweep::measure_point(&index, &ds, 10, ef);
        println!(
            "ef={ef:<4} recall@10={:.4}  QPS={:<8.0} mean={}",
            point.recall,
            point.qps,
            crinn::util::bench::fmt_duration(point.mean_latency_s)
        );
    }

    // 4. One concrete query, next to its exact answer.
    let q = ds.query_vec(0);
    let approx = index.search(q, 5, 64);
    println!("\nquery 0 — approx top-5: {approx:?}");
    println!("query 0 — exact  top-5: {:?}", &ds.gt[0][..5]);
    Ok(())
}
