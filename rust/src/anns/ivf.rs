//! IVF baseline (Vearch-like: inverted-file partitions + quantized scan).
//!
//! Build: k-means (k-means++ seeding, a few Lloyd iterations) partitions
//! the base vectors into `nlist` cells. Search: rank cells by centroid
//! distance, then scan the `nprobe` nearest cells in one of two modes
//! ([`IvfParams::quantized_scan`]):
//!
//! * **SQ8 posting-list scan** (default) — each probed cell's member list
//!   goes through one one-to-many i8 batch kernel call
//!   ([`QuantizedStore::distance_batch`], prefetch pipelined over the code
//!   rows), then the pooled survivors are exactly reranked in f32
//!   (mirroring Vearch's IVFPQ-style pipeline with our scalar quantizer).
//! * **Exact IVFFlat scan** — posting lists scanned in full precision via
//!   the f32 batch kernel, no rerank pass and no code storage: the memory
//!   baseline the quantized mode's 4x traffic saving is measured against.
//! * **IVF-PQ fast-scan** ([`IvfParams::pq_m`] > 0) — each probed cell is
//!   scanned through the 4-bit ADC block kernel
//!   (`distance::simd::kernels_pq`, 32 packed rows per `pshufb` pass over
//!   position-major cell blocks), then the top `k · pq_rerank` survivors
//!   go through the same exact f32 rerank as the SQ8 mode. 8–32× less
//!   code traffic than SQ8; the rerank pass restores exact distances.
//!
//! The `ef` sweep parameter maps to `nprobe` (cells probed), giving IVF the
//! same recall↔QPS dial as the graph methods in Figure 1.

use crate::anns::filter::{Admit, FilterBitset, DEFAULT_FILTERED_FALLBACK};
use crate::anns::heap::dist_cmp;
use crate::anns::hnsw::search::SearchContext;
use crate::anns::scratch::ScratchPool;
use crate::anns::store::pq::{self, PqStore};
use crate::anns::tombstones::Tombstones;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::distance::quant::QuantizedStore;
use crate::distance::simd::{kernels_pq, PQ_BLOCK};
use crate::util::rng::Rng;

/// Build parameters.
#[derive(Clone, Debug)]
pub struct IvfParams {
    /// Number of partitions (0 = sqrt(n) heuristic).
    pub nlist: usize,
    /// Lloyd iterations.
    pub kmeans_iters: usize,
    /// Rerank multiplier over k during the exact pass.
    pub rerank_mult: usize,
    /// SQ8 posting-list scan + exact rerank (default). `false` builds no
    /// codes and scans posting lists in full precision (exact IVFFlat).
    pub quantized_scan: bool,
    /// PQ subquantizer count; > 0 switches the probe scan to 4-bit PQ
    /// fast-scan (superseding `quantized_scan` — no SQ8 codes are built).
    /// Clamped to `[1, min(dim, 256)]` at build time.
    pub pq_m: usize,
    /// Rerank multiplier over k for the PQ mode's exact pass (PQ needs a
    /// deeper pool than SQ8 — 4-bit cells rank coarser than i8 codes).
    pub pq_rerank: usize,
}

impl Default for IvfParams {
    fn default() -> Self {
        IvfParams {
            nlist: 0,
            kmeans_iters: 8,
            rerank_mult: 4,
            quantized_scan: true,
            pq_m: 0,
            pq_rerank: 8,
        }
    }
}

/// Built IVF index.
///
/// Mutable ([`MutableAnnIndex`]): an insert appends to the posting list of
/// its nearest centroid (re-quantizing through the frozen-scale
/// [`QuantizedStore`] when `quantized_scan` is on — centroids are *not*
/// re-fit online; a rebuild re-runs k-means), a delete tombstones the id
/// (the scan still computes its distance but never pools it), and
/// consolidation compacts the posting lists in place. Compaction keeps
/// surviving entries in their original order, so consolidation is
/// **bitwise result-preserving** for every query — the strongest form of
/// the "untouched queries" guarantee.
pub struct IvfIndex {
    pub vectors: VectorSet,
    /// SQ8 codes for the quantized scan mode; `None` = exact IVFFlat.
    quant: Option<QuantizedStore>,
    /// 4-bit PQ codes for the fast-scan mode; supersedes `quant`.
    pq: Option<PqStore>,
    /// Per-cell position-major fast-scan blocks (32 rows per block, see
    /// `store::pq::scatter_row`). DERIVED data: rebuilt from the
    /// row-major `PqStore` on consolidate, never persisted.
    pq_blocks: Vec<Vec<u8>>,
    /// Rerank multiplier for the PQ mode's exact pass.
    pq_rerank: usize,
    centroids: Vec<f32>,
    nlist: usize,
    /// Per-cell posting lists (ids ascending at build time; inserts
    /// append). A `Vec` per cell instead of the old frozen CSR so online
    /// appends and compaction stay O(cell), at the cost of one extra
    /// indirection per probed cell — the batch kernel still sees each
    /// posting list as one contiguous gathered id slice.
    cells: Vec<Vec<u32>>,
    rerank_mult: usize,
    deleted: Tombstones,
    /// Consolidated slots awaiting reuse (still marked in `deleted`).
    free: Vec<u32>,
    /// Shared scratch: cell-ranking, gather and distance buffers that the
    /// old code allocated fresh on every query.
    scratch: ScratchPool,
    /// Selectivity crossover for filtered search (see
    /// [`AnnIndex::filtered_fallback_threshold`]).
    filtered_fallback: usize,
}

impl IvfIndex {
    pub fn build(vectors: VectorSet, params: IvfParams, seed: u64) -> Self {
        let n = vectors.len();
        let dim = vectors.dim;
        let nlist = if params.nlist == 0 {
            ((n as f64).sqrt() as usize).clamp(1, 4096)
        } else {
            params.nlist.clamp(1, n.max(1))
        };
        let mut rng = Rng::new(seed ^ 0x1F1F);

        // --- k-means++ seeding over a sample.
        let sample_n = n.min(20_000);
        let sample = rng.sample_indices(n, sample_n);
        let mut centroids = vec![0f32; nlist * dim];
        if n > 0 {
            let first = sample[rng.next_below(sample_n)];
            centroids[..dim].copy_from_slice(vectors.vec(first as u32));
            let mut d2: Vec<f32> = sample
                .iter()
                .map(|&i| vectors.metric.distance(&centroids[..dim], vectors.vec(i as u32)).max(0.0))
                .collect();
            for c in 1..nlist {
                let total: f64 = d2.iter().map(|&x| x as f64).sum();
                let pick = if total <= 0.0 {
                    rng.next_below(sample_n)
                } else {
                    let mut t = rng.next_f64() * total;
                    let mut idx = 0;
                    for (j, &x) in d2.iter().enumerate() {
                        t -= x as f64;
                        if t <= 0.0 {
                            idx = j;
                            break;
                        }
                    }
                    idx
                };
                let chosen = sample[pick];
                centroids[c * dim..(c + 1) * dim].copy_from_slice(vectors.vec(chosen as u32));
                for (j, &i) in sample.iter().enumerate() {
                    let nd = vectors
                        .metric
                        .distance(&centroids[c * dim..(c + 1) * dim], vectors.vec(i as u32))
                        .max(0.0);
                    if nd < d2[j] {
                        d2[j] = nd;
                    }
                }
            }
        }

        // --- Lloyd iterations (assignments over all points).
        let mut assign = vec![0u32; n];
        for _ in 0..params.kmeans_iters {
            // Assign.
            for i in 0..n {
                assign[i] = nearest_centroid(&vectors, &centroids, nlist, i as u32);
            }
            // Update.
            let mut sums = vec![0f64; nlist * dim];
            let mut counts = vec![0usize; nlist];
            for i in 0..n {
                let c = assign[i] as usize;
                counts[c] += 1;
                for (s, &v) in sums[c * dim..(c + 1) * dim].iter_mut().zip(vectors.vec(i as u32)) {
                    *s += v as f64;
                }
            }
            for c in 0..nlist {
                if counts[c] > 0 {
                    for (ct, s) in centroids[c * dim..(c + 1) * dim].iter_mut().zip(&sums[c * dim..(c + 1) * dim]) {
                        *ct = (*s / counts[c] as f64) as f32;
                    }
                }
            }
        }
        for i in 0..n {
            assign[i] = nearest_centroid(&vectors, &centroids, nlist, i as u32);
        }

        // --- Per-cell posting lists (ids ascending, same order the old
        // CSR layout produced).
        let mut cells: Vec<Vec<u32>> = vec![Vec::new(); nlist];
        for i in 0..n {
            cells[assign[i] as usize].push(i as u32);
        }

        // PQ fast-scan supersedes SQ8: exactly one code store is built.
        let pq = (params.pq_m > 0).then(|| PqStore::build(&vectors.data, dim, params.pq_m, seed));
        let quant = (pq.is_none() && params.quantized_scan)
            .then(|| QuantizedStore::build(&vectors.data, dim));
        let pq_blocks = match &pq {
            Some(store) => cells.iter().map(|cell| cell_blocks(store, cell)).collect(),
            None => Vec::new(),
        };
        let deleted = Tombstones::new(n);
        IvfIndex {
            vectors,
            quant,
            pq,
            pq_blocks,
            pq_rerank: params.pq_rerank.max(1),
            centroids,
            nlist,
            cells,
            rerank_mult: params.rerank_mult.max(1),
            deleted,
            free: Vec::new(),
            scratch: ScratchPool::new(),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    /// Tune the selectivity crossover: filters with at most this many
    /// matching ids take the exact-scan fallback instead of the probe scan.
    pub fn set_filtered_fallback(&mut self, threshold: usize) {
        self.filtered_fallback = threshold;
    }

    /// The PQ code store when running in fast-scan mode (size accounting,
    /// diagnostics).
    pub fn pq_store(&self) -> Option<&PqStore> {
        self.pq.as_ref()
    }

    /// Rank cells by centroid distance to `q` into the caller's buffer
    /// (cleared and refilled; no per-query allocation once warm).
    fn rank_cells(&self, q: &[f32], out: &mut Vec<(f32, u32)>) {
        let dim = self.vectors.dim;
        out.clear();
        out.extend((0..self.nlist).map(|c| {
            (
                self.vectors
                    .metric
                    .distance(q, &self.centroids[c * dim..(c + 1) * dim]),
                c as u32,
            )
        }));
        out.sort_by(dist_cmp);
    }

    pub fn cell_sizes(&self) -> Vec<usize> {
        self.cells.iter().map(|c| c.len()).collect()
    }

    /// Member ids of cell `c` (a contiguous posting list — already the
    /// gathered id-list shape the one-to-many kernels take).
    #[inline]
    fn cell_members(&self, c: u32) -> &[u32] {
        &self.cells[c as usize]
    }

    /// One query with caller-provided scratch — the shared body of the
    /// (filtered and unfiltered) search and batch entry points. `ef` maps
    /// to nprobe (≥1), scaled down since cells ≫ beam widths. Non-matching
    /// members still get a (discarded) distance — the batch kernel runs
    /// whole posting lists — but never enter the pool, exactly the
    /// tombstone treatment; `filter = None` is byte-identical to the
    /// pre-filter path.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let n = self.vectors.len();
        if n == 0 {
            return Vec::new();
        }
        if let Some(f) = filter {
            // Selectivity fallback: scan just the matching ids exactly
            // instead of probing cells that mostly don't contain them.
            if f.count() <= self.filtered_fallback {
                return crate::anns::filtered_exact_fallback(
                    &self.vectors,
                    query,
                    k,
                    &mut ctx.batch,
                    &mut ctx.dists,
                    self.deleted.filter_ref(),
                    f,
                );
            }
        }
        let admit = Admit {
            deleted: self.deleted.filter_ref(),
            filter,
        };
        let nprobe = (ef / 8).clamp(1, self.nlist);
        self.rank_cells(query, &mut ctx.cands);

        if self.quant.is_none() && self.pq.is_none() {
            // Exact IVFFlat: full-precision posting-list scan through the
            // f32 one-to-many kernel; no rerank pass needed. Tombstoned
            // members' cost disappears at the next consolidate.
            let mut pool = crate::anns::heap::TopK::new(k);
            for &(_, c) in ctx.cands.iter().take(nprobe) {
                let members = self.cell_members(c);
                self.vectors.distance_batch(query, members, &mut ctx.dists);
                for (&i, &d) in members.iter().zip(&ctx.dists) {
                    if admit.allows(i) {
                        pool.push(d, i);
                    }
                }
            }
            return pool.into_sorted();
        }

        let metric = self.vectors.metric;
        let pool = if let Some(store) = &self.pq {
            // PQ fast-scan: one LUT build per query, then each probed
            // cell's position-major blocks go through the 32-row pshufb
            // kernel — 32 ADC distances per pass. Zero-padded tail lanes
            // (slots past the posting-list length) are computed and
            // discarded; decode + admission happen per live lane, exactly
            // the tombstone/filter treatment of the other modes.
            let lut = store.lut(metric, query);
            let block_bytes = pq::block_bytes(store.row_bytes());
            let mut sums = [0u32; PQ_BLOCK];
            let mut pool = crate::anns::heap::TopK::new((k * self.pq_rerank).max(k));
            for &(_, c) in ctx.cands.iter().take(nprobe) {
                let members = self.cell_members(c);
                for (b, block) in self.pq_blocks[c as usize].chunks_exact(block_bytes).enumerate() {
                    (kernels_pq().block)(&lut, block, &mut sums);
                    let base = b * PQ_BLOCK;
                    for s in 0..PQ_BLOCK.min(members.len() - base) {
                        let i = members[base + s];
                        if admit.allows(i) {
                            pool.push(lut.decode(sums[s]), i);
                        }
                    }
                }
            }
            pool
        } else {
            // SQ8 scan of probed cells: one i8 batch-kernel call per
            // posting list (each cell's member ids are exactly a gathered
            // id list, so the code-row prefetch pipeline applies
            // unchanged).
            let quant = self.quant.as_ref().unwrap();
            let qc = quant.encode_query(query);
            let mut pool = crate::anns::heap::TopK::new((k * self.rerank_mult).max(k));
            for &(_, c) in ctx.cands.iter().take(nprobe) {
                let members = self.cell_members(c);
                quant.distance_batch(metric, &qc, members, &mut ctx.dists);
                for (&i, &d) in members.iter().zip(&ctx.dists) {
                    if admit.allows(i) {
                        pool.push(d, i);
                    }
                }
            }
            pool
        };
        // Exact rerank of the quantized survivors through the one-to-many
        // SIMD kernel (prefetch pipelined gather over the f32 rows) —
        // shared by the SQ8 and PQ scan modes: approximate codes only
        // ever *rank* candidates, exact f32 decides what is returned.
        ctx.batch.clear();
        ctx.batch
            .extend(pool.into_sorted().into_iter().map(|(_, i)| i));
        self.vectors.distance_batch(query, &ctx.batch, &mut ctx.dists);
        let mut exact: Vec<(f32, u32)> = ctx
            .batch
            .iter()
            .zip(ctx.dists.iter())
            .map(|(&i, &d)| (d, i))
            .collect();
        exact.sort_by(dist_cmp);
        exact.truncate(k);
        exact
    }
}

/// Position-major fast-scan blocks for one posting list (derived from the
/// row-major store; rebuilt whenever the list is compacted).
fn cell_blocks(store: &PqStore, members: &[u32]) -> Vec<u8> {
    let rb = store.row_bytes();
    let mut blocks = Vec::with_capacity(members.len().div_ceil(PQ_BLOCK) * pq::block_bytes(rb));
    for (slot, &i) in members.iter().enumerate() {
        pq::scatter_row(&mut blocks, rb, slot, store.code(i as usize));
    }
    blocks
}

fn nearest_centroid(vs: &VectorSet, centroids: &[f32], nlist: usize, i: u32) -> u32 {
    let dim = vs.dim;
    let v = vs.vec(i);
    let mut best = (f32::INFINITY, 0u32);
    for c in 0..nlist {
        let d = vs.metric.distance(v, &centroids[c * dim..(c + 1) * dim]);
        if d < best.0 {
            best = (d, c as u32);
        }
    }
    best.1
}

impl AnnIndex for IvfIndex {
    fn name(&self) -> String {
        if self.pq.is_some() {
            "ivfpq".to_string()
        } else {
            "vearch-ivf".to_string()
        }
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(0);
        self.search_one(query, k, ef, &mut ctx, None)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One pooled context across the batch: cell ranking, posting-list
        // distance buffers and the rerank gather all reuse its buffers.
        let mut ctx = self.scratch.checkout(0);
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, None))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(0);
        self.search_one(query, k, ef, &mut ctx, filter)
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(0);
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, filter))
            .collect()
    }

    fn filtered_fallback_threshold(&self) -> usize {
        self.filtered_fallback
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4
            + self.quant.as_ref().map_or(0, |q| q.bytes())
            + self.pq.as_ref().map_or(0, |p| p.bytes())
            + self.pq_blocks.iter().map(|b| b.len()).sum::<usize>()
            + self.centroids.len() * 4
            + self.cells.iter().map(|c| c.len() * 4).sum::<usize>()
    }
}

impl MutableAnnIndex for IvfIndex {
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        crate::anns::validate_insert_vec(vec, self.vectors.dim)?;
        let (id, recycled) = crate::anns::recycle_or_append(
            &mut self.vectors,
            &mut self.deleted,
            &mut self.free,
            vec,
        );
        if let Some(q) = &mut self.quant {
            if recycled {
                q.reencode(id as usize, vec);
            } else {
                q.append(vec);
            }
        }
        if let Some(p) = &mut self.pq {
            // Frozen codebooks: encoding an insert never perturbs other
            // rows, same bit-stability contract as the SQ8 scale.
            if recycled {
                p.reencode(id as usize, vec);
            } else {
                p.append(vec);
            }
        }
        let c = nearest_centroid(&self.vectors, &self.centroids, self.nlist, id);
        self.cells[c as usize].push(id);
        if let Some(p) = &self.pq {
            let slot = self.cells[c as usize].len() - 1;
            pq::scatter_row(
                &mut self.pq_blocks[c as usize],
                p.row_bytes(),
                slot,
                p.code(id as usize),
            );
        }
        Ok(id)
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        self.deleted.delete(id)
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        let pending = self.deleted.pending(&self.free);
        if pending.is_empty() {
            return Ok(0);
        }
        let mut pending_mask = vec![false; self.vectors.len()];
        for &t in &pending {
            pending_mask[t as usize] = true;
        }
        // Posting-list compaction: surviving entries keep their relative
        // order, so live results are bitwise unchanged for every query.
        for cell in &mut self.cells {
            cell.retain(|&i| !pending_mask[i as usize]);
        }
        // Fast-scan blocks are derived from (store row, cell order); the
        // rows are untouched and order is preserved, so rebuilding them
        // keeps every ADC sum — and therefore every result — bitwise
        // identical.
        if let Some(store) = &self.pq {
            for (cell, blocks) in self.cells.iter().zip(&mut self.pq_blocks) {
                *blocks = cell_blocks(store, cell);
            }
        }
        self.free.extend(&pending);
        Ok(pending.len())
    }

    fn live_count(&self) -> usize {
        self.vectors.len() - self.deleted.count()
    }

    fn deleted_count(&self) -> usize {
        self.deleted.count() - self.free.len()
    }

    fn is_deleted(&self, id: u32) -> bool {
        self.deleted.contains(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn ivf_partitions_cover_all_points() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 800, 10, 51);
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), IvfParams::default(), 1);
        assert_eq!(idx.cell_sizes().iter().sum::<usize>(), 800);
    }

    #[test]
    fn recall_grows_with_nprobe() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 52);
        ds.compute_ground_truth(10);
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), IvfParams::default(), 1);
        let recall = |ef: usize| {
            let mut acc = 0.0;
            for qi in 0..ds.n_queries() {
                let found = idx.search(ds.query_vec(qi), 10, ef);
                acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
            }
            acc / ds.n_queries() as f64
        };
        let lo = recall(8);
        let hi = recall(256);
        assert!(hi > lo, "lo={lo} hi={hi}");
        assert!(hi > 0.85, "hi={hi}");
    }

    #[test]
    fn probing_all_cells_is_near_exact() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 600, 20, 53);
        ds.compute_ground_truth(5);
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), IvfParams::default(), 1);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 5, 100_000);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 5);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.95, "full-probe recall {recall}");
    }

    #[test]
    fn exact_scan_mode_full_probe_is_exact() {
        // quantized_scan = false is the exact IVFFlat scenario: probing
        // every cell must reproduce brute-force ground truth exactly (no
        // quantization error anywhere in the pipeline).
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 600, 20, 54);
        ds.compute_ground_truth(5);
        let params = IvfParams {
            quantized_scan: false,
            ..IvfParams::default()
        };
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), params, 1);
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 5, 100_000);
            assert_eq!(found, ds.gt[qi][..5], "query {qi}");
        }
    }

    #[test]
    fn quantized_and_exact_modes_agree_at_high_probe() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1000, 30, 55);
        ds.compute_ground_truth(10);
        let recall_of = |quantized_scan: bool| {
            let params = IvfParams {
                quantized_scan,
                ..IvfParams::default()
            };
            let idx = IvfIndex::build(VectorSet::from_dataset(&ds), params, 1);
            let mut acc = 0.0;
            for qi in 0..ds.n_queries() {
                let found = idx.search(ds.query_vec(qi), 10, 256);
                acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
            }
            acc / ds.n_queries() as f64
        };
        let rq = recall_of(true);
        let re = recall_of(false);
        assert!(rq > 0.85 && re > 0.85, "quantized {rq} exact {re}");
        // The SQ8 scan's exact rerank closes nearly all the quantization
        // gap at the same probe budget.
        assert!(rq > re - 0.05, "quantized {rq} vs exact {re}");
    }

    #[test]
    fn mutation_insert_delete_consolidate_ivf() {
        for quantized_scan in [true, false] {
            let sp = synth::spec("demo-64").unwrap();
            let mut ds = synth::generate_counts(sp, 800, 20, 57);
            ds.compute_ground_truth(10);
            let params = IvfParams { quantized_scan, ..IvfParams::default() };
            let mut idx = IvfIndex::build(VectorSet::from_dataset(&ds), params, 1);
            // Insert: point lands in exactly one cell and wins its query.
            let v = ds.query_vec(0).to_vec();
            let id = idx.insert(&v).unwrap();
            assert_eq!(id, 800);
            assert_eq!(idx.cell_sizes().iter().sum::<usize>(), 801);
            assert_eq!(idx.search(&v, 1, 100_000), vec![id], "qs={quantized_scan}");
            // Delete the query's whole top-10: none may surface again.
            let doomed = idx.search(ds.query_vec(1), 10, 100_000);
            for &d in &doomed {
                idx.delete(d).unwrap();
            }
            let after = idx.search(ds.query_vec(1), 10, 100_000);
            assert_eq!(after.len(), 10);
            assert!(after.iter().all(|i| !doomed.contains(i)));
            assert_eq!(idx.deleted_count(), 10);
            // Consolidation is bitwise result-preserving for IVF — for
            // EVERY query, not just untouched ones (compaction keeps
            // surviving order; distances of live points are unchanged).
            let before: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 256))
                .collect();
            assert_eq!(idx.consolidate().unwrap(), 10);
            assert_eq!(idx.consolidate().unwrap(), 0);
            let post: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 256))
                .collect();
            assert_eq!(before, post, "consolidate changed results (qs={quantized_scan})");
            assert_eq!(idx.cell_sizes().iter().sum::<usize>(), 791);
            assert_eq!(idx.live_count(), 791);
            assert_eq!(idx.deleted_count(), 0);
            // Recycled insert reuses a freed slot and is searchable.
            let id2 = idx.insert(&v).unwrap();
            assert!(doomed.contains(&id2), "expected a recycled slot, got {id2}");
            assert!(idx.search(&v, 2, 100_000).contains(&id2));
        }
    }

    #[test]
    fn filtered_ivf_both_scan_modes_honor_filter() {
        // Full-probe filtered search in exact mode must equal the filtered
        // ground truth exactly; quantized mode must at least never surface
        // a non-matching or tombstoned id. filter=None stays bitwise
        // identical to the unfiltered path in both modes.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 800, 20, 58);
        for quantized_scan in [false, true] {
            let params = IvfParams { quantized_scan, ..IvfParams::default() };
            let mut idx = IvfIndex::build(VectorSet::from_dataset(&ds), params, 1);
            let filter = FilterBitset::from_predicate(800, |id| id % 3 == 0);
            assert!(filter.count() > idx.filtered_fallback_threshold());
            for qi in 0..ds.n_queries() {
                let q = ds.query_vec(qi);
                assert_eq!(
                    idx.search_filtered_with_dists(q, 10, 100_000, None),
                    idx.search_with_dists(q, 10, 100_000),
                    "filter=None diverged (qs={quantized_scan})"
                );
                let got = idx.search_filtered_with_dists(q, 10, 100_000, Some(&filter));
                assert_eq!(got.len(), 10);
                assert!(got.iter().all(|&(_, id)| id % 3 == 0));
                if !quantized_scan {
                    let (mut ids, mut dists) = (Vec::new(), Vec::new());
                    let want = crate::dataset::gt::topk_pairs_for_query_filtered(
                        &ds.base,
                        q,
                        ds.dim,
                        ds.metric,
                        10,
                        &mut ids,
                        &mut dists,
                        |i| filter.matches(i),
                    );
                    assert_eq!(got, want, "exact full-probe filtered != oracle");
                }
            }
            // Sparse filter takes the exact fallback and skips tombstones.
            let rare = FilterBitset::from_predicate(800, |id| id % 80 == 0); // 10 ids
            assert!(rare.count() <= idx.filtered_fallback_threshold());
            let q = ds.query_vec(0);
            let before = idx.search_filtered_with_dists(q, 10, 8, Some(&rare));
            assert_eq!(before.len(), 10);
            assert!(before.iter().all(|&(_, id)| id % 80 == 0));
            idx.delete(before[0].1).unwrap();
            let after = idx.search_filtered_with_dists(q, 10, 8, Some(&rare));
            assert!(after.iter().all(|&(_, id)| id != before[0].1));
            // Filtered batch == filtered per-query.
            let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
            let batched = idx.search_filtered_batch(&queries, 10, 256, Some(&filter));
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(
                    batched[qi],
                    idx.search_filtered_with_dists(q, 10, 256, Some(&filter))
                );
            }
        }
    }

    fn pq_params() -> IvfParams {
        IvfParams { pq_m: 16, ..IvfParams::default() }
    }

    #[test]
    fn ivfpq_recall_with_rerank_and_name() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 61);
        ds.compute_ground_truth(10);
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), pq_params(), 1);
        assert_eq!(idx.name(), "ivfpq");
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 10, 256);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "ivfpq recall@10 {recall}");
        // The PQ store (codes + codebooks) is ≤ 1/8 of the f32 payload.
        let pq_bytes = idx.pq_store().unwrap().bytes();
        assert!(pq_bytes * 8 <= 1200 * 64 * 4, "pq bytes {pq_bytes}");
    }

    #[test]
    fn ivfpq_block_scan_matches_per_pair_adc_bitwise() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 500, 5, 62);
        let idx = IvfIndex::build(VectorSet::from_dataset(&ds), pq_params(), 3);
        let store = idx.pq.as_ref().unwrap();
        let q = ds.query_vec(0);
        let lut = store.lut(idx.vectors.metric, q);
        let bb = pq::block_bytes(store.row_bytes());
        let mut sums = [0u32; PQ_BLOCK];
        for (c, members) in idx.cells.iter().enumerate() {
            for (b, block) in idx.pq_blocks[c].chunks_exact(bb).enumerate() {
                (kernels_pq().block)(&lut, block, &mut sums);
                for s in 0..PQ_BLOCK.min(members.len() - b * PQ_BLOCK) {
                    let id = members[b * PQ_BLOCK + s] as usize;
                    assert_eq!(
                        lut.decode(sums[s]),
                        store.distance(&lut, id),
                        "cell {c} slot {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn ivfpq_mutation_insert_delete_consolidate_bitwise() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 20, 63);
        ds.compute_ground_truth(10);
        let mut idx = IvfIndex::build(VectorSet::from_dataset(&ds), pq_params(), 1);
        let v = ds.query_vec(0).to_vec();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id, 800);
        // PQ ranks coarsely, but the inserted exact duplicate must win
        // its own query after the exact rerank.
        assert_eq!(idx.search(&v, 1, 100_000), vec![id]);
        let doomed = idx.search(ds.query_vec(1), 10, 100_000);
        for &d in &doomed {
            idx.delete(d).unwrap();
        }
        let after = idx.search(ds.query_vec(1), 10, 100_000);
        assert!(after.iter().all(|i| !doomed.contains(i)));
        let before: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 256))
            .collect();
        assert_eq!(idx.consolidate().unwrap(), 10);
        let post: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 256))
            .collect();
        assert_eq!(before, post, "consolidate changed ivfpq results");
        // Recycled insert reuses a freed slot, re-encodes in place, and
        // the rebuilt blocks still agree with the row store.
        let id2 = idx.insert(&v).unwrap();
        assert!(doomed.contains(&id2), "expected a recycled slot, got {id2}");
        assert!(idx.search(&v, 2, 100_000).contains(&id2));
    }

    #[test]
    fn exact_mode_skips_code_storage() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 400, 5, 56);
        let q = IvfIndex::build(VectorSet::from_dataset(&ds), IvfParams::default(), 1);
        let e = IvfIndex::build(
            VectorSet::from_dataset(&ds),
            IvfParams { quantized_scan: false, ..IvfParams::default() },
            1,
        );
        assert_eq!(q.memory_bytes() - e.memory_bytes(), 400 * 64);
    }
}
