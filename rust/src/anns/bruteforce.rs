//! Exact brute-force baseline.
//!
//! Serves three roles: the exactness reference in Figure 1, the recall
//! oracle in tests, and (via [`crate::runtime::Engine`]) a consumer of the
//! AOT Pallas scan artifact — the integration tests cross-check the Rust
//! scalar scan against the compiled kernel's results.

use crate::anns::filter::FilterBitset;
use crate::anns::scratch::ScratchPool;
use crate::anns::tombstones::Tombstones;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};

/// Brute-force index: the vectors plus pooled scan buffers.
///
/// The trivially mutable index: insert appends (or recycles) a row,
/// delete tombstones it out of the scan filter, and consolidation just
/// moves tombstones to the free list — there is no structure to repair,
/// so it is bitwise result-preserving for every query. Doubles as the
/// reference semantics for the mutation property tests.
pub struct BruteForceIndex {
    pub vectors: VectorSet,
    scratch: ScratchPool,
    deleted: Tombstones,
    /// Consolidated slots awaiting reuse (still marked in `deleted`).
    free: Vec<u32>,
}

impl BruteForceIndex {
    pub fn build(vectors: VectorSet) -> Self {
        let deleted = Tombstones::new(vectors.len());
        BruteForceIndex {
            vectors,
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
        }
    }

    /// One blocked `distance_batch` scan with caller-provided scratch —
    /// the shared body of `search_with_dists` and `search_batch`. With no
    /// deletions this is the constant-true-predicate scan, which compiles
    /// to the pre-mutability blocked scan exactly.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ctx: &mut crate::anns::hnsw::search::SearchContext,
    ) -> Vec<(f32, u32)> {
        if self.deleted.none() {
            crate::dataset::gt::topk_pairs_for_query(
                &self.vectors.data,
                query,
                self.vectors.dim,
                self.vectors.metric,
                k,
                &mut ctx.batch,
                &mut ctx.dists,
            )
        } else {
            crate::dataset::gt::topk_pairs_for_query_filtered(
                &self.vectors.data,
                query,
                self.vectors.dim,
                self.vectors.metric,
                k,
                &mut ctx.batch,
                &mut ctx.dists,
                |i| !self.deleted.contains(i),
            )
        }
    }

    /// Filtered variant of [`Self::search_one`]: the predicate threads
    /// straight into the blocked oracle scan, so filtered brute force IS
    /// the filtered ground truth. No fallback threshold — this already is
    /// the fallback.
    fn search_one_filtered(
        &self,
        query: &[f32],
        k: usize,
        ctx: &mut crate::anns::hnsw::search::SearchContext,
        filter: &FilterBitset,
    ) -> Vec<(f32, u32)> {
        crate::dataset::gt::topk_pairs_for_query_filtered(
            &self.vectors.data,
            query,
            self.vectors.dim,
            self.vectors.metric,
            k,
            &mut ctx.batch,
            &mut ctx.dists,
            |i| self.deleted.is_live(i) && filter.matches(i),
        )
    }
}

impl AnnIndex for BruteForceIndex {
    fn name(&self) -> String {
        "bruteforce".to_string()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, _ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(0);
        self.search_one(query, k, &mut ctx)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, _ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One scratch checkout: every query's blocked scan reuses the
        // same id/distance block buffers.
        let mut ctx = self.scratch.checkout(0);
        queries
            .iter()
            .map(|q| self.search_one(q, k, &mut ctx))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        _ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(0);
        match filter {
            None => self.search_one(query, k, &mut ctx),
            Some(f) => self.search_one_filtered(query, k, &mut ctx, f),
        }
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        _ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(0);
        queries
            .iter()
            .map(|q| match filter {
                None => self.search_one(q, k, &mut ctx),
                Some(f) => self.search_one_filtered(q, k, &mut ctx, f),
            })
            .collect()
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4
    }
}

impl MutableAnnIndex for BruteForceIndex {
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        crate::anns::validate_insert_vec(vec, self.vectors.dim)?;
        let (id, _) = crate::anns::recycle_or_append(
            &mut self.vectors,
            &mut self.deleted,
            &mut self.free,
            vec,
        );
        Ok(id)
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        self.deleted.delete(id)
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        let pending = self.deleted.pending(&self.free);
        self.free.extend(&pending);
        Ok(pending.len())
    }

    fn live_count(&self) -> usize {
        self.vectors.len() - self.deleted.count()
    }

    fn deleted_count(&self) -> usize {
        self.deleted.count() - self.free.len()
    }

    fn is_deleted(&self, id: u32) -> bool {
        self.deleted.contains(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn exact_by_construction() {
        let vs = VectorSet::new(vec![0.0, 1.0, 2.0, 10.0], 1, Metric::L2);
        let idx = BruteForceIndex::build(vs);
        assert_eq!(idx.search(&[1.4], 2, 0), vec![1, 2]);
        assert_eq!(idx.len(), 4);
    }

    #[test]
    fn filtered_bruteforce_is_the_filtered_oracle() {
        let vs = VectorSet::new(vec![0.0, 1.0, 2.0, 3.0, 10.0], 1, Metric::L2);
        let mut idx = BruteForceIndex::build(vs);
        // filter=None identical to the plain scan.
        assert_eq!(
            idx.search_filtered_with_dists(&[1.4], 3, 0, None),
            idx.search_with_dists(&[1.4], 3, 0)
        );
        // Allow odd ids only.
        let odd = FilterBitset::from_predicate(5, |id| id % 2 == 1);
        assert_eq!(idx.search_filtered(&[1.4], 3, 0, Some(&odd)), vec![1, 3]);
        // A tombstoned matching id drops out.
        idx.delete(1).unwrap();
        assert_eq!(idx.search_filtered(&[1.4], 3, 0, Some(&odd)), vec![3]);
        // Filtered batch == filtered per-query (including the None arm).
        let queries: Vec<&[f32]> = vec![&[1.4], &[9.0]];
        for f in [None, Some(&odd)] {
            let batched = idx.search_filtered_batch(&queries, 2, 0, f);
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(batched[qi], idx.search_filtered_with_dists(q, 2, 0, f));
            }
        }
        // Empty filter: no results, no panic.
        let nothing = FilterBitset::new(5);
        assert!(idx.search_filtered(&[1.4], 3, 0, Some(&nothing)).is_empty());
    }

    #[test]
    fn mutation_is_exact_over_live_set() {
        let vs = VectorSet::new(vec![0.0, 1.0, 2.0, 10.0], 1, Metric::L2);
        let mut idx = BruteForceIndex::build(vs);
        // Delete the current best; the scan must fall through exactly.
        idx.delete(1).unwrap();
        assert_eq!(idx.search(&[1.4], 2, 0), vec![2, 0]);
        assert_eq!(idx.live_count(), 3);
        // Insert appends and is immediately exact.
        let id = idx.insert(&[1.5]).unwrap();
        assert_eq!(id, 4);
        assert_eq!(idx.search(&[1.4], 2, 0), vec![id, 2]);
        // Consolidate frees the slot; results are bitwise unchanged.
        let before = idx.search_with_dists(&[1.4], 3, 0);
        assert_eq!(idx.consolidate().unwrap(), 1);
        assert_eq!(idx.search_with_dists(&[1.4], 3, 0), before);
        // The freed slot is recycled with the old id.
        let id2 = idx.insert(&[0.9]).unwrap();
        assert_eq!(id2, 1);
        assert_eq!(idx.search(&[1.0], 1, 0), vec![1]);
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.live_count(), 5);
    }
}
