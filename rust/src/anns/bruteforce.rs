//! Exact brute-force baseline.
//!
//! Serves three roles: the exactness reference in Figure 1, the recall
//! oracle in tests, and (via [`crate::runtime::Engine`]) a consumer of the
//! AOT Pallas scan artifact — the integration tests cross-check the Rust
//! scalar scan against the compiled kernel's results.

use crate::anns::{AnnIndex, VectorSet};

/// Brute-force index: just the vectors.
pub struct BruteForceIndex {
    pub vectors: VectorSet,
}

impl BruteForceIndex {
    pub fn build(vectors: VectorSet) -> Self {
        BruteForceIndex { vectors }
    }
}

impl AnnIndex for BruteForceIndex {
    fn name(&self) -> String {
        "bruteforce".to_string()
    }

    fn search(&self, query: &[f32], k: usize, _ef: usize) -> Vec<u32> {
        crate::dataset::gt::topk_for_query(
            &self.vectors.data,
            query,
            self.vectors.dim,
            self.vectors.metric,
            k,
        )
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn exact_by_construction() {
        let vs = VectorSet::new(vec![0.0, 1.0, 2.0, 10.0], 1, Metric::L2);
        let idx = BruteForceIndex::build(vs);
        assert_eq!(idx.search(&[1.4], 2, 0), vec![1, 2]);
        assert_eq!(idx.len(), 4);
    }
}
