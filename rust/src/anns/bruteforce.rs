//! Exact brute-force baseline.
//!
//! Serves three roles: the exactness reference in Figure 1, the recall
//! oracle in tests, and (via [`crate::runtime::Engine`]) a consumer of the
//! AOT Pallas scan artifact — the integration tests cross-check the Rust
//! scalar scan against the compiled kernel's results.

use crate::anns::scratch::ScratchPool;
use crate::anns::{AnnIndex, VectorSet};

/// Brute-force index: the vectors plus pooled scan buffers.
pub struct BruteForceIndex {
    pub vectors: VectorSet,
    scratch: ScratchPool,
}

impl BruteForceIndex {
    pub fn build(vectors: VectorSet) -> Self {
        BruteForceIndex {
            vectors,
            scratch: ScratchPool::new(),
        }
    }

    /// One blocked `distance_batch` scan with caller-provided scratch —
    /// the shared body of `search_with_dists` and `search_batch`.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ctx: &mut crate::anns::hnsw::search::SearchContext,
    ) -> Vec<(f32, u32)> {
        crate::dataset::gt::topk_pairs_for_query(
            &self.vectors.data,
            query,
            self.vectors.dim,
            self.vectors.metric,
            k,
            &mut ctx.batch,
            &mut ctx.dists,
        )
    }
}

impl AnnIndex for BruteForceIndex {
    fn name(&self) -> String {
        "bruteforce".to_string()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, _ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(0);
        self.search_one(query, k, &mut ctx)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, _ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One scratch checkout: every query's blocked scan reuses the
        // same id/distance block buffers.
        let mut ctx = self.scratch.checkout(0);
        queries
            .iter()
            .map(|q| self.search_one(q, k, &mut ctx))
            .collect()
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn exact_by_construction() {
        let vs = VectorSet::new(vec![0.0, 1.0, 2.0, 10.0], 1, Metric::L2);
        let idx = BruteForceIndex::build(vs);
        assert_eq!(idx.search(&[1.4], 2, 0), vec![1, 2]);
        assert_eq!(idx.len(), 4);
    }
}
