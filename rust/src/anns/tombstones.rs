//! Tombstone bitset for mutable indexes.
//!
//! Deletion in a graph/IVF index cannot eagerly rewrite the structure on
//! the request path — FreshDiskANN-style systems instead *mark* the point
//! dead and keep it traversable (a tombstoned graph node still routes the
//! beam through its neighborhood) while filtering it out of every result
//! list. [`Tombstones`] is that mark: one bit per physical slot, a
//! popcount kept incrementally, and a cheap [`Tombstones::none`] test so
//! the common no-deletions search path stays branch-predictable.
//!
//! Lifecycle of a slot (see `MutableAnnIndex` in [`crate::anns`]):
//! *live* → `delete` marks the bit (pending tombstone) → `consolidate`
//! repairs the structure around it and hands the id to the index's free
//! list (the bit stays set — the slot is still not live) → a later
//! `insert` reuses the slot and clears the bit. External ids therefore
//! never shift, which is what lets consolidation preserve results for
//! untouched queries.

/// One bit per slot; set = not live (pending tombstone or free slot).
#[derive(Clone, Debug, Default)]
pub struct Tombstones {
    words: Vec<u64>,
    n: usize,
    dead: usize,
}

impl Tombstones {
    pub fn new(n: usize) -> Self {
        Tombstones {
            words: vec![0; n.div_ceil(64)],
            n,
            dead: 0,
        }
    }

    /// Number of slots covered (physical index size, not live count).
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of marked (non-live) slots.
    #[inline]
    pub fn count(&self) -> usize {
        self.dead
    }

    /// True when no slot is marked — the search hot paths test this once
    /// and skip per-candidate filtering entirely.
    #[inline]
    pub fn none(&self) -> bool {
        self.dead == 0
    }

    /// May `id` appear in results? One definition of the `none()`
    /// fast-path + bit test every mutable index's scan shares.
    #[inline]
    pub fn is_live(&self, id: u32) -> bool {
        self.none() || !self.contains(id)
    }

    /// The filter handed to the beam paths: `None` while nothing is
    /// marked, so the common no-deletions search stays byte-for-byte on
    /// the pre-mutability code path.
    #[inline]
    pub fn filter_ref(&self) -> Option<&Tombstones> {
        if self.none() {
            None
        } else {
            Some(self)
        }
    }

    /// Grow to cover `n` slots (new slots unmarked). Never shrinks.
    pub fn resize(&mut self, n: usize) {
        if n > self.n {
            self.n = n;
            self.words.resize(n.div_ceil(64), 0);
        }
    }

    /// Is slot `id` marked? Out-of-range ids read as unmarked.
    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        self.words
            .get(id as usize / 64)
            .is_some_and(|w| (w >> (id % 64)) & 1 == 1)
    }

    /// Mark `id`; returns true if it was live (newly marked).
    pub fn set(&mut self, id: u32) -> bool {
        assert!((id as usize) < self.n, "tombstone id {id} out of range");
        let w = &mut self.words[id as usize / 64];
        let bit = 1u64 << (id % 64);
        if *w & bit != 0 {
            return false;
        }
        *w |= bit;
        self.dead += 1;
        true
    }

    /// Unmark `id` (slot reuse); returns true if it was marked.
    pub fn clear(&mut self, id: u32) -> bool {
        assert!((id as usize) < self.n, "tombstone id {id} out of range");
        let w = &mut self.words[id as usize / 64];
        let bit = 1u64 << (id % 64);
        if *w & bit == 0 {
            return false;
        }
        *w &= !bit;
        self.dead -= 1;
        true
    }

    /// Marked ids, ascending.
    pub fn iter_set(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.dead);
        for (wi, &w) in self.words.iter().enumerate() {
            let mut w = w;
            while w != 0 {
                let b = w.trailing_zeros();
                out.push((wi * 64) as u32 + b);
                w &= w - 1;
            }
        }
        out
    }

    /// The one range-checked tombstone delete every mutable index shares
    /// (the bitset length tracks the index length by construction): `Err`
    /// on out-of-range ids and on ids that are already non-live
    /// (tombstoned or free).
    pub fn delete(&mut self, id: u32) -> crate::Result<()> {
        crate::ensure!(
            (id as usize) < self.n,
            "delete id {id} out of range (len {})",
            self.n
        );
        crate::ensure!(self.set(id), "id {id} is already deleted");
        Ok(())
    }

    /// Marked ids not yet handed to the caller's free list — the set one
    /// `consolidate()` call drops, ascending. (Free-list entries stay
    /// marked after consolidation, so pending = marked ∖ free; every
    /// index's consolidate shares this one definition of the lifecycle.)
    pub fn pending(&self, free: &[u32]) -> Vec<u32> {
        let freed: std::collections::HashSet<u32> = free.iter().copied().collect();
        self.iter_set()
            .into_iter()
            .filter(|t| !freed.contains(t))
            .collect()
    }

    /// Raw words (persistence).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from persisted words, validating shape: the word count must
    /// match `n` and no bit may be set beyond slot `n` — a hostile or
    /// corrupted file fails here instead of resurrecting phantom slots.
    /// The popcount is recomputed, never trusted from the file.
    pub fn from_words(words: Vec<u64>, n: usize) -> Result<Self, String> {
        if words.len() != n.div_ceil(64) {
            return Err(format!(
                "tombstone bitset has {} words, expected {} for {n} points",
                words.len(),
                n.div_ceil(64)
            ));
        }
        if n % 64 != 0 {
            if let Some(&last) = words.last() {
                if last >> (n % 64) != 0 {
                    return Err(format!(
                        "tombstone bitset marks slots beyond point count {n}"
                    ));
                }
            }
        }
        let dead = words.iter().map(|w| w.count_ones() as usize).sum();
        Ok(Tombstones { words, n, dead })
    }
}

/// Shared free-list validation for snapshot readers and log replay: every
/// entry must be an in-range, tombstoned, unique slot. (Free slots stay
/// marked in the bitset until an insert recycles them, so a free-list
/// entry that is live or out of range can only come from a corrupted or
/// hostile file.)
pub(crate) fn validate_free_list(
    free: &[u32],
    deleted: &Tombstones,
    n_points: usize,
) -> Result<(), String> {
    if free.len() > deleted.count() {
        return Err(format!(
            "free list ({}) larger than tombstone count ({})",
            free.len(),
            deleted.count()
        ));
    }
    let mut seen = std::collections::HashSet::with_capacity(free.len());
    for &f in free {
        if (f as usize) >= n_points || !deleted.contains(f) {
            return Err(format!("free slot {f} is not a tombstoned point"));
        }
        if !seen.insert(f) {
            return Err(format!("duplicate free slot {f}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_clear_count() {
        let mut t = Tombstones::new(130);
        assert!(t.none());
        assert!(t.set(0));
        assert!(t.set(63));
        assert!(t.set(64));
        assert!(t.set(129));
        assert!(!t.set(64), "double-mark must report already set");
        assert_eq!(t.count(), 4);
        assert!(!t.none());
        assert!(t.contains(63) && t.contains(129));
        assert!(!t.contains(1));
        assert!(!t.contains(1000), "out of range reads unmarked");
        assert_eq!(t.iter_set(), vec![0, 63, 64, 129]);
        assert!(t.clear(63));
        assert!(!t.clear(63));
        assert_eq!(t.count(), 3);
        assert!(!t.contains(63));
    }

    #[test]
    fn resize_preserves_marks() {
        let mut t = Tombstones::new(10);
        t.set(7);
        t.resize(200);
        assert_eq!(t.len(), 200);
        assert!(t.contains(7));
        assert!(!t.contains(150));
        t.set(150);
        assert_eq!(t.count(), 2);
    }

    #[test]
    fn words_roundtrip() {
        let mut t = Tombstones::new(100);
        for id in [3u32, 64, 99] {
            t.set(id);
        }
        let back = Tombstones::from_words(t.words().to_vec(), 100).unwrap();
        assert_eq!(back.count(), 3);
        assert_eq!(back.iter_set(), t.iter_set());
    }

    #[test]
    fn from_words_rejects_bad_shapes() {
        // Wrong word count.
        assert!(Tombstones::from_words(vec![0; 3], 100).is_err());
        // Bit set beyond n (slot 100 of a 100-slot set).
        let mut words = vec![0u64; 2];
        words[1] = 1 << 36;
        assert!(Tombstones::from_words(words, 100).is_err());
        // Exactly at the boundary is fine.
        let mut words = vec![0u64; 2];
        words[1] = 1 << 35; // slot 99
        let t = Tombstones::from_words(words, 100).unwrap();
        assert!(t.contains(99));
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn delete_range_and_double_delete_errors() {
        let mut t = Tombstones::new(10);
        assert!(t.delete(3).is_ok());
        assert!(t.delete(3).is_err(), "double delete must error");
        assert!(t.delete(10).is_err(), "out of range must error");
        assert_eq!(t.count(), 1);
    }

    #[test]
    fn pending_excludes_free_entries() {
        let mut t = Tombstones::new(50);
        for id in [2u32, 9, 17, 33] {
            t.set(id);
        }
        assert_eq!(t.pending(&[]), vec![2, 9, 17, 33]);
        assert_eq!(t.pending(&[9, 33]), vec![2, 17]);
        assert_eq!(t.pending(&[2, 9, 17, 33]), Vec::<u32>::new());
    }

    #[test]
    fn validate_free_list_rejects_bad_entries() {
        let mut t = Tombstones::new(50);
        for id in [2u32, 9, 17] {
            t.set(id);
        }
        assert!(validate_free_list(&[2, 9], &t, 50).is_ok());
        assert!(validate_free_list(&[], &t, 50).is_ok());
        // Longer than the tombstone count.
        assert!(validate_free_list(&[2, 9, 17, 17], &t, 50).is_err());
        // A live (non-tombstoned) slot, an out-of-range slot, a duplicate.
        assert!(validate_free_list(&[3], &t, 50).is_err());
        assert!(validate_free_list(&[60], &t, 50).is_err());
        assert!(validate_free_list(&[2, 2], &t, 50).is_err());
    }

    #[test]
    fn empty_set() {
        let t = Tombstones::new(0);
        assert!(t.is_empty() && t.none());
        assert!(t.iter_set().is_empty());
        assert!(Tombstones::from_words(vec![], 0).is_ok());
    }
}
