//! Per-id search filters: the scan/beam-time predicate generalized from
//! "not deleted" ([`crate::anns::tombstones::Tombstones`]) to arbitrary
//! allow-lists.
//!
//! A [`FilterBitset`] is the compiled form of a query predicate ("tenant
//! = X ∧ tag ∈ S"): one bit per id, **set = matching/allowed** — the
//! inverse convention of `Tombstones` (set = dead), because a filter is
//! an allow-list while tombstones are a deny-list. Out-of-range ids never
//! match, so a bitset compiled against a snapshot of the metadata store
//! is safe to apply to an index that has since grown: freshly inserted
//! points are simply invisible to the stale filter (deny-safe), never
//! spuriously surfaced.
//!
//! [`Admit`] conjoins the two predicates — liveness and filter — into the
//! single result-admission check the beams and scans apply at
//! `results.push`. Both sides are `Option`s whose `None` compiles to the
//! constant-true arm, so the unfiltered path keeps the exact behavior
//! (and results) it had before filters existed.

use crate::anns::tombstones::Tombstones;

/// Default popcount threshold below which filtered graph search routes to
/// exact brute force over the matching ids (see
/// [`crate::anns::AnnIndex::search_filtered_with_dists`]): with only a
/// few dozen candidates, a blocked exact scan is both faster and exact,
/// while a beam would spend its budget traversing non-matching regions.
/// Exposed as a per-index tunable (`set_filtered_fallback`); measured by
/// `eval::sweep::measure_filtered_point`.
pub const DEFAULT_FILTERED_FALLBACK: usize = 64;

/// An allow-list bitset over ids `0..len`: bit set = id matches the
/// filter. Storage mirrors [`Tombstones`] (LSB-first u64 words, an
/// incrementally maintained popcount) with the inverted semantics.
#[derive(Clone, Debug)]
pub struct FilterBitset {
    words: Vec<u64>,
    n: usize,
    /// Number of set (matching) bits — maintained incrementally so the
    /// selectivity-fallback decision is O(1) per query.
    count: usize,
}

impl FilterBitset {
    /// An empty (match-nothing) filter over `n` ids.
    pub fn new(n: usize) -> FilterBitset {
        FilterBitset {
            words: vec![0u64; n.div_ceil(64)],
            n,
            count: 0,
        }
    }

    /// Compile a predicate into a bitset over `n` ids.
    pub fn from_predicate(n: usize, pred: impl Fn(u32) -> bool) -> FilterBitset {
        let mut f = FilterBitset::new(n);
        for id in 0..n as u32 {
            if pred(id) {
                f.set(id);
            }
        }
        f
    }

    /// Number of ids the bitset spans (NOT the number of matches).
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Number of matching ids — the popcount the selectivity fallback
    /// tests against its threshold.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Does `id` match? Out-of-range ids never match (deny-safe for
    /// points inserted after the filter was compiled).
    #[inline]
    pub fn matches(&self, id: u32) -> bool {
        self.words
            .get(id as usize / 64)
            .is_some_and(|w| (w >> (id % 64)) & 1 == 1)
    }

    /// Mark `id` as matching. Returns false if it already matched.
    pub fn set(&mut self, id: u32) -> bool {
        assert!((id as usize) < self.n, "filter id {id} out of range {}", self.n);
        let (w, b) = (id as usize / 64, id % 64);
        if (self.words[w] >> b) & 1 == 1 {
            return false;
        }
        self.words[w] |= 1 << b;
        self.count += 1;
        true
    }

    /// Unmark `id`. Returns false if it was not matching.
    pub fn clear(&mut self, id: u32) -> bool {
        let (w, b) = (id as usize / 64, id % 64);
        match self.words.get_mut(w) {
            Some(word) if (*word >> b) & 1 == 1 => {
                *word &= !(1 << b);
                self.count -= 1;
                true
            }
            _ => false,
        }
    }

    /// Matching ids, ascending — the candidate list the brute-force
    /// fallback scans.
    pub fn iter_set(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.count);
        for (wi, &word) in self.words.iter().enumerate() {
            let mut w = word;
            while w != 0 {
                out.push((wi * 64 + w.trailing_zeros() as usize) as u32);
                w &= w - 1;
            }
        }
        out
    }

    /// Raw words (LSB-first), for persistence/translation.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words with hostile-input validation: the word
    /// count must match `n`, no phantom bit may mark an id ≥ `n`, and the
    /// popcount is recomputed (never trusted).
    pub fn from_words(words: Vec<u64>, n: usize) -> Result<FilterBitset, String> {
        if words.len() != n.div_ceil(64) {
            return Err(format!(
                "filter bitset has {} words for {n} ids (want {})",
                words.len(),
                n.div_ceil(64)
            ));
        }
        if n % 64 != 0 {
            if let Some(&last) = words.last() {
                if last >> (n % 64) != 0 {
                    return Err(format!("filter bitset marks ids beyond {n}"));
                }
            }
        }
        let count = words.iter().map(|w| w.count_ones() as usize).sum();
        Ok(FilterBitset { words, n, count })
    }
}

/// The conjoined result-admission predicate a beam or scan applies at
/// `results.push`: an id is admitted iff it is live (not tombstoned) AND
/// matches the filter. Frontier admission never consults this — dead and
/// non-matching nodes stay traversable (the PR 5 tombstone discipline),
/// which is what keeps recall usable under selective filters.
#[derive(Clone, Copy, Default)]
pub struct Admit<'a> {
    /// Deny-list: set bit = deleted. `None` = everything live.
    pub deleted: Option<&'a Tombstones>,
    /// Allow-list: set bit = matching. `None` = everything matches.
    pub filter: Option<&'a FilterBitset>,
}

impl<'a> Admit<'a> {
    /// The unfiltered predicate (constant true).
    pub fn none() -> Admit<'static> {
        Admit {
            deleted: None,
            filter: None,
        }
    }

    /// Liveness only — exactly the predicate the pre-filter
    /// `search_filtered(.., Option<&Tombstones>)` signature carried.
    pub fn live_only(deleted: Option<&'a Tombstones>) -> Admit<'a> {
        Admit {
            deleted,
            filter: None,
        }
    }

    #[inline]
    pub fn allows(&self, id: u32) -> bool {
        self.deleted.map_or(true, |t| !t.contains(id))
            && self.filter.map_or(true, |f| f.matches(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filtered_bitset_set_clear_count_matches() {
        let mut f = FilterBitset::new(130);
        assert_eq!(f.count(), 0);
        assert!(!f.matches(0));
        assert!(f.set(0));
        assert!(f.set(64));
        assert!(f.set(129));
        assert!(!f.set(129), "double set must report no-op");
        assert_eq!(f.count(), 3);
        assert!(f.matches(0) && f.matches(64) && f.matches(129));
        assert!(!f.matches(1));
        // Out of range never matches (and clear is a safe no-op).
        assert!(!f.matches(130));
        assert!(!f.matches(u32::MAX));
        assert!(!f.clear(500));
        assert!(f.clear(64));
        assert!(!f.clear(64));
        assert_eq!(f.count(), 2);
        assert_eq!(f.iter_set(), vec![0, 129]);
    }

    #[test]
    fn filtered_bitset_from_predicate_and_words_roundtrip() {
        let f = FilterBitset::from_predicate(200, |id| id % 3 == 0);
        assert_eq!(f.count(), 67);
        assert!(f.matches(0) && f.matches(198) && !f.matches(199));
        let back = FilterBitset::from_words(f.words().to_vec(), 200).unwrap();
        assert_eq!(back.count(), f.count());
        assert_eq!(back.iter_set(), f.iter_set());
        // Hostile inputs: wrong word count, phantom bits beyond n.
        assert!(FilterBitset::from_words(vec![0; 3], 200).is_err());
        let mut words = f.words().to_vec();
        *words.last_mut().unwrap() |= 1 << 63; // id 255 of a 200-id set
        assert!(FilterBitset::from_words(words, 200).is_err());
    }

    #[test]
    fn filtered_admit_conjoins_liveness_and_filter() {
        let mut dead = Tombstones::new(10);
        dead.set(3);
        let mut f = FilterBitset::new(10);
        f.set(3);
        f.set(4);
        let admit = Admit {
            deleted: Some(&dead),
            filter: Some(&f),
        };
        assert!(!admit.allows(3), "dead beats matching");
        assert!(admit.allows(4));
        assert!(!admit.allows(5), "non-matching denied");
        assert!(Admit::none().allows(3));
        assert!(!Admit::live_only(Some(&dead)).allows(3));
        assert!(Admit::live_only(Some(&dead)).allows(5));
    }
}
