//! Per-id metadata for filtered / multi-tenant search: each id carries an
//! optional tenant and a set of tags, and a query-side [`FilterExpr`]
//! (tenant equality, tag membership, conjunction) compiles against the
//! store into a [`FilterBitset`] the index scans/beams consume.
//!
//! Strings are interned once into a shared name table (tenants and tags
//! draw from the same table), so the per-id storage is plain `u32`s —
//! compact, order-stable, and directly serializable by `anns::persist`.
//! Ids the store has never seen (points inserted after the last metadata
//! write) have no tenant and no tags, so they match no tenant/tag
//! predicate: deny-safe, same convention as
//! [`FilterBitset::matches`] on out-of-range ids.

use crate::anns::filter::FilterBitset;
use std::collections::HashMap;

/// Sentinel for "no tenant" in the per-id tenant column.
pub const NO_TENANT: u32 = u32::MAX;

/// A query-side filter over the metadata store. Conjunction-only by
/// design: "tenant = X ∧ tag ∈ {a, b}" covers the multi-tenant RAG
/// shape, and a conjunction's compiled bitset is the intersection of its
/// parts — monotone, so selectivity only ever shrinks.
#[derive(Clone, Debug, PartialEq)]
pub enum FilterExpr {
    /// Id's tenant equals this name.
    Tenant(String),
    /// Id's tag set contains this name.
    HasTag(String),
    /// Every sub-expression holds. `And(vec![])` matches everything the
    /// store knows about (the neutral element of conjunction).
    And(Vec<FilterExpr>),
}

impl FilterExpr {
    pub fn tenant(name: &str) -> FilterExpr {
        FilterExpr::Tenant(name.to_string())
    }

    pub fn tag(name: &str) -> FilterExpr {
        FilterExpr::HasTag(name.to_string())
    }

    pub fn and(parts: Vec<FilterExpr>) -> FilterExpr {
        FilterExpr::And(parts)
    }
}

/// Id → (tenant, tag set) with interned names.
#[derive(Clone, Debug, Default)]
pub struct MetadataStore {
    /// Intern table: names[i] is the string with id `i`.
    names: Vec<String>,
    /// Reverse lookup for interning.
    by_name: HashMap<String, u32>,
    /// Per-id tenant name id ([`NO_TENANT`] = none).
    tenants: Vec<u32>,
    /// Per-id tag name ids (sorted, deduped — membership is a binary
    /// search and the persisted form is canonical).
    tags: Vec<Vec<u32>>,
}

impl MetadataStore {
    pub fn new() -> MetadataStore {
        MetadataStore::default()
    }

    /// Number of ids with metadata rows (ids ≥ this have none).
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    fn intern(&mut self, name: &str) -> u32 {
        if let Some(&id) = self.by_name.get(name) {
            return id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.by_name.insert(name.to_string(), id);
        id
    }

    /// Append the next id's metadata (id == current `len()`).
    pub fn push(&mut self, tenant: Option<&str>, tags: &[&str]) {
        let t = tenant.map_or(NO_TENANT, |t| self.intern(t));
        let mut tg: Vec<u32> = tags.iter().map(|s| self.intern(s)).collect();
        tg.sort_unstable();
        tg.dedup();
        self.tenants.push(t);
        self.tags.push(tg);
    }

    /// Set (or overwrite) metadata for `id`, growing the store with
    /// no-tenant/no-tag rows as needed — the recycled-slot path: an
    /// insert that reuses a consolidated slot replaces the old point's
    /// metadata wholesale.
    pub fn set_for(&mut self, id: u32, tenant: Option<&str>, tags: &[&str]) {
        while self.tenants.len() <= id as usize {
            self.tenants.push(NO_TENANT);
            self.tags.push(Vec::new());
        }
        let t = tenant.map_or(NO_TENANT, |t| self.intern(t));
        let mut tg: Vec<u32> = tags.iter().map(|s| self.intern(s)).collect();
        tg.sort_unstable();
        tg.dedup();
        self.tenants[id as usize] = t;
        self.tags[id as usize] = tg;
    }

    /// Tenant of `id` (None for no tenant or unknown id).
    pub fn tenant(&self, id: u32) -> Option<&str> {
        match self.tenants.get(id as usize) {
            Some(&t) if t != NO_TENANT => Some(&self.names[t as usize]),
            _ => None,
        }
    }

    /// Does `id` carry `tag`? Unknown ids and unknown tags never match.
    pub fn has_tag(&self, id: u32, tag: &str) -> bool {
        match (self.tags.get(id as usize), self.by_name.get(tag)) {
            (Some(tg), Some(t)) => tg.binary_search(t).is_ok(),
            _ => false,
        }
    }

    /// Does `id` satisfy `expr`? A name the store has never interned
    /// matches nothing (an unknown tenant owns no points).
    pub fn matches_expr(&self, id: u32, expr: &FilterExpr) -> bool {
        match expr {
            FilterExpr::Tenant(name) => match self.by_name.get(name) {
                Some(&t) => self.tenants.get(id as usize) == Some(&t),
                None => false,
            },
            FilterExpr::HasTag(name) => self.has_tag(id, name),
            FilterExpr::And(parts) => parts.iter().all(|p| self.matches_expr(id, p)),
        }
    }

    /// Compile `expr` into an allow-list bitset over ids `0..n` (`n` is
    /// the index's point count — ids beyond the store's rows stay
    /// unmatched, ids beyond `n` don't exist).
    pub fn compile(&self, expr: &FilterExpr, n: usize) -> FilterBitset {
        let upto = n.min(self.len());
        let mut f = FilterBitset::new(n);
        for id in 0..upto as u32 {
            if self.matches_expr(id, expr) {
                f.set(id);
            }
        }
        f
    }

    // --- Persistence accessors (see `anns::persist`): the raw columns,
    // and reconstruction with hostile-input validation.

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn tenants(&self) -> &[u32] {
        &self.tenants
    }

    pub fn tags(&self) -> &[Vec<u32>] {
        &self.tags
    }

    /// Rebuild from persisted columns. Every name id must be in range
    /// (`tenant == NO_TENANT` allowed), the two per-id columns must
    /// agree on length, and tag rows are re-canonicalized (sorted,
    /// deduped) so a permuted-but-valid file loads to the same store.
    pub fn from_columns(
        names: Vec<String>,
        tenants: Vec<u32>,
        tags: Vec<Vec<u32>>,
    ) -> Result<MetadataStore, String> {
        if tenants.len() != tags.len() {
            return Err(format!(
                "metadata column mismatch: {} tenants vs {} tag rows",
                tenants.len(),
                tags.len()
            ));
        }
        let n_names = names.len() as u32;
        for (id, &t) in tenants.iter().enumerate() {
            if t != NO_TENANT && t >= n_names {
                return Err(format!("metadata tenant id {t} of row {id} out of range {n_names}"));
            }
        }
        let mut canon = Vec::with_capacity(tags.len());
        for (id, mut row) in tags.into_iter().enumerate() {
            if let Some(&bad) = row.iter().find(|&&t| t >= n_names) {
                return Err(format!("metadata tag id {bad} of row {id} out of range {n_names}"));
            }
            row.sort_unstable();
            row.dedup();
            canon.push(row);
        }
        let mut by_name = HashMap::with_capacity(names.len());
        for (i, name) in names.iter().enumerate() {
            if by_name.insert(name.clone(), i as u32).is_some() {
                return Err(format!("metadata name table repeats {name:?}"));
            }
        }
        Ok(MetadataStore {
            names,
            by_name,
            tenants,
            tags: canon,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_store() -> MetadataStore {
        let mut m = MetadataStore::new();
        for id in 0..100u32 {
            let tenant = format!("t{}", id % 10);
            let mut tags: Vec<&str> = Vec::new();
            if id % 10 != 0 {
                tags.push("hot");
            }
            if id % 50 == 0 {
                tags.push("rare");
            }
            m.push(Some(&tenant), &tags);
        }
        m
    }

    #[test]
    fn filtered_metadata_lookup_and_expr() {
        let m = demo_store();
        assert_eq!(m.len(), 100);
        assert_eq!(m.tenant(23), Some("t3"));
        assert!(m.has_tag(23, "hot"));
        assert!(!m.has_tag(20, "hot"));
        assert!(m.has_tag(50, "rare"));
        assert!(!m.has_tag(200, "hot"), "unknown id matches nothing");
        assert!(!m.has_tag(1, "absent"), "unknown tag matches nothing");
        assert!(m.matches_expr(23, &FilterExpr::tenant("t3")));
        assert!(!m.matches_expr(23, &FilterExpr::tenant("t4")));
        assert!(!m.matches_expr(23, &FilterExpr::tenant("never-seen")));
        let both = FilterExpr::and(vec![FilterExpr::tenant("t0"), FilterExpr::tag("rare")]);
        assert!(m.matches_expr(0, &both) && m.matches_expr(50, &both));
        assert!(!m.matches_expr(10, &both), "t0 but not rare");
        assert!(m.matches_expr(7, &FilterExpr::and(vec![])), "empty AND is true");
    }

    #[test]
    fn filtered_metadata_compile_counts_selectivity() {
        let m = demo_store();
        let tenant = m.compile(&FilterExpr::tenant("t3"), 100);
        assert_eq!(tenant.count(), 10);
        assert!(tenant.matches(3) && tenant.matches(93) && !tenant.matches(4));
        let hot = m.compile(&FilterExpr::tag("hot"), 100);
        assert_eq!(hot.count(), 90);
        let rare = m.compile(&FilterExpr::tag("rare"), 100);
        assert_eq!(rare.count(), 2);
        // Compiling over a larger index: ids beyond the store never match.
        let grown = m.compile(&FilterExpr::tag("hot"), 150);
        assert_eq!(grown.count(), 90);
        assert!(!grown.matches(120));
        // Over a smaller one: capped at n.
        let cut = m.compile(&FilterExpr::tag("hot"), 20);
        assert_eq!(cut.count(), 18);
    }

    #[test]
    fn filtered_metadata_set_for_grows_and_overwrites() {
        let mut m = MetadataStore::new();
        m.set_for(5, Some("a"), &["x"]);
        assert_eq!(m.len(), 6);
        assert_eq!(m.tenant(5), Some("a"));
        assert_eq!(m.tenant(2), None);
        assert!(!m.has_tag(2, "x"));
        // Recycled slot: metadata replaced wholesale.
        m.set_for(5, Some("b"), &[]);
        assert_eq!(m.tenant(5), Some("b"));
        assert!(!m.has_tag(5, "x"));
    }

    #[test]
    fn filtered_metadata_columns_roundtrip_and_hostile_reject() {
        let m = demo_store();
        let back = MetadataStore::from_columns(
            m.names().to_vec(),
            m.tenants().to_vec(),
            m.tags().to_vec(),
        )
        .unwrap();
        for id in 0..100u32 {
            assert_eq!(back.tenant(id), m.tenant(id));
            assert_eq!(back.has_tag(id, "hot"), m.has_tag(id, "hot"));
        }
        // Hostile columns: length mismatch, out-of-range ids, dup names.
        assert!(MetadataStore::from_columns(vec![], vec![0], vec![]).is_err());
        assert!(
            MetadataStore::from_columns(vec!["a".into()], vec![1], vec![vec![]]).is_err(),
            "tenant id beyond name table"
        );
        assert!(
            MetadataStore::from_columns(vec!["a".into()], vec![NO_TENANT], vec![vec![9]])
                .is_err(),
            "tag id beyond name table"
        );
        assert!(
            MetadataStore::from_columns(vec!["a".into(), "a".into()], vec![0], vec![vec![]])
                .is_err(),
            "duplicate interned name"
        );
        // NO_TENANT is always acceptable.
        assert!(MetadataStore::from_columns(vec![], vec![NO_TENANT], vec![vec![]]).is_ok());
    }
}
