//! NN-Descent baseline (Dong et al., and the PyNNDescent profile).
//!
//! Index construction: iterative neighbor-of-neighbor refinement of a
//! random initial k-NN graph until the update rate drops below `delta`.
//! Search: best-first beam over the (diversified) k-NN graph from a few
//! random entry points — the strategy PyNNDescent uses.
//!
//! Two preset profiles mirror the paper's two baselines:
//! * `nndescent`   — plain graph, greedy beam;
//! * `pynndescent` — diversified graph (occlusion pruning) + backtracking
//!   beam, which trades build time for better high-recall behavior.

use crate::anns::filter::{Admit, FilterBitset, DEFAULT_FILTERED_FALLBACK};
use crate::anns::heap::{dist_cmp, TopK};
use crate::anns::hnsw::search::SearchContext;
use crate::anns::scratch::ScratchPool;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::util::rng::Rng;

/// Build parameters.
#[derive(Clone, Debug)]
pub struct NnDescentParams {
    /// Graph degree.
    pub k_graph: usize,
    /// Max refinement iterations.
    pub iters: usize,
    /// Early-stop threshold on the fraction of updated edges.
    pub delta: f64,
    /// Sampled candidates per node per iteration.
    pub sample: usize,
    /// PyNNDescent-style occlusion pruning of the final graph.
    pub diversify: bool,
    /// Number of random entry points per search.
    pub n_entries: usize,
}

impl Default for NnDescentParams {
    fn default() -> Self {
        NnDescentParams {
            k_graph: 24,
            iters: 12,
            delta: 0.001,
            sample: 12,
            diversify: false,
            n_entries: 4,
        }
    }
}

impl NnDescentParams {
    pub fn pynndescent() -> Self {
        NnDescentParams {
            diversify: true,
            k_graph: 30,
            n_entries: 3,
            ..Self::default()
        }
    }
}

/// Built NN-Descent index.
pub struct NnDescentIndex {
    pub vectors: VectorSet,
    /// Flat `[n * k_graph]` adjacency (u32::MAX padding after diversify).
    graph: Vec<u32>,
    k_graph: usize,
    params: NnDescentParams,
    label: String,
    seed: u64,
    scratch: ScratchPool,
    /// Filters with popcount at or below this route to exact fallback.
    filtered_fallback: usize,
}

const NONE: u32 = u32::MAX;

impl NnDescentIndex {
    pub fn build(vectors: VectorSet, params: NnDescentParams, seed: u64) -> Self {
        let n = vectors.len();
        let k = params.k_graph.min(n.saturating_sub(1)).max(1);
        let mut rng = Rng::new(seed ^ 0xD00D);

        // Current kNN lists as (dist, id, is_new) max-heaps by distance.
        let mut lists: Vec<Vec<(f32, u32, bool)>> = (0..n)
            .map(|i| {
                let mut l = Vec::with_capacity(k);
                while l.len() < k.min(n - 1) {
                    let c = rng.next_below(n) as u32;
                    if c as usize != i && !l.iter().any(|&(_, id, _)| id == c) {
                        let d = vectors.distance(vectors.vec(i as u32), c);
                        l.push((d, c, true));
                    }
                }
                l.sort_by(|a, b| dist_cmp(&(a.0, a.1), &(b.0, b.1)));
                l
            })
            .collect();

        let try_insert = |lists: &mut Vec<Vec<(f32, u32, bool)>>,
                          vectors: &VectorSet,
                          i: usize,
                          c: u32|
         -> bool {
            if c as usize == i {
                return false;
            }
            let worst = lists[i].last().map(|x| x.0).unwrap_or(f32::INFINITY);
            let d = vectors.distance(vectors.vec(i as u32), c);
            if lists[i].len() >= k && d >= worst {
                return false;
            }
            if lists[i].iter().any(|&(_, id, _)| id == c) {
                return false;
            }
            let pos = lists[i]
                .binary_search_by(|probe| dist_cmp(&(probe.0, probe.1), &(d, c)))
                .unwrap_or_else(|p| p);
            lists[i].insert(pos, (d, c, true));
            if lists[i].len() > k {
                lists[i].pop();
            }
            true
        };

        // NN-Descent iterations: compare each node's sampled new neighbors
        // against neighbors-of-neighbors (forward + reverse).
        for _iter in 0..params.iters {
            // Reverse adjacency of the sampled new edges.
            let mut updates = 0usize;
            let mut pairs: Vec<(u32, u32)> = Vec::new();
            for i in 0..n {
                let news: Vec<u32> = lists[i]
                    .iter()
                    .filter(|x| x.2)
                    .take(params.sample)
                    .map(|x| x.1)
                    .collect();
                let olds: Vec<u32> = lists[i]
                    .iter()
                    .filter(|x| !x.2)
                    .take(params.sample)
                    .map(|x| x.1)
                    .collect();
                // Mark sampled news as old.
                for e in lists[i].iter_mut() {
                    if e.2 {
                        e.2 = false;
                    }
                }
                for (a, &na) in news.iter().enumerate() {
                    for &nb in news.iter().skip(a + 1) {
                        pairs.push((na, nb));
                    }
                    for &nb in &olds {
                        pairs.push((na, nb));
                    }
                    pairs.push((i as u32, na));
                }
            }
            for &(a, b) in &pairs {
                if a == b {
                    continue;
                }
                if try_insert(&mut lists, &vectors, a as usize, b) {
                    updates += 1;
                }
                if try_insert(&mut lists, &vectors, b as usize, a) {
                    updates += 1;
                }
            }
            if (updates as f64) < params.delta * (n * k) as f64 {
                break;
            }
        }

        // Flatten (+ optional occlusion pruning à la PyNNDescent).
        let mut graph = vec![NONE; n * k];
        for i in 0..n {
            let ids: Vec<u32> = if params.diversify {
                let cands: Vec<(f32, u32)> = lists[i].iter().map(|x| (x.0, x.1)).collect();
                crate::anns::hnsw::select::select_heuristic(&vectors, &cands, k, 1.0, true)
            } else {
                lists[i].iter().map(|x| x.1).collect()
            };
            for (j, id) in ids.into_iter().take(k).enumerate() {
                graph[i * k + j] = id;
            }
        }

        NnDescentIndex {
            vectors,
            graph,
            k_graph: k,
            label: if params.diversify {
                "pynndescent".into()
            } else {
                "nndescent".into()
            },
            params,
            seed,
            scratch: ScratchPool::new(),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    /// Tune the selectivity crossover: filters with `count() <=
    /// threshold` skip the beam and scan the matching ids exactly.
    pub fn set_filtered_fallback(&mut self, threshold: usize) {
        self.filtered_fallback = threshold;
    }

    #[inline]
    fn neighbors(&self, i: u32) -> &[u32] {
        let s = &self.graph[i as usize * self.k_graph..(i as usize + 1) * self.k_graph];
        let mut d = 0;
        while d < s.len() && s[d] != NONE {
            d += 1;
        }
        &s[..d]
    }

    /// Average degree (for reports).
    pub fn avg_degree(&self) -> f64 {
        let n = self.vectors.len();
        if n == 0 {
            return 0.0;
        }
        (0..n as u32).map(|i| self.neighbors(i).len()).sum::<usize>() as f64 / n as f64
    }

    /// One beam search with caller-provided scratch — the shared body of
    /// the (filtered and unfiltered) search and batch entry points. The
    /// admission discipline matches the graph indexes: non-matching nodes
    /// still seed and extend the frontier, they are only withheld from
    /// `results.push`, so `filter = None` compiles to the constant-true
    /// predicate and stays bitwise identical to the pre-filter path.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let n = self.vectors.len();
        if n == 0 {
            return Vec::new();
        }
        if let Some(f) = filter {
            if f.count() <= self.filtered_fallback {
                return crate::anns::filtered_exact_fallback(
                    &self.vectors,
                    query,
                    k,
                    &mut ctx.batch,
                    &mut ctx.dists,
                    None,
                    f,
                );
            }
        }
        let admit = Admit {
            deleted: None,
            filter,
        };
        let ef = ef.max(k);
        ctx.visited.clear();
        ctx.frontier.clear();
        let mut results = TopK::new(ef);

        // Deterministic pseudo-random entries derived from the query bits.
        let mut h = self.seed ^ 0x9E3779B97F4A7C15;
        for &x in query.iter().take(8) {
            h = h.wrapping_mul(0x100000001B3).wrapping_add(x.to_bits() as u64);
        }
        let mut rng = Rng::new(h);
        for _ in 0..self.params.n_entries.max(1) {
            let e = rng.next_below(n) as u32;
            if ctx.visited.insert(e) {
                let d = self.vectors.distance(query, e);
                ctx.frontier.push(d, e);
                if admit.allows(e) {
                    results.push(d, e);
                }
            }
        }

        while let Some((d, u)) = ctx.frontier.pop() {
            if d > results.bound() {
                break;
            }
            for &nb in self.neighbors(u) {
                if !ctx.visited.insert(nb) {
                    continue;
                }
                let dnb = self.vectors.distance(query, nb);
                if dnb < results.bound() {
                    if admit.allows(nb) {
                        results.push(dnb, nb);
                    }
                    ctx.frontier.push(dnb, nb);
                }
            }
        }
        let mut out = results.into_sorted();
        out.truncate(k);
        out
    }
}

impl AnnIndex for NnDescentIndex {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        self.search_one(query, k, ef, &mut ctx, None)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, None))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        self.search_one(query, k, ef, &mut ctx, filter)
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, filter))
            .collect()
    }

    fn filtered_fallback_threshold(&self) -> usize {
        self.filtered_fallback
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4 + self.graph.len() * 4
    }
}

/// NN-Descent's graph is the converged fixed point of the whole-dataset
/// refinement loop — there is no sound single-point update rule, so every
/// mutating method reports `Unsupported` (the coordinator fails the
/// request, not the process).
impl MutableAnnIndex for NnDescentIndex {
    fn insert(&mut self, _vec: &[f32]) -> crate::Result<u32> {
        crate::bail!("Unsupported: nndescent does not implement online insert (rebuild instead)")
    }

    fn delete(&mut self, _id: u32) -> crate::Result<()> {
        crate::bail!("Unsupported: nndescent does not implement delete (rebuild instead)")
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        crate::bail!("Unsupported: nndescent does not implement consolidate")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn dataset() -> crate::dataset::Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1000, 40, 31);
        ds.compute_ground_truth(10);
        ds
    }

    #[test]
    fn nndescent_converges_to_good_graph() {
        let ds = dataset();
        let idx = NnDescentIndex::build(
            VectorSet::from_dataset(&ds),
            NnDescentParams::default(),
            1,
        );
        assert!(idx.avg_degree() > 10.0);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 10, 128);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        // Flat kNN-graph beam search from random entries is the weakest
        // graph baseline (as in the paper's Figure 1) — but it must still
        // be far better than chance on 1000 points.
        assert!(recall > 0.6, "nndescent recall {recall}");
    }

    #[test]
    fn pynndescent_profile_builds() {
        let ds = dataset();
        let idx = NnDescentIndex::build(
            VectorSet::from_dataset(&ds),
            NnDescentParams::pynndescent(),
            1,
        );
        assert_eq!(idx.name(), "pynndescent");
        let found = idx.search(ds.query_vec(0), 10, 64);
        assert_eq!(found.len(), 10);
    }

    #[test]
    fn filtered_nndescent_beam_and_fallback_paths() {
        let ds = dataset();
        let mut idx = NnDescentIndex::build(
            VectorSet::from_dataset(&ds),
            NnDescentParams::default(),
            3,
        );
        let n = idx.len();
        // filter=None is bitwise identical to the unfiltered path.
        for qi in 0..8 {
            let q = ds.query_vec(qi);
            assert_eq!(
                idx.search_filtered_with_dists(q, 10, 96, None),
                idx.search_with_dists(q, 10, 96)
            );
        }
        // Wide filter takes the beam; results all match.
        let third = FilterBitset::from_predicate(n, |id| id % 3 == 0);
        assert!(third.count() > idx.filtered_fallback);
        for qi in 0..8 {
            let found = idx.search_filtered(ds.query_vec(qi), 10, 96, Some(&third));
            assert!(!found.is_empty());
            assert!(found.iter().all(|&id| id % 3 == 0), "leak in {found:?}");
        }
        // Rare filter routes to exact fallback and equals the oracle.
        let rare = FilterBitset::from_predicate(n, |id| id % 100 == 0);
        assert!(rare.count() <= idx.filtered_fallback);
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        for qi in 0..8 {
            let q = ds.query_vec(qi);
            let want = crate::dataset::gt::topk_pairs_for_query_filtered(
                &idx.vectors.data,
                q,
                idx.vectors.dim,
                idx.vectors.metric,
                5,
                &mut ids,
                &mut dists,
                |i| rare.matches(i),
            );
            assert_eq!(idx.search_filtered_with_dists(q, 5, 96, Some(&rare)), want);
        }
        // Forced beam on the rare filter still never leaks.
        idx.set_filtered_fallback(0);
        for qi in 0..8 {
            let found = idx.search_filtered(ds.query_vec(qi), 5, 96, Some(&rare));
            assert!(found.iter().all(|&id| id % 100 == 0));
        }
        idx.set_filtered_fallback(DEFAULT_FILTERED_FALLBACK);
        // Filtered batch == filtered per-query.
        let queries: Vec<&[f32]> = (0..8).map(|qi| ds.query_vec(qi)).collect();
        for f in [None, Some(&third), Some(&rare)] {
            let batched = idx.search_filtered_batch(&queries, 10, 96, f);
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(batched[qi], idx.search_filtered_with_dists(q, 10, 96, f));
            }
        }
    }

    #[test]
    fn search_deterministic() {
        let ds = dataset();
        let idx = NnDescentIndex::build(
            VectorSet::from_dataset(&ds),
            NnDescentParams::default(),
            2,
        );
        let a = idx.search(ds.query_vec(1), 10, 64);
        let b = idx.search(ds.query_vec(1), 10, 64);
        assert_eq!(a, b);
    }
}
