//! Priority structures for beam search.
//!
//! Two complementary pieces:
//! * [`MinQueue`] — the exploration frontier: pop the *closest* unexplored
//!   candidate (binary min-heap on distance).
//! * [`TopK`] — the bounded result pool of the `ef` best candidates seen:
//!   a binary max-heap that evicts its worst element on overflow and
//!   exposes the current worst distance as the pruning bound.
//!
//! Both order `(f32, u32)` by distance then id, so searches are fully
//! deterministic (Table 1's "deterministic and reproducible" requirement).

/// Distance-then-id ordering that treats NaN as +inf (defensive).
#[inline]
pub fn dist_cmp(a: &(f32, u32), b: &(f32, u32)) -> std::cmp::Ordering {
    a.0.partial_cmp(&b.0)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.1.cmp(&b.1))
}

/// Binary min-heap on `(distance, id)`.
#[derive(Clone, Debug, Default)]
pub struct MinQueue {
    items: Vec<(f32, u32)>,
}

impl MinQueue {
    pub fn new() -> Self {
        MinQueue { items: Vec::new() }
    }

    pub fn with_capacity(n: usize) -> Self {
        MinQueue {
            items: Vec::with_capacity(n),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn clear(&mut self) {
        self.items.clear();
    }

    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) {
        self.items.push((dist, id));
        let mut i = self.items.len() - 1;
        while i > 0 {
            let p = (i - 1) / 2;
            if dist_cmp(&self.items[i], &self.items[p]) == std::cmp::Ordering::Less {
                self.items.swap(i, p);
                i = p;
            } else {
                break;
            }
        }
    }

    #[inline]
    pub fn peek(&self) -> Option<(f32, u32)> {
        self.items.first().copied()
    }

    #[inline]
    pub fn pop(&mut self) -> Option<(f32, u32)> {
        if self.items.is_empty() {
            return None;
        }
        let top = self.items.swap_remove(0);
        if !self.items.is_empty() {
            self.sift_down(0);
        }
        Some(top)
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut m = i;
            if l < n && dist_cmp(&self.items[l], &self.items[m]) == std::cmp::Ordering::Less {
                m = l;
            }
            if r < n && dist_cmp(&self.items[r], &self.items[m]) == std::cmp::Ordering::Less {
                m = r;
            }
            if m == i {
                break;
            }
            self.items.swap(i, m);
            i = m;
        }
    }
}

/// Bounded max-heap keeping the `cap` smallest `(distance, id)` pairs.
#[derive(Clone, Debug)]
pub struct TopK {
    cap: usize,
    items: Vec<(f32, u32)>, // max-heap: items[0] is the WORST kept
}

impl TopK {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        TopK {
            cap,
            items: Vec::with_capacity(cap + 1),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.cap
    }

    /// Current worst kept distance, or +inf if not yet full.
    #[inline]
    pub fn bound(&self) -> f32 {
        if self.is_full() {
            self.items[0].0
        } else {
            f32::INFINITY
        }
    }

    /// Offer a candidate; returns true if it entered the pool.
    #[inline]
    pub fn push(&mut self, dist: f32, id: u32) -> bool {
        if self.is_full() {
            if dist_cmp(&(dist, id), &self.items[0]) != std::cmp::Ordering::Less {
                return false;
            }
            self.items[0] = (dist, id);
            self.sift_down_max(0);
            true
        } else {
            self.items.push((dist, id));
            let mut i = self.items.len() - 1;
            while i > 0 {
                let p = (i - 1) / 2;
                if dist_cmp(&self.items[p], &self.items[i]) == std::cmp::Ordering::Less {
                    self.items.swap(i, p);
                    i = p;
                } else {
                    break;
                }
            }
            true
        }
    }

    fn sift_down_max(&mut self, mut i: usize) {
        let n = self.items.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut m = i;
            if l < n && dist_cmp(&self.items[m], &self.items[l]) == std::cmp::Ordering::Less {
                m = l;
            }
            if r < n && dist_cmp(&self.items[m], &self.items[r]) == std::cmp::Ordering::Less {
                m = r;
            }
            if m == i {
                break;
            }
            self.items.swap(i, m);
            i = m;
        }
    }

    /// Drain to a nearest-first sorted vector.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.items.sort_by(dist_cmp);
        self.items
    }

    /// Iterate over current (unsorted) contents.
    pub fn iter(&self) -> impl Iterator<Item = &(f32, u32)> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn minqueue_pops_ascending() {
        let mut q = MinQueue::new();
        let mut rng = Rng::new(1);
        let mut vals: Vec<f32> = (0..200).map(|_| rng.next_f32()).collect();
        for (i, &v) in vals.iter().enumerate() {
            q.push(v, i as u32);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut out = Vec::new();
        while let Some((d, _)) = q.pop() {
            out.push(d);
        }
        assert_eq!(out, vals);
    }

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(5);
        let mut rng = Rng::new(2);
        let mut vals: Vec<f32> = (0..100).map(|_| rng.next_f32()).collect();
        for (i, &v) in vals.iter().enumerate() {
            t.push(v, i as u32);
        }
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let kept: Vec<f32> = t.into_sorted().iter().map(|x| x.0).collect();
        assert_eq!(kept, &vals[..5]);
    }

    #[test]
    fn topk_bound_tracks_worst() {
        let mut t = TopK::new(2);
        assert_eq!(t.bound(), f32::INFINITY);
        t.push(3.0, 0);
        assert_eq!(t.bound(), f32::INFINITY);
        t.push(1.0, 1);
        assert_eq!(t.bound(), 3.0);
        assert!(t.push(2.0, 2)); // evicts 3.0
        assert_eq!(t.bound(), 2.0);
        assert!(!t.push(5.0, 3));
    }

    #[test]
    fn deterministic_tie_break_by_id() {
        let mut t = TopK::new(2);
        t.push(1.0, 7);
        t.push(1.0, 3);
        t.push(1.0, 5); // same dist, id 5 < 7 => evicts 7
        let ids: Vec<u32> = t.into_sorted().iter().map(|x| x.1).collect();
        assert_eq!(ids, vec![3, 5]);
    }

    #[test]
    fn minqueue_clear_and_reuse() {
        let mut q = MinQueue::with_capacity(4);
        q.push(1.0, 1);
        q.clear();
        assert!(q.is_empty());
        q.push(2.0, 2);
        assert_eq!(q.pop(), Some((2.0, 2)));
    }
}
