//! ANNS index implementations.
//!
//! [`hnsw`] is the optimization backbone (§2 of the paper); [`glass`] wraps
//! it with SQ8 quantized search + exact refinement — the RL starting point
//! (§3.5). The rest are the Figure-1 baselines: [`bruteforce`] (exact),
//! [`nndescent`] (NNDescent / PyNNDescent), [`vamana`] (ParlayANN-like),
//! [`ivf`] (Vearch-like). All implement [`AnnIndex`] so the eval harness
//! and serving coordinator treat them uniformly.

pub mod bruteforce;
pub mod glass;
pub mod heap;
pub mod hnsw;
pub mod ivf;
pub mod nndescent;
pub mod persist;
pub mod vamana;
pub mod visited;

/// A built, queryable index.
pub trait AnnIndex: Send + Sync {
    /// Implementation name (appears in reports / Figure 1 legends).
    fn name(&self) -> String;

    /// k-NN search. `ef` is the beam/candidate budget (the recall↔speed
    /// knob swept by the benchmarks; brute force ignores it). Returns ids
    /// nearest-first.
    fn search(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32>;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True if no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (memory reporting in EXPERIMENTS.md).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// Owned view of base vectors shared by index implementations.
#[derive(Clone, Debug)]
pub struct VectorSet {
    pub dim: usize,
    pub metric: crate::distance::Metric,
    pub data: Vec<f32>,
}

impl VectorSet {
    pub fn new(data: Vec<f32>, dim: usize, metric: crate::distance::Metric) -> Self {
        assert!(dim > 0 && data.len() % dim == 0);
        VectorSet { dim, metric, data }
    }

    pub fn from_dataset(ds: &crate::dataset::Dataset) -> Self {
        VectorSet::new(ds.base.clone(), ds.dim, ds.metric)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn vec(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn distance(&self, q: &[f32], i: u32) -> f32 {
        self.metric.distance(q, self.vec(i))
    }

    /// Distances from `q` to a gathered id list through the one-to-many
    /// SIMD kernels (prefetch pipelined; clears and refills `out`). Bitwise
    /// identical to per-pair [`VectorSet::distance`] calls. The SQ8
    /// counterpart for code rows is
    /// [`crate::distance::quant::QuantizedStore::distance_batch`].
    #[inline]
    pub fn distance_batch(&self, q: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.metric.distance_batch(q, ids, &self.data, self.dim, out);
    }

    /// [`VectorSet::distance_batch`] with an explicit prefetch schedule —
    /// how the §6 prefetch knobs reach the batched paths (`lookahead == 0`
    /// disables prefetch; results are identical for every schedule).
    #[inline]
    pub fn distance_batch_with(
        &self,
        q: &[f32],
        ids: &[u32],
        lookahead: usize,
        locality: i32,
        out: &mut Vec<f32>,
    ) {
        crate::distance::distance_batch_with(
            self.metric,
            q,
            ids,
            &self.data,
            self.dim,
            lookahead,
            locality,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn vectorset_accessors() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0], 2, Metric::L2);
        assert_eq!(vs.len(), 2);
        assert_eq!(vs.vec(1), &[3.0, 4.0]);
        assert_eq!(vs.distance(&[0.0, 0.0], 1), 25.0);
    }

    #[test]
    fn vectorset_distance_batch_matches_per_pair() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2, Metric::L2);
        let q = [0.5, 0.5];
        let mut out = Vec::new();
        vs.distance_batch(&q, &[2, 0, 1], &mut out);
        assert_eq!(out, vec![vs.distance(&q, 2), vs.distance(&q, 0), vs.distance(&q, 1)]);
    }
}
