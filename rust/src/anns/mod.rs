//! ANNS index implementations.
//!
//! [`hnsw`] is the optimization backbone (§2 of the paper); [`glass`] wraps
//! it with SQ8 quantized search + exact refinement — the RL starting point
//! (§3.5). The rest are the Figure-1 baselines: [`bruteforce`] (exact),
//! [`nndescent`] (NNDescent / PyNNDescent), [`vamana`] (ParlayANN-like),
//! [`ivf`] (Vearch-like). All implement [`AnnIndex`] so the eval harness
//! and serving coordinator treat them uniformly. HNSW, GLASS, IVF and
//! brute force additionally implement [`MutableAnnIndex`] — online insert,
//! tombstone delete ([`tombstones`]) and consolidation — for serving under
//! live traffic.

pub mod bruteforce;
pub mod filter;
pub mod glass;
pub mod heap;
pub mod hnsw;
pub mod ivf;
pub mod metadata;
pub mod nndescent;
pub mod persist;
pub mod scratch;
pub mod store;
pub mod tombstones;
pub mod vamana;
pub mod visited;

pub use filter::FilterBitset;
pub use metadata::{FilterExpr, MetadataStore};
pub use tombstones::Tombstones;

/// A built, queryable index.
///
/// The trait is **distance-carrying and batch-first**: the one required
/// search method is [`AnnIndex::search_with_dists`], so exact distances
/// survive the trait boundary (the coordinator surfaces them in
/// `QueryResponse`, the sharded router merges on them), and
/// [`AnnIndex::search_batch`] is the serving entry point — all six index
/// types override it to reuse one pooled
/// [`hnsw::search::SearchContext`] across the whole batch. Batch results
/// are bitwise identical to per-query [`AnnIndex::search_with_dists`]
/// calls for every index and metric (asserted by the table-driven
/// cross-index suite in `tests/conformance.rs`), extending the
/// kernel-level batch==per-pair identity up through the whole stack.
pub trait AnnIndex: Send + Sync {
    /// Implementation name (appears in reports / Figure 1 legends).
    fn name(&self) -> String;

    /// k-NN search returning `(distance, id)` pairs nearest-first. `ef` is
    /// the beam/candidate budget (the recall↔speed knob swept by the
    /// benchmarks; brute force ignores it). Distances are **exact
    /// full-precision metric values** (quantized pipelines rerank in f32
    /// before returning) — the contract that lets the sharded router merge
    /// shard results on carried distances without rescoring.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)>;

    /// Ids-only k-NN search — a thin projection of
    /// [`AnnIndex::search_with_dists`].
    fn search(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32> {
        self.search_with_dists(query, k, ef)
            .into_iter()
            .map(|(_, i)| i)
            .collect()
    }

    /// Multi-query batch search: one result list per query, in query
    /// order, each bitwise identical to the corresponding
    /// [`AnnIndex::search_with_dists`] call. The default loops per query;
    /// implementations override it to amortize scratch checkout and keep
    /// caches warm across the batch.
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        queries
            .iter()
            .map(|q| self.search_with_dists(q, k, ef))
            .collect()
    }

    /// [`AnnIndex::search_with_dists`] restricted to ids the filter
    /// allows — the predicate-constrained ("tenant = X ∧ tag ∈ S") query
    /// path. `filter = None` **is** the unfiltered path: every index
    /// delegates it to `search_with_dists`, so results are bitwise
    /// identical to a plain call. With `Some(f)`, no id with
    /// `f.matches(id) == false` (and no tombstoned id) ever surfaces;
    /// graph beams keep admitting non-matching nodes to the frontier and
    /// filter only at result admission (the tombstone discipline), and
    /// indexes route very selective filters (popcount ≤
    /// [`AnnIndex::filtered_fallback_threshold`]) to an exact scan over
    /// the matching ids instead of a beam.
    ///
    /// The default is a **best-effort post-filter** (search, drop
    /// non-matching) for exotic trait impls; all six index types and both
    /// sharded routers override it with true scan/beam-time filtering.
    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&filter::FilterBitset>,
    ) -> Vec<(f32, u32)> {
        match filter {
            None => self.search_with_dists(query, k, ef),
            Some(f) => {
                let mut out = self.search_with_dists(query, k, ef.max(k));
                out.retain(|&(_, id)| f.matches(id));
                out.truncate(k);
                out
            }
        }
    }

    /// Ids-only projection of [`AnnIndex::search_filtered_with_dists`].
    fn search_filtered(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&filter::FilterBitset>,
    ) -> Vec<u32> {
        self.search_filtered_with_dists(query, k, ef, filter)
            .into_iter()
            .map(|(_, i)| i)
            .collect()
    }

    /// Batched [`AnnIndex::search_filtered_with_dists`]: one result list
    /// per query under a shared filter, each bitwise identical to the
    /// per-query call (same contract as [`AnnIndex::search_batch`]).
    /// Indexes override to amortize scratch checkout; the sharded routers
    /// override to translate the global bitset once per shard and fan the
    /// whole batch out.
    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&filter::FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        queries
            .iter()
            .map(|q| self.search_filtered_with_dists(q, k, ef, filter))
            .collect()
    }

    /// The selectivity crossover this index applies in
    /// [`AnnIndex::search_filtered_with_dists`]: filters whose popcount is
    /// at or below this route to exact brute force over the matching ids.
    /// 0 (the default) means "never falls back" — brute force is already
    /// exact, and exotic impls don't fall back. Advisory: the serving
    /// metrics use it to count fallback-routed queries.
    fn filtered_fallback_threshold(&self) -> usize {
        0
    }

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True if no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (memory reporting in EXPERIMENTS.md).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// A queryable index that also absorbs streaming updates — the serving
/// half of the FreshDiskANN-style mutation protocol.
///
/// Semantics shared by every implementation:
///
/// * **Stable external ids.** [`MutableAnnIndex::insert`] returns the id
///   the point will answer under forever; neither `delete` nor
///   `consolidate` ever renumbers a live point. Consolidation recycles
///   dead *slots* into a free list instead of compacting the id space, so
///   a router or client-side cache never has to remap.
/// * **Tombstone deletes.** [`MutableAnnIndex::delete`] only marks a
///   [`Tombstones`] bit. The point stays physically present (graph nodes
///   remain traversable, IVF entries remain scanned) but is filtered from
///   every result list — a tombstoned id never surfaces from
///   [`AnnIndex::search_with_dists`] or [`AnnIndex::search_batch`].
/// * **Consolidation.** [`MutableAnnIndex::consolidate`] physically drops
///   pending tombstones: graphs repair edges by neighbor-of-neighbor
///   reconnection, IVF compacts posting lists, and the freed slots become
///   reusable by later inserts. With zero pending tombstones it is a
///   strict no-op (search results are bitwise unchanged).
///
/// Mutations take `&mut self`; concurrent serving wraps the index in the
/// coordinator's `RwLock` (searches share read locks, mutations take the
/// write lock — see `coordinator::Server::start_mutable`).
///
/// Index types that cannot absorb updates yet (Vamana, NNDescent)
/// implement the trait by returning an `Unsupported`-style error from all
/// three mutating methods, so the coordinator can expose one uniform
/// update path and report the failure per request instead of panicking.
pub trait MutableAnnIndex: AnnIndex {
    /// Insert one vector (dimension must match the index); returns its
    /// assigned id — a recycled free slot when one exists, else a fresh
    /// slot at the end of the id space.
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32>;

    /// Tombstone-delete `id`. Errors if `id` is out of range or not live.
    fn delete(&mut self, id: u32) -> crate::Result<()>;

    /// Physically drop pending tombstones and repair the structure.
    /// Returns how many points were dropped (0 = strict no-op).
    fn consolidate(&mut self) -> crate::Result<usize>;

    /// Number of live (searchable) points: `len()` minus tombstoned and
    /// free slots.
    fn live_count(&self) -> usize {
        self.len()
    }

    /// Pending tombstones — deleted but not yet consolidated.
    fn deleted_count(&self) -> usize {
        0
    }

    /// Is `id` currently non-live (tombstoned or free)? Out-of-range ids
    /// read as false.
    fn is_deleted(&self, _id: u32) -> bool {
        false
    }
}

/// Shared validation for every online-insert entry point: the dimension
/// must match and every component must be finite. A NaN/Inf row would
/// *permanently* corrupt the index (NaN-keyed neighbor sorts hand the
/// node bidirectional edges on live nodes, and it quantizes to a phantom
/// zero code row) — unlike a NaN query, which is transient.
pub(crate) fn validate_insert_vec(vec: &[f32], dim: usize) -> crate::Result<()> {
    crate::ensure!(
        vec.len() == dim,
        "insert dimension {} != index dimension {dim}",
        vec.len()
    );
    crate::ensure!(
        vec.iter().all(|x| x.is_finite()),
        "insert vector contains non-finite components"
    );
    Ok(())
}

/// Shared flat-row slot lifecycle for mutable indexes without graph
/// structure (IVF, brute force): recycle a freed slot (overwrite the row,
/// unmark the bit) or append a fresh one (extend the rows, grow the
/// bitset). Returns `(id, recycled)` — the caller layers its own per-slot
/// upkeep (e.g. SQ8 re-encoding) on the flag, mirroring
/// `hnsw::insert_point`'s `on_slot` hook. Keeping the ordering invariants
/// (write-then-clear, extend-then-resize, free entries staying marked) in
/// one place is what stops the four mutable impls drifting apart.
pub(crate) fn recycle_or_append(
    vectors: &mut VectorSet,
    deleted: &mut Tombstones,
    free: &mut Vec<u32>,
    vec: &[f32],
) -> (u32, bool) {
    debug_assert_eq!(vec.len(), vectors.dim);
    let dim = vectors.dim;
    match free.pop() {
        Some(id) => {
            let i = id as usize;
            vectors.data[i * dim..(i + 1) * dim].copy_from_slice(vec);
            deleted.clear(id);
            (id, true)
        }
        None => {
            let id = vectors.len() as u32;
            vectors.data.extend_from_slice(vec);
            deleted.resize(vectors.len());
            (id, false)
        }
    }
}

/// Shared selectivity fallback for filtered search: an exact scan over
/// the (few) ids the filter allows, used by every graph/IVF index when
/// the filter's popcount is at or below its fallback threshold. Gathers
/// the live matching ids, scores them in one SIMD batch
/// ([`VectorSet::distance_batch`] — bitwise identical to per-pair
/// distances), and sorts by [`heap::dist_cmp`] (distance then id) — the
/// exact ordering of `gt::topk_pairs_for_query_filtered`, so the
/// fallback's results ARE the filtered ground truth for those queries.
pub(crate) fn filtered_exact_fallback(
    vectors: &VectorSet,
    query: &[f32],
    k: usize,
    ids_buf: &mut Vec<u32>,
    dists_buf: &mut Vec<f32>,
    deleted: Option<&Tombstones>,
    filter: &filter::FilterBitset,
) -> Vec<(f32, u32)> {
    ids_buf.clear();
    ids_buf.extend(filter.iter_set().into_iter().filter(|&id| {
        (id as usize) < vectors.len() && deleted.map_or(true, |t| !t.contains(id))
    }));
    vectors.distance_batch(query, ids_buf, dists_buf);
    let mut out: Vec<(f32, u32)> = dists_buf
        .iter()
        .copied()
        .zip(ids_buf.iter().copied())
        .collect();
    out.sort_by(heap::dist_cmp);
    out.truncate(k);
    out
}

/// Owned view of base vectors shared by index implementations.
#[derive(Clone, Debug)]
pub struct VectorSet {
    pub dim: usize,
    pub metric: crate::distance::Metric,
    pub data: Vec<f32>,
}

impl VectorSet {
    pub fn new(data: Vec<f32>, dim: usize, metric: crate::distance::Metric) -> Self {
        assert!(dim > 0 && data.len() % dim == 0);
        VectorSet { dim, metric, data }
    }

    pub fn from_dataset(ds: &crate::dataset::Dataset) -> Self {
        VectorSet::new(ds.base.clone(), ds.dim, ds.metric)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn vec(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn distance(&self, q: &[f32], i: u32) -> f32 {
        self.metric.distance(q, self.vec(i))
    }

    /// Distances from `q` to a gathered id list through the one-to-many
    /// SIMD kernels (prefetch pipelined; clears and refills `out`). Bitwise
    /// identical to per-pair [`VectorSet::distance`] calls. The SQ8
    /// counterpart for code rows is
    /// [`crate::distance::quant::QuantizedStore::distance_batch`].
    #[inline]
    pub fn distance_batch(&self, q: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.metric.distance_batch(q, ids, &self.data, self.dim, out);
    }

    /// [`VectorSet::distance_batch`] with an explicit prefetch schedule —
    /// how the §6 prefetch knobs reach the batched paths (`lookahead == 0`
    /// disables prefetch; results are identical for every schedule).
    #[inline]
    pub fn distance_batch_with(
        &self,
        q: &[f32],
        ids: &[u32],
        lookahead: usize,
        locality: i32,
        out: &mut Vec<f32>,
    ) {
        crate::distance::distance_batch_with(
            self.metric,
            q,
            ids,
            &self.data,
            self.dim,
            lookahead,
            locality,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn vectorset_accessors() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0], 2, Metric::L2);
        assert_eq!(vs.len(), 2);
        assert_eq!(vs.vec(1), &[3.0, 4.0]);
        assert_eq!(vs.distance(&[0.0, 0.0], 1), 25.0);
    }

    #[test]
    fn vectorset_distance_batch_matches_per_pair() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2, Metric::L2);
        let q = [0.5, 0.5];
        let mut out = Vec::new();
        vs.distance_batch(&q, &[2, 0, 1], &mut out);
        assert_eq!(out, vec![vs.distance(&q, 2), vs.distance(&q, 0), vs.distance(&q, 1)]);
    }
}
