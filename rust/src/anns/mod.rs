//! ANNS index implementations.
//!
//! [`hnsw`] is the optimization backbone (§2 of the paper); [`glass`] wraps
//! it with SQ8 quantized search + exact refinement — the RL starting point
//! (§3.5). The rest are the Figure-1 baselines: [`bruteforce`] (exact),
//! [`nndescent`] (NNDescent / PyNNDescent), [`vamana`] (ParlayANN-like),
//! [`ivf`] (Vearch-like). All implement [`AnnIndex`] so the eval harness
//! and serving coordinator treat them uniformly.

pub mod bruteforce;
pub mod glass;
pub mod heap;
pub mod hnsw;
pub mod ivf;
pub mod nndescent;
pub mod persist;
pub mod vamana;
pub mod visited;

/// A built, queryable index.
pub trait AnnIndex: Send + Sync {
    /// Implementation name (appears in reports / Figure 1 legends).
    fn name(&self) -> String;

    /// k-NN search. `ef` is the beam/candidate budget (the recall↔speed
    /// knob swept by the benchmarks; brute force ignores it). Returns ids
    /// nearest-first.
    fn search(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32>;

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True if no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (memory reporting in EXPERIMENTS.md).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// Owned view of base vectors shared by index implementations.
#[derive(Clone, Debug)]
pub struct VectorSet {
    pub dim: usize,
    pub metric: crate::distance::Metric,
    pub data: Vec<f32>,
}

impl VectorSet {
    pub fn new(data: Vec<f32>, dim: usize, metric: crate::distance::Metric) -> Self {
        assert!(dim > 0 && data.len() % dim == 0);
        VectorSet { dim, metric, data }
    }

    pub fn from_dataset(ds: &crate::dataset::Dataset) -> Self {
        VectorSet::new(ds.base.clone(), ds.dim, ds.metric)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn vec(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn distance(&self, q: &[f32], i: u32) -> f32 {
        self.metric.distance(q, self.vec(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn vectorset_accessors() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0], 2, Metric::L2);
        assert_eq!(vs.len(), 2);
        assert_eq!(vs.vec(1), &[3.0, 4.0]);
        assert_eq!(vs.distance(&[0.0, 0.0], 1), 25.0);
    }
}
