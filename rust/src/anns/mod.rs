//! ANNS index implementations.
//!
//! [`hnsw`] is the optimization backbone (§2 of the paper); [`glass`] wraps
//! it with SQ8 quantized search + exact refinement — the RL starting point
//! (§3.5). The rest are the Figure-1 baselines: [`bruteforce`] (exact),
//! [`nndescent`] (NNDescent / PyNNDescent), [`vamana`] (ParlayANN-like),
//! [`ivf`] (Vearch-like). All implement [`AnnIndex`] so the eval harness
//! and serving coordinator treat them uniformly.

pub mod bruteforce;
pub mod glass;
pub mod heap;
pub mod hnsw;
pub mod ivf;
pub mod nndescent;
pub mod persist;
pub mod scratch;
pub mod vamana;
pub mod visited;

/// A built, queryable index.
///
/// The trait is **distance-carrying and batch-first**: the one required
/// search method is [`AnnIndex::search_with_dists`], so exact distances
/// survive the trait boundary (the coordinator surfaces them in
/// `QueryResponse`, the sharded router merges on them), and
/// [`AnnIndex::search_batch`] is the serving entry point — all six index
/// types override it to reuse one pooled
/// [`hnsw::search::SearchContext`] across the whole batch. Batch results
/// are bitwise identical to per-query [`AnnIndex::search_with_dists`]
/// calls for every index and metric (asserted by `tests/properties.rs`),
/// extending the kernel-level batch==per-pair identity up through the
/// whole stack.
pub trait AnnIndex: Send + Sync {
    /// Implementation name (appears in reports / Figure 1 legends).
    fn name(&self) -> String;

    /// k-NN search returning `(distance, id)` pairs nearest-first. `ef` is
    /// the beam/candidate budget (the recall↔speed knob swept by the
    /// benchmarks; brute force ignores it). Distances are **exact
    /// full-precision metric values** (quantized pipelines rerank in f32
    /// before returning) — the contract that lets the sharded router merge
    /// shard results on carried distances without rescoring.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)>;

    /// Ids-only k-NN search — a thin projection of
    /// [`AnnIndex::search_with_dists`].
    fn search(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32> {
        self.search_with_dists(query, k, ef)
            .into_iter()
            .map(|(_, i)| i)
            .collect()
    }

    /// Multi-query batch search: one result list per query, in query
    /// order, each bitwise identical to the corresponding
    /// [`AnnIndex::search_with_dists`] call. The default loops per query;
    /// implementations override it to amortize scratch checkout and keep
    /// caches warm across the batch.
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        queries
            .iter()
            .map(|q| self.search_with_dists(q, k, ef))
            .collect()
    }

    /// Number of indexed vectors.
    fn len(&self) -> usize;

    /// True if no vectors are indexed.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (memory reporting in EXPERIMENTS.md).
    fn memory_bytes(&self) -> usize {
        0
    }
}

/// Owned view of base vectors shared by index implementations.
#[derive(Clone, Debug)]
pub struct VectorSet {
    pub dim: usize,
    pub metric: crate::distance::Metric,
    pub data: Vec<f32>,
}

impl VectorSet {
    pub fn new(data: Vec<f32>, dim: usize, metric: crate::distance::Metric) -> Self {
        assert!(dim > 0 && data.len() % dim == 0);
        VectorSet { dim, metric, data }
    }

    pub fn from_dataset(ds: &crate::dataset::Dataset) -> Self {
        VectorSet::new(ds.base.clone(), ds.dim, ds.metric)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn vec(&self, i: u32) -> &[f32] {
        let i = i as usize;
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    #[inline]
    pub fn distance(&self, q: &[f32], i: u32) -> f32 {
        self.metric.distance(q, self.vec(i))
    }

    /// Distances from `q` to a gathered id list through the one-to-many
    /// SIMD kernels (prefetch pipelined; clears and refills `out`). Bitwise
    /// identical to per-pair [`VectorSet::distance`] calls. The SQ8
    /// counterpart for code rows is
    /// [`crate::distance::quant::QuantizedStore::distance_batch`].
    #[inline]
    pub fn distance_batch(&self, q: &[f32], ids: &[u32], out: &mut Vec<f32>) {
        self.metric.distance_batch(q, ids, &self.data, self.dim, out);
    }

    /// [`VectorSet::distance_batch`] with an explicit prefetch schedule —
    /// how the §6 prefetch knobs reach the batched paths (`lookahead == 0`
    /// disables prefetch; results are identical for every schedule).
    #[inline]
    pub fn distance_batch_with(
        &self,
        q: &[f32],
        ids: &[u32],
        lookahead: usize,
        locality: i32,
        out: &mut Vec<f32>,
    ) {
        crate::distance::distance_batch_with(
            self.metric,
            q,
            ids,
            &self.data,
            self.dim,
            lookahead,
            locality,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    #[test]
    fn vectorset_accessors() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0], 2, Metric::L2);
        assert_eq!(vs.len(), 2);
        assert_eq!(vs.vec(1), &[3.0, 4.0]);
        assert_eq!(vs.distance(&[0.0, 0.0], 1), 25.0);
    }

    #[test]
    fn vectorset_distance_batch_matches_per_pair() {
        let vs = VectorSet::new(vec![0.0, 0.0, 3.0, 4.0, 1.0, 1.0], 2, Metric::L2);
        let q = [0.5, 0.5];
        let mut out = Vec::new();
        vs.distance_batch(&q, &[2, 0, 1], &mut out);
        assert_eq!(out, vec![vs.distance(&q, 2), vs.distance(&q, 0), vs.distance(&q, 1)]);
    }
}
