//! GLASS-style index: HNSW graph + SQ8 quantized primary search + exact
//! refinement — the paper's RL starting point (§3.5) and the index CRINN's
//! three optimization modules act on.
//!
//! Search pipeline (§2.3 "Refinement"):
//! 1. greedy upper-layer descent (full precision — the upper layers are
//!    tiny and touched a handful of times);
//! 2. layer-0 beam search over **int8 codes** (4–8x less memory traffic
//!    than f32 — the quantized preliminary search);
//! 3. exact re-rank of the top `rerank_count` survivors in full precision
//!    (asymmetric refinement), honoring the §6.3 knobs: adaptive prefetch
//!    with lookahead, and precomputed edge metadata during traversal.
//!
//! The batch rerank can also run through the AOT Pallas artifact
//! (`runtime::Engine::rerank`) — used by the serving coordinator; the
//! per-query path below stays in Rust.

use crate::anns::heap::TopK;
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::hnsw::search::{greedy_descent, search_filtered, SearchContext};
use crate::anns::hnsw::builder;
use crate::anns::scratch::ScratchPool;
use crate::anns::tombstones::Tombstones;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::distance::quant::QuantizedStore;
use crate::util::rng::Rng;
use crate::variants::VariantConfig;

/// GLASS index: graph + quantized codes + variant knobs.
///
/// Mutable ([`MutableAnnIndex`]): inserts run the shared HNSW insertion
/// body and append an SQ8 code row encoded with the *frozen* build-time
/// scale (re-quantization drift is bounded by the robust-quantile scale;
/// a rebuild re-fits it), deletes tombstone a bit consulted by both the
/// quantized beam and the full-precision fallback, and consolidation
/// repairs edges via [`HnswGraph::drop_nodes`] with slot recycling.
pub struct GlassIndex {
    pub graph: HnswGraph,
    pub quant: QuantizedStore,
    pub config: VariantConfig,
    label: String,
    scratch: ScratchPool,
    pub(crate) deleted: Tombstones,
    /// Consolidated slots awaiting reuse (still marked in `deleted`).
    pub(crate) free: Vec<u32>,
    /// Level-sampling stream for online inserts (deterministic per seed).
    rng: Rng,
}

impl GlassIndex {
    /// Build from vectors under a full variant configuration.
    pub fn build(vs: VectorSet, config: VariantConfig, seed: u64) -> Self {
        let quant = QuantizedStore::build(&vs.data, vs.dim);
        let graph = builder::build(vs, &config.construction, seed);
        let deleted = Tombstones::new(graph.len());
        GlassIndex {
            graph,
            quant,
            config,
            label: "glass".to_string(),
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
            rng: Rng::new(seed ^ 0x61A5_61A5),
        }
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Reassemble from persisted parts (see [`crate::anns::persist`]).
    pub fn from_parts(graph: HnswGraph, quant: QuantizedStore, config: VariantConfig) -> Self {
        let deleted = Tombstones::new(graph.len());
        GlassIndex {
            graph,
            quant,
            config,
            label: "glass".to_string(),
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
            rng: Rng::new(0x61A5_61A5),
        }
    }

    /// Restore persisted mutation state (tombstones + free list + the
    /// insert-level RNG stream) — the persist reader validates shape
    /// before calling this. Restoring the RNG state keeps a reloaded
    /// snapshot *stream-deterministic*: the same inserts applied to the
    /// loaded index and to the original in-memory one sample the same
    /// levels and build the same edges.
    pub(crate) fn restore_mutation_state(
        &mut self,
        deleted: Tombstones,
        free: Vec<u32>,
        rng_state: [u64; 4],
    ) {
        self.deleted = deleted;
        self.free = free;
        self.rng = Rng::from_state(rng_state);
    }

    /// Raw insert-level RNG state (persistence).
    pub(crate) fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// `true` when `id` may appear in results (see
    /// [`Tombstones::is_live`]).
    #[inline]
    fn live(&self, id: u32) -> bool {
        self.deleted.is_live(id)
    }

    /// Tombstone filter for the full-precision fallback path (see
    /// [`Tombstones::filter_ref`]).
    fn tombstone_ref(&self) -> Option<&Tombstones> {
        self.deleted.filter_ref()
    }

    /// Swap the search/refine knobs without rebuilding the graph — how the
    /// CRINN trainer evaluates search- and refinement-module candidates
    /// cheaply (§3.5: construction is only rebuilt in its own round).
    pub fn set_runtime_knobs(&mut self, config: &VariantConfig) {
        self.config.search = config.search.clone();
        self.config.refine = config.refine.clone();
    }

    /// One query through the full pipeline with caller-provided scratch —
    /// the shared body of `search_with_dists` and `search_batch`.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
    ) -> Vec<(f32, u32)> {
        if self.graph.is_empty() {
            return Vec::new();
        }
        if !self.config.refine.quantized_primary {
            // Plain full-precision HNSW search (refinement disabled point
            // in the action space).
            return search_filtered(
                &self.graph,
                &self.config.search,
                ctx,
                query,
                k,
                ef,
                self.tombstone_ref(),
            );
        }
        let pool = self.quantized_beam(query, k, ef, ctx);
        self.rerank(query, k, ef, pool, ctx)
    }

    /// Layer-0 beam search over int8 codes (§2.3 quantized preliminary
    /// search) with the search-module knobs.
    fn quantized_beam(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
    ) -> Vec<(f32, u32)> {
        let g = &self.graph;
        let knobs = &self.config.search;
        let refine = &self.config.refine;
        let ef = ef.max(k);
        let qcode = self.quant.encode_query(query);
        let metric = g.vectors.metric;

        ctx.visited.clear();
        ctx.frontier.clear();
        let mut results = TopK::new(ef);

        // Tier-1 entry from full-precision greedy descent. Tombstoned
        // nodes seed/extend the frontier (they stay traversable) but never
        // enter the result pool — same contract as
        // [`crate::anns::hnsw::search::search_filtered`].
        let (_, e0) = greedy_descent(g, query);
        let d0 = self.quant.distance(metric, &qcode, e0 as usize);
        ctx.visited.insert(e0);
        ctx.frontier.push(d0, e0);
        if self.live(e0) {
            results.push(d0, e0);
        }
        // Extra tiers (§6.2) from the diverse entry-point set. Tier 1 uses
        // only the greedy-descended entry (same fix as `hnsw::search`: the
        // old `_ => 1` fallback silently ran tier-2 behavior).
        let extra = match (knobs.entry_tiers, ef) {
            (t, ef) if t >= 3 && ef >= knobs.tier_budget_2 => g.entry_points.len(),
            (t, ef) if t >= 2 && ef >= knobs.tier_budget_1 => 3,
            _ => 0,
        };
        for &ep in g.entry_points.iter().take(extra) {
            if ctx.visited.insert(ep) {
                let d = self.quant.distance(metric, &qcode, ep as usize);
                ctx.frontier.push(d, ep);
                if self.live(ep) {
                    results.push(d, ep);
                }
            }
        }

        let mut no_improve = 0usize;
        let patience = knobs.patience.max(1) * 4;
        while let Some((d, u)) = ctx.frontier.pop() {
            if d > results.bound() {
                break;
            }
            // §6.3 precomputed metadata vs sentinel scan.
            let neighbors: &[u32] = if refine.precomputed_metadata {
                g.neighbors0_meta(u)
            } else {
                g.neighbors0_scan(u)
            };
            let mut improved = false;
            if knobs.edge_batch {
                // Gather unvisited neighbors, then evaluate each batch with
                // one one-to-many i8 kernel call into the pooled `dists`
                // buffer (same shape as the f32 HNSW edge batching) —
                // prefetch of code row `i + depth` is pipelined inside the
                // kernel while row `i` is evaluated. Distances are exactly
                // equal to the per-pair path (i32 accumulation), so batching
                // never changes search results.
                let bs = knobs.batch_size.max(1);
                let lookahead = if refine.adaptive_prefetch {
                    knobs.prefetch_depth.max(1)
                } else {
                    0
                };
                let mut idx = 0;
                while idx < neighbors.len() {
                    ctx.batch.clear();
                    while idx < neighbors.len() && ctx.batch.len() < bs {
                        let nb = neighbors[idx];
                        idx += 1;
                        if ctx.visited.insert(nb) {
                            ctx.batch.push(nb);
                        }
                    }
                    self.quant.distance_batch_with(
                        metric,
                        &qcode,
                        &ctx.batch,
                        lookahead,
                        knobs.prefetch_locality,
                        &mut ctx.dists,
                    );
                    for (&nb, &dnb) in ctx.batch.iter().zip(ctx.dists.iter()) {
                        if dnb < results.bound() {
                            if self.live(nb) && results.push(dnb, nb) {
                                improved = true;
                            }
                            ctx.frontier.push(dnb, nb);
                        }
                    }
                }
            } else {
                for (j, &nb) in neighbors.iter().enumerate() {
                    // §6.3 adaptive lookahead prefetch over future edges.
                    if refine.adaptive_prefetch {
                        let ahead = j + refine.lookahead.max(1);
                        if ahead < neighbors.len() {
                            prefetch_code(
                                self.quant.code(neighbors[ahead] as usize),
                                knobs.prefetch_locality,
                            );
                        }
                    }
                    if !ctx.visited.insert(nb) {
                        continue;
                    }
                    let dnb = self.quant.distance(metric, &qcode, nb as usize);
                    if dnb < results.bound() {
                        if self.live(nb) && results.push(dnb, nb) {
                            improved = true;
                        }
                        ctx.frontier.push(dnb, nb);
                    }
                }
            }
            if knobs.early_termination {
                if improved {
                    no_improve = 0;
                } else {
                    no_improve += 1;
                    if no_improve >= patience && results.is_full() {
                        break;
                    }
                }
            }
        }
        results.into_sorted()
    }

    /// Exact re-rank of the quantized survivors (§6.3 knobs). With
    /// `adaptive_prefetch` the gather runs through the one-to-many SIMD
    /// kernel (prefetch pipelined, `refine.lookahead` deep) using the
    /// pooled context's batch buffers — no per-query allocation beyond the
    /// returned vector. Distances are bitwise identical either way, so
    /// the knob stays a pure speed dial.
    fn rerank(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        pool: Vec<(f32, u32)>,
        ctx: &mut SearchContext,
    ) -> Vec<(f32, u32)> {
        let refine = &self.config.refine;
        let take = refine.rerank_count(k, ef).min(pool.len());
        let mut out: Vec<(f32, u32)> = Vec::with_capacity(take);
        if refine.adaptive_prefetch {
            ctx.batch.clear();
            ctx.batch.extend(pool.iter().take(take).map(|&(_, id)| id));
            self.graph.vectors.distance_batch_with(
                query,
                &ctx.batch,
                refine.lookahead.max(1),
                3,
                &mut ctx.dists,
            );
            out.extend(ctx.batch.iter().zip(ctx.dists.iter()).map(|(&id, &d)| (d, id)));
        } else {
            out.extend(
                pool.iter()
                    .take(take)
                    .map(|&(_, id)| (self.graph.vectors.distance(query, id), id)),
            );
        }
        out.sort_by(crate::anns::heap::dist_cmp);
        out.truncate(k);
        out
    }

    /// The candidate pools for a batch of queries (pre-rerank) — feeds the
    /// PJRT batch-rerank path in the serving coordinator. Honors
    /// `refine.quantized_primary` exactly like [`Self::search_with_dists`]:
    /// when the knob is off the pool comes from the full-precision HNSW
    /// search (with `k = ef` so the whole beam pool survives — `search`
    /// truncates to `k`), so an exact rerank of these candidates reproduces
    /// `search_with_dists` at both points of the action space.
    pub fn candidates_for_rerank(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        let pool = if self.config.refine.quantized_primary {
            self.quantized_beam(query, k, ef, &mut ctx)
        } else {
            search_filtered(
                &self.graph,
                &self.config.search,
                &mut ctx,
                query,
                ef.max(k),
                ef,
                self.tombstone_ref(),
            )
        };
        let take = self.config.refine.rerank_count(k, ef).min(pool.len());
        pool.into_iter().take(take).map(|(_, i)| i).collect()
    }
}

#[inline]
fn prefetch_code(code: &[i8], locality: i32) {
    // Hint the raw byte address — cache lines are typeless. The previous
    // version reinterpreted the codes as `&[f32]` with a fudged length,
    // which constructed an out-of-bounds slice whenever `dim < 4` (UB even
    // though prefetch never dereferences); `prefetch_ptr` takes the pointer
    // directly, valid for every dim.
    crate::distance::prefetch_ptr(code.as_ptr().cast(), locality);
}

impl AnnIndex for GlassIndex {
    fn name(&self) -> String {
        self.label.clone()
    }

    /// Search returning `(exact_dist, id)` nearest-first.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        self.search_one(query, k, ef, &mut ctx)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One pooled context drives the whole batch (quantized beam +
        // exact rerank both reset it per query), so the batch path is
        // bitwise identical to per-query `search_with_dists`.
        let mut ctx = self.scratch.checkout(self.graph.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx))
            .collect()
    }

    fn len(&self) -> usize {
        self.graph.len()
    }

    fn memory_bytes(&self) -> usize {
        self.graph.memory_bytes() + self.quant.bytes()
    }
}

impl MutableAnnIndex for GlassIndex {
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        // Shared HNSW insertion body; the slot hook keeps the SQ8 code
        // rows in lockstep with the vector rows (frozen-scale encoding).
        let quant = &mut self.quant;
        crate::anns::hnsw::insert_point(
            &mut self.graph,
            &self.config.construction,
            &self.scratch,
            &mut self.deleted,
            &mut self.free,
            &mut self.rng,
            vec,
            |id, recycled| {
                if recycled {
                    quant.reencode(id as usize, vec);
                } else {
                    quant.append(vec);
                }
            },
        )
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        self.deleted.delete(id)
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        Ok(crate::anns::hnsw::consolidate_graph(
            &mut self.graph,
            &self.deleted,
            &mut self.free,
        ))
    }

    fn live_count(&self) -> usize {
        self.graph.len() - self.deleted.count()
    }

    fn deleted_count(&self) -> usize {
        self.deleted.count() - self.free.len()
    }

    fn is_deleted(&self, id: u32) -> bool {
        self.deleted.contains(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn dataset() -> crate::dataset::Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1500, 50, 21);
        ds.compute_ground_truth(10);
        ds
    }

    fn recall(idx: &GlassIndex, ds: &crate::dataset::Dataset, ef: usize) -> f64 {
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 10, ef);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        acc / ds.n_queries() as f64
    }

    #[test]
    fn glass_baseline_reaches_high_recall() {
        let ds = dataset();
        let idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let r = recall(&idx, &ds, 128);
        assert!(r > 0.85, "glass recall@10 ef=128: {r}");
    }

    #[test]
    fn crinn_full_matches_or_beats_baseline_recall() {
        let ds = dataset();
        let base = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let crinn = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 3);
        let rb = recall(&base, &ds, 96);
        let rc = recall(&crinn, &ds, 96);
        assert!(rc > rb - 0.05, "baseline {rb} vs crinn {rc}");
    }

    #[test]
    fn rerank_improves_over_raw_quantized_order() {
        let ds = dataset();
        let mut cfg = VariantConfig::glass_baseline();
        cfg.refine.rerank_frac = 2.0; // deep rerank
        let idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
        let deep = recall(&idx, &ds, 64);
        let mut shallow_cfg = VariantConfig::glass_baseline();
        shallow_cfg.refine.rerank_frac = 0.2;
        let mut idx2 = GlassIndex::build(VectorSet::from_dataset(&ds), shallow_cfg, 3);
        idx2.set_runtime_knobs(&idx2.config.clone());
        let shallow = recall(&idx2, &ds, 64);
        assert!(deep >= shallow, "deep {deep} shallow {shallow}");
    }

    #[test]
    fn runtime_knob_swap_changes_behavior_without_rebuild() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let before = idx.search(ds.query_vec(0), 10, 64);
        let mut cfg = idx.config.clone();
        cfg.refine.quantized_primary = false;
        idx.set_runtime_knobs(&cfg);
        let after = idx.search(ds.query_vec(0), 10, 64);
        // Same graph, different pipeline; both decent answers.
        assert_eq!(before.len(), after.len());
    }

    #[test]
    fn adaptive_prefetch_is_result_invariant() {
        // The §6.3 prefetch knob now routes the rerank gather through the
        // one-to-many SIMD kernel; it must stay a pure speed dial.
        let ds = dataset();
        let mut cfg = VariantConfig::glass_baseline();
        cfg.refine.adaptive_prefetch = false;
        let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg.clone(), 3);
        let before: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        cfg.refine.adaptive_prefetch = true;
        idx.set_runtime_knobs(&cfg);
        let after: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        assert_eq!(before, after);
    }

    #[test]
    fn candidates_for_rerank_bounded() {
        let ds = dataset();
        let idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let c = idx.candidates_for_rerank(ds.query_vec(0), 10, 64);
        assert!(!c.is_empty());
        assert!(c.len() <= 64);
    }

    /// One dataset per metric for the cross-metric quantized-path tests.
    fn metric_datasets() -> Vec<crate::dataset::Dataset> {
        let mut out = Vec::new();
        let sp = synth::spec("demo-64").unwrap();
        let mut l2 = synth::generate_counts(sp, 1200, 30, 31);
        l2.compute_ground_truth(10);
        out.push(l2);
        let sp = synth::spec("glove-25-angular").unwrap();
        let mut ang = synth::generate_counts(sp, 1200, 30, 32);
        ang.compute_ground_truth(10);
        out.push(ang);
        // No Ip preset: reuse the demo manifold under the Ip convention.
        let sp = synth::spec("demo-64").unwrap();
        let mut ip = synth::generate_counts(sp, 1200, 30, 33);
        ip.metric = crate::distance::Metric::Ip;
        ip.compute_ground_truth(10);
        out.push(ip);
        out
    }

    #[test]
    fn edge_batch_rewrite_is_result_identical_all_metrics() {
        // Acceptance criterion: the one-batch-call-per-gathered-batch
        // quantized beam must return exactly what the per-pair loop
        // returns — ids AND distances — for L2, Angular, and Ip. The i8
        // kernels accumulate in i32, so this is exact, not approximate.
        for ds in metric_datasets() {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.search.edge_batch = false;
            let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg.clone(), 3);
            let per_pair: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            cfg.search.edge_batch = true;
            cfg.search.batch_size = 8;
            idx.set_runtime_knobs(&cfg);
            let batched: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            assert_eq!(per_pair, batched, "metric {:?}", ds.metric);
            // And with the adaptive-prefetch schedule wired into the batch
            // kernel — prefetch must stay a pure speed dial.
            cfg.refine.adaptive_prefetch = true;
            cfg.search.prefetch_depth = 6;
            idx.set_runtime_knobs(&cfg);
            let prefetched: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            assert_eq!(per_pair, prefetched, "prefetch changed results ({:?})", ds.metric);
        }
    }

    #[test]
    fn quantized_beam_reaches_recall_on_angular_and_ip() {
        // The quantized path was only ever recall-tested under L2; assert
        // the Angular/Ip mappings also drive the beam to useful recall and
        // stay consistent with the full-precision pipeline.
        for ds in metric_datasets() {
            let idx = GlassIndex::build(
                VectorSet::from_dataset(&ds),
                VariantConfig::glass_baseline(),
                3,
            );
            let r = recall(&idx, &ds, 128);
            // Absolute floor for the metrics HNSW is strong on; MIPS has no
            // triangle inequality, so Ip only gets the parity bound below.
            if ds.metric != crate::distance::Metric::Ip {
                assert!(r > 0.8, "quantized recall@10 under {:?}: {r}", ds.metric);
            }
            let mut full = VariantConfig::glass_baseline();
            full.refine.quantized_primary = false;
            let fidx = GlassIndex::build(VectorSet::from_dataset(&ds), full, 3);
            let rf = recall(&fidx, &ds, 128);
            assert!(
                r > rf - 0.1,
                "quantized path lost too much recall under {:?}: {r} vs {rf}",
                ds.metric
            );
        }
    }

    #[test]
    fn prefetch_survives_tiny_dims() {
        // Regression for the `prefetch_code` UB: dims 1..3 quantize to code
        // rows shorter than one f32; the old slice reinterpretation built
        // an out-of-bounds `&[f32]` for them. Run the full quantized
        // pipeline with every prefetch knob on.
        for dim in 1usize..=3 {
            let n = 300;
            let mut rng = crate::util::rng::Rng::new(dim as u64);
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let vs = VectorSet::new(data.clone(), dim, crate::distance::Metric::L2);
            let mut cfg = VariantConfig::glass_baseline();
            cfg.refine.adaptive_prefetch = true;
            cfg.refine.lookahead = 4;
            cfg.search.prefetch_depth = 8;
            let mut idx = GlassIndex::build(vs, cfg.clone(), 5);
            // Both the sequential-scan and edge-batch beams touch the
            // prefetch paths.
            for edge_batch in [false, true] {
                let mut c = cfg.clone();
                c.search.edge_batch = edge_batch;
                idx.set_runtime_knobs(&c);
                let out = idx.search(&data[0..dim], 5, 32);
                assert!(!out.is_empty(), "dim={dim} edge_batch={edge_batch}");
            }
        }
    }

    #[test]
    fn mutation_quantized_beam_never_surfaces_tombstones() {
        // Delete the full top-10 of a query: the quantized pipeline (beam
        // + rerank) must return only live ids, for both the edge-batch and
        // sequential beam shapes, and for the full-precision fallback.
        let ds = dataset();
        for (edge_batch, quantized) in [(false, true), (true, true), (false, false)] {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.search.edge_batch = edge_batch;
            cfg.refine.quantized_primary = quantized;
            let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
            let q = ds.query_vec(0);
            let doomed = idx.search(q, 10, 128);
            for &id in &doomed {
                idx.delete(id).unwrap();
            }
            let batched: Vec<u32> = idx
                .search_batch(&[q], 10, 128)
                .pop()
                .unwrap()
                .into_iter()
                .map(|(_, i)| i)
                .collect();
            for out in [idx.search(q, 10, 128), batched] {
                assert_eq!(out.len(), 10);
                for id in out {
                    assert!(
                        !doomed.contains(&id),
                        "tombstoned id {id} surfaced \
                         (edge_batch={edge_batch} quantized={quantized})"
                    );
                }
            }
        }
    }

    #[test]
    fn mutation_insert_consolidate_recycle_glass() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let n0 = idx.len();
        let v = ds.query_vec(1).to_vec();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id as usize, n0);
        assert_eq!(idx.quant.len(), n0 + 1, "code row must be appended");
        // The inserted point wins its own query through the quantized
        // pipeline (self-distance quantizes to exactly 0).
        assert_eq!(idx.search(&v, 1, 64), vec![id]);
        idx.delete(id).unwrap();
        assert_eq!(idx.consolidate().unwrap(), 1);
        idx.graph.validate().unwrap();
        let id2 = idx.insert(&v).unwrap();
        assert_eq!(id2, id, "freed slot must be recycled");
        assert_eq!(idx.quant.len(), n0 + 1, "recycle must not grow the codes");
        assert_eq!(idx.search(&v, 1, 64), vec![id2]);
    }

    #[test]
    fn candidates_for_rerank_honors_quantized_primary() {
        // Pool parity at both points of the action space: reranking the
        // returned candidates in full precision must reproduce
        // `search_with_dists` exactly, whether the pool came from the
        // quantized beam or the full-precision fallback.
        let ds = dataset();
        for quantized in [true, false] {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.refine.quantized_primary = quantized;
            let idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
            for qi in 0..ds.n_queries().min(10) {
                let q = ds.query_vec(qi);
                let want: Vec<u32> = idx
                    .search_with_dists(q, 10, 64)
                    .into_iter()
                    .map(|(_, i)| i)
                    .collect();
                let cands = idx.candidates_for_rerank(q, 10, 64);
                let mut reranked: Vec<(f32, u32)> = cands
                    .iter()
                    .map(|&id| (idx.graph.vectors.distance(q, id), id))
                    .collect();
                reranked.sort_by(crate::anns::heap::dist_cmp);
                reranked.truncate(10);
                let got: Vec<u32> = reranked.into_iter().map(|(_, i)| i).collect();
                assert_eq!(got, want, "quantized_primary={quantized} query {qi}");
            }
        }
    }
}
