//! GLASS-style index: HNSW graph + SQ8 quantized primary search + exact
//! refinement — the paper's RL starting point (§3.5) and the index CRINN's
//! three optimization modules act on.
//!
//! Search pipeline (§2.3 "Refinement"):
//! 1. greedy upper-layer descent (full precision — the upper layers are
//!    tiny and touched a handful of times);
//! 2. layer-0 beam search over **int8 codes** (4–8x less memory traffic
//!    than f32 — the quantized preliminary search);
//! 3. exact re-rank of the top `rerank_count` survivors in full precision
//!    (asymmetric refinement), honoring the §6.3 knobs: adaptive prefetch
//!    with lookahead, and precomputed edge metadata during traversal.
//!
//! The batch rerank can also run through the AOT Pallas artifact
//! (`runtime::Engine::rerank`) — used by the serving coordinator; the
//! per-query path below stays in Rust.

use crate::anns::filter::{Admit, FilterBitset, DEFAULT_FILTERED_FALLBACK};
use crate::anns::hnsw::builder;
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::hnsw::search::{
    beam_search0, greedy_descent, search_admit, BeamScorer, SearchContext,
};
use crate::anns::scratch::ScratchPool;
use crate::anns::store::pq::PqStore;
use crate::anns::tombstones::Tombstones;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::distance::quant::QuantizedStore;
use crate::distance::simd::PqLut;
use crate::distance::Metric;
use crate::util::rng::Rng;
use crate::variants::VariantConfig;

/// GLASS index: graph + quantized codes + variant knobs.
///
/// Mutable ([`MutableAnnIndex`]): inserts run the shared HNSW insertion
/// body and append an SQ8 code row encoded with the *frozen* build-time
/// scale (re-quantization drift is bounded by the robust-quantile scale;
/// a rebuild re-fits it), deletes tombstone a bit consulted by both the
/// quantized beam and the full-precision fallback, and consolidation
/// repairs edges via [`HnswGraph::drop_nodes`] with slot recycling.
pub struct GlassIndex {
    pub graph: HnswGraph,
    pub quant: QuantizedStore,
    /// Optional 4-bit PQ codes for the layer-0 beam (DESIGN.md
    /// §PQ-Fast-Scan): when present, the quantized preliminary search
    /// scores through ADC lookup tables instead of the SQ8 rows — 8× less
    /// code traffic — and the exact rerank stays unchanged. Outside the
    /// CRINN action space (a serving-mode choice, not a tuned knob).
    pq: Option<PqStore>,
    pub config: VariantConfig,
    label: String,
    scratch: ScratchPool,
    pub(crate) deleted: Tombstones,
    /// Consolidated slots awaiting reuse (still marked in `deleted`).
    pub(crate) free: Vec<u32>,
    /// Level-sampling stream for online inserts (deterministic per seed).
    rng: Rng,
    /// Selectivity crossover for filtered search (see
    /// [`AnnIndex::filtered_fallback_threshold`]).
    filtered_fallback: usize,
}

impl GlassIndex {
    /// Build from vectors under a full variant configuration.
    pub fn build(vs: VectorSet, config: VariantConfig, seed: u64) -> Self {
        let quant = QuantizedStore::build(&vs.data, vs.dim);
        let graph = builder::build(vs, &config.construction, seed);
        let deleted = Tombstones::new(graph.len());
        GlassIndex {
            graph,
            quant,
            pq: None,
            config,
            label: "glass".to_string(),
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
            rng: Rng::new(seed ^ 0x61A5_61A5),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Train 4-bit PQ codebooks over the current vectors and switch the
    /// layer-0 beam to ADC fast-scan. Deterministic for a fixed seed;
    /// codebooks are frozen afterwards (inserts only encode).
    pub fn enable_pq(&mut self, m: usize, seed: u64) {
        self.pq = Some(PqStore::build(
            &self.graph.vectors.data,
            self.graph.vectors.dim,
            m,
            seed,
        ));
    }

    /// Attach an already-built PQ store (snapshot load path). The reader
    /// validates shape/row-count against the graph before calling this.
    pub(crate) fn attach_pq(&mut self, store: PqStore) {
        self.pq = Some(store);
    }

    /// The layer-0 PQ store, when enabled.
    pub fn pq_store(&self) -> Option<&PqStore> {
        self.pq.as_ref()
    }

    /// Tune the selectivity crossover: filters with at most this many
    /// matching ids take the exact-scan fallback instead of the beam.
    pub fn set_filtered_fallback(&mut self, threshold: usize) {
        self.filtered_fallback = threshold;
    }

    /// Reassemble from persisted parts (see [`crate::anns::persist`]).
    pub fn from_parts(graph: HnswGraph, quant: QuantizedStore, config: VariantConfig) -> Self {
        let deleted = Tombstones::new(graph.len());
        GlassIndex {
            graph,
            quant,
            pq: None,
            config,
            label: "glass".to_string(),
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
            rng: Rng::new(0x61A5_61A5),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    /// Restore persisted mutation state (tombstones + free list + the
    /// insert-level RNG stream) — the persist reader validates shape
    /// before calling this. Restoring the RNG state keeps a reloaded
    /// snapshot *stream-deterministic*: the same inserts applied to the
    /// loaded index and to the original in-memory one sample the same
    /// levels and build the same edges.
    pub(crate) fn restore_mutation_state(
        &mut self,
        deleted: Tombstones,
        free: Vec<u32>,
        rng_state: [u64; 4],
    ) {
        self.deleted = deleted;
        self.free = free;
        self.rng = Rng::from_state(rng_state);
    }

    /// Raw insert-level RNG state (persistence).
    pub(crate) fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Tombstone filter for the full-precision fallback path (see
    /// [`Tombstones::filter_ref`]).
    fn tombstone_ref(&self) -> Option<&Tombstones> {
        self.deleted.filter_ref()
    }

    /// Swap the search/refine knobs without rebuilding the graph — how the
    /// CRINN trainer evaluates search- and refinement-module candidates
    /// cheaply (§3.5: construction is only rebuilt in its own round).
    pub fn set_runtime_knobs(&mut self, config: &VariantConfig) {
        self.config.search = config.search.clone();
        self.config.refine = config.refine.clone();
    }

    /// One query through the full pipeline with caller-provided scratch —
    /// the shared body of the (filtered and unfiltered) search and batch
    /// entry points. `filter = None` takes exactly the pre-filter path.
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        if self.graph.is_empty() {
            return Vec::new();
        }
        if let Some(f) = filter {
            // Selectivity fallback: with only a handful of matching ids an
            // exact scan beats (and out-recalls) any beam.
            if f.count() <= self.filtered_fallback {
                return crate::anns::filtered_exact_fallback(
                    &self.graph.vectors,
                    query,
                    k,
                    &mut ctx.batch,
                    &mut ctx.dists,
                    self.tombstone_ref(),
                    f,
                );
            }
        }
        let admit = Admit {
            deleted: self.tombstone_ref(),
            filter,
        };
        if !self.config.refine.quantized_primary {
            // Plain full-precision HNSW search (refinement disabled point
            // in the action space).
            return search_admit(&self.graph, &self.config.search, ctx, query, k, ef, admit);
        }
        let pool = self.quantized_beam(query, k, ef, ctx, admit);
        self.rerank(query, k, ef, pool, ctx)
    }

    /// Layer-0 beam search over int8 codes (§2.3 quantized preliminary
    /// search) with the search-module knobs. The beam control flow is the
    /// shared [`beam_search0`] — only the SQ8 scoring/prefetch behavior
    /// ([`QuantScorer`]) lives here. Tombstoned/non-matching nodes
    /// seed/extend the frontier (they stay traversable) but never enter
    /// the result pool — same contract as
    /// [`crate::anns::hnsw::search::search_admit`].
    fn quantized_beam(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut SearchContext,
        admit: Admit<'_>,
    ) -> Vec<(f32, u32)> {
        let g = &self.graph;
        let knobs = &self.config.search;
        let refine = &self.config.refine;
        let metric = g.vectors.metric;
        if let Some(store) = &self.pq {
            // PQ beam: one LUT build per query, then every scored node is
            // m u8 table lookups. Same control flow, same admission, same
            // exact rerank afterwards.
            let lut = store.lut(metric, query);
            let (_, e0) = greedy_descent(g, query);
            let d0 = store.distance(&lut, e0 as usize);
            let scorer = PqScorer {
                pq: store,
                graph: g,
                lut: &lut,
                batch_lookahead: if refine.adaptive_prefetch {
                    knobs.prefetch_depth.max(1)
                } else {
                    0
                },
                seq_lookahead: refine.lookahead.max(1),
                adaptive_prefetch: refine.adaptive_prefetch,
                precomputed_metadata: refine.precomputed_metadata,
                locality: knobs.prefetch_locality,
            };
            return beam_search0(
                &scorer,
                knobs,
                ctx,
                (d0, e0),
                &g.entry_points,
                ef.max(k),
                &admit,
            );
        }
        let qcode = self.quant.encode_query(query);
        // Tier-1 entry from full-precision greedy descent, re-scored in the
        // quantized space the beam ranks in.
        let (_, e0) = greedy_descent(g, query);
        let d0 = self.quant.distance(metric, &qcode, e0 as usize);
        let scorer = QuantScorer {
            quant: &self.quant,
            graph: g,
            qcode: &qcode,
            metric,
            batch_lookahead: if refine.adaptive_prefetch {
                knobs.prefetch_depth.max(1)
            } else {
                0
            },
            seq_lookahead: refine.lookahead.max(1),
            adaptive_prefetch: refine.adaptive_prefetch,
            precomputed_metadata: refine.precomputed_metadata,
            locality: knobs.prefetch_locality,
        };
        beam_search0(
            &scorer,
            knobs,
            ctx,
            (d0, e0),
            &g.entry_points,
            ef.max(k),
            &admit,
        )
    }

    /// Exact re-rank of the quantized survivors (§6.3 knobs). With
    /// `adaptive_prefetch` the gather runs through the one-to-many SIMD
    /// kernel (prefetch pipelined, `refine.lookahead` deep) using the
    /// pooled context's batch buffers — no per-query allocation beyond the
    /// returned vector. Distances are bitwise identical either way, so
    /// the knob stays a pure speed dial.
    fn rerank(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        pool: Vec<(f32, u32)>,
        ctx: &mut SearchContext,
    ) -> Vec<(f32, u32)> {
        let refine = &self.config.refine;
        let take = refine.rerank_count(k, ef).min(pool.len());
        let mut out: Vec<(f32, u32)> = Vec::with_capacity(take);
        if refine.adaptive_prefetch {
            ctx.batch.clear();
            ctx.batch.extend(pool.iter().take(take).map(|&(_, id)| id));
            self.graph.vectors.distance_batch_with(
                query,
                &ctx.batch,
                refine.lookahead.max(1),
                3,
                &mut ctx.dists,
            );
            out.extend(ctx.batch.iter().zip(ctx.dists.iter()).map(|(&id, &d)| (d, id)));
        } else {
            out.extend(
                pool.iter()
                    .take(take)
                    .map(|&(_, id)| (self.graph.vectors.distance(query, id), id)),
            );
        }
        out.sort_by(crate::anns::heap::dist_cmp);
        out.truncate(k);
        out
    }

    /// The candidate pools for a batch of queries (pre-rerank) — feeds the
    /// PJRT batch-rerank path in the serving coordinator. Honors
    /// `refine.quantized_primary` exactly like [`Self::search_with_dists`]:
    /// when the knob is off the pool comes from the full-precision HNSW
    /// search (with `k = ef` so the whole beam pool survives — `search`
    /// truncates to `k`), so an exact rerank of these candidates reproduces
    /// `search_with_dists` at both points of the action space.
    pub fn candidates_for_rerank(&self, query: &[f32], k: usize, ef: usize) -> Vec<u32> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        let live = Admit::live_only(self.tombstone_ref());
        let pool = if self.config.refine.quantized_primary {
            self.quantized_beam(query, k, ef, &mut ctx, live)
        } else {
            search_admit(
                &self.graph,
                &self.config.search,
                &mut ctx,
                query,
                ef.max(k),
                ef,
                live,
            )
        };
        let take = self.config.refine.rerank_count(k, ef).min(pool.len());
        pool.into_iter().take(take).map(|(_, i)| i).collect()
    }
}

/// SQ8 scorer for the shared beam: distances come from the int8 code
/// rows, adjacency honors the §6.3 precomputed-metadata knob, and the
/// prefetch hooks carry the §6.3 adaptive-lookahead schedule (code-row
/// prefetch on the sequential path, kernel-pipelined lookahead on the
/// batched path). No warmup — the quantized path never had one: code rows
/// are small enough that the sliding lookahead alone covers the latency.
struct QuantScorer<'a> {
    quant: &'a QuantizedStore,
    graph: &'a HnswGraph,
    qcode: &'a [i8],
    metric: Metric,
    /// Lookahead depth for the one-to-many i8 kernel (edge-batch path).
    batch_lookahead: usize,
    /// Lookahead distance for the sequential scan (§6.3 `refine.lookahead`).
    seq_lookahead: usize,
    adaptive_prefetch: bool,
    precomputed_metadata: bool,
    locality: i32,
}

impl BeamScorer for QuantScorer<'_> {
    fn score(&self, id: u32) -> f32 {
        self.quant.distance(self.metric, self.qcode, id as usize)
    }

    fn score_batch(&self, ids: &[u32], out: &mut Vec<f32>) {
        // One one-to-many i8 kernel call per gathered batch — prefetch of
        // code row `i + lookahead` is pipelined inside the kernel while row
        // `i` is evaluated. Distances are exactly equal to the per-pair
        // path (i32 accumulation), so batching never changes results.
        self.quant.distance_batch_with(
            self.metric,
            self.qcode,
            ids,
            self.batch_lookahead,
            self.locality,
            out,
        );
    }

    fn neighbors(&self, u: u32) -> &[u32] {
        // §6.3 precomputed metadata vs sentinel scan.
        if self.precomputed_metadata {
            self.graph.neighbors0_meta(u)
        } else {
            self.graph.neighbors0_scan(u)
        }
    }

    fn warmup(&self, _neighbors: &[u32]) {}

    fn lookahead(&self, neighbors: &[u32], j: usize) {
        // §6.3 adaptive lookahead prefetch over future edges.
        if self.adaptive_prefetch {
            let ahead = j + self.seq_lookahead;
            if ahead < neighbors.len() {
                prefetch_code(self.quant.code(neighbors[ahead] as usize), self.locality);
            }
        }
    }
}

/// PQ ADC scorer for the shared beam — the fast-scan sibling of
/// [`QuantScorer`]: distances come from u8 lookup tables over the packed
/// 4-bit rows, adjacency and prefetch knobs behave identically. Batch
/// scoring is bitwise identical to per-pair (pure integer accumulation +
/// one shared f32 decode), so the edge-batch knob stays a speed dial here
/// too.
struct PqScorer<'a> {
    pq: &'a PqStore,
    graph: &'a HnswGraph,
    lut: &'a PqLut,
    /// Lookahead depth for the one-to-many ADC gather (edge-batch path).
    batch_lookahead: usize,
    /// Lookahead distance for the sequential scan (§6.3 `refine.lookahead`).
    seq_lookahead: usize,
    adaptive_prefetch: bool,
    precomputed_metadata: bool,
    locality: i32,
}

impl BeamScorer for PqScorer<'_> {
    fn score(&self, id: u32) -> f32 {
        self.pq.distance(self.lut, id as usize)
    }

    fn score_batch(&self, ids: &[u32], out: &mut Vec<f32>) {
        self.pq
            .distance_batch_with(self.lut, ids, self.batch_lookahead, self.locality, out);
    }

    fn neighbors(&self, u: u32) -> &[u32] {
        if self.precomputed_metadata {
            self.graph.neighbors0_meta(u)
        } else {
            self.graph.neighbors0_scan(u)
        }
    }

    fn warmup(&self, _neighbors: &[u32]) {}

    fn lookahead(&self, neighbors: &[u32], j: usize) {
        if self.adaptive_prefetch {
            let ahead = j + self.seq_lookahead;
            if ahead < neighbors.len() {
                let row = self.pq.code(neighbors[ahead] as usize);
                crate::distance::prefetch_ptr(row.as_ptr(), self.locality);
            }
        }
    }
}

#[inline]
fn prefetch_code(code: &[i8], locality: i32) {
    // Hint the raw byte address — cache lines are typeless. The previous
    // version reinterpreted the codes as `&[f32]` with a fudged length,
    // which constructed an out-of-bounds slice whenever `dim < 4` (UB even
    // though prefetch never dereferences); `prefetch_ptr` takes the pointer
    // directly, valid for every dim.
    crate::distance::prefetch_ptr(code.as_ptr().cast(), locality);
}

impl AnnIndex for GlassIndex {
    fn name(&self) -> String {
        self.label.clone()
    }

    /// Search returning `(exact_dist, id)` nearest-first.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        self.search_one(query, k, ef, &mut ctx, None)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One pooled context drives the whole batch (quantized beam +
        // exact rerank both reset it per query), so the batch path is
        // bitwise identical to per-query `search_with_dists`.
        let mut ctx = self.scratch.checkout(self.graph.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, None))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        self.search_one(query, k, ef, &mut ctx, filter)
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, filter))
            .collect()
    }

    fn filtered_fallback_threshold(&self) -> usize {
        self.filtered_fallback
    }

    fn len(&self) -> usize {
        self.graph.len()
    }

    fn memory_bytes(&self) -> usize {
        self.graph.memory_bytes()
            + self.quant.bytes()
            + self.pq.as_ref().map_or(0, |p| p.bytes())
    }
}

impl MutableAnnIndex for GlassIndex {
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        // Shared HNSW insertion body; the slot hook keeps the SQ8 (and,
        // when enabled, PQ) code rows in lockstep with the vector rows —
        // both encoders are frozen after training.
        let quant = &mut self.quant;
        let pq = &mut self.pq;
        crate::anns::hnsw::insert_point(
            &mut self.graph,
            &self.config.construction,
            &self.scratch,
            &mut self.deleted,
            &mut self.free,
            &mut self.rng,
            vec,
            |id, recycled| {
                if recycled {
                    quant.reencode(id as usize, vec);
                } else {
                    quant.append(vec);
                }
                if let Some(p) = pq {
                    if recycled {
                        p.reencode(id as usize, vec);
                    } else {
                        p.append(vec);
                    }
                }
            },
        )
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        self.deleted.delete(id)
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        Ok(crate::anns::hnsw::consolidate_graph(
            &mut self.graph,
            &self.deleted,
            &mut self.free,
        ))
    }

    fn live_count(&self) -> usize {
        self.graph.len() - self.deleted.count()
    }

    fn deleted_count(&self) -> usize {
        self.deleted.count() - self.free.len()
    }

    fn is_deleted(&self, id: u32) -> bool {
        self.deleted.contains(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn dataset() -> crate::dataset::Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1500, 50, 21);
        ds.compute_ground_truth(10);
        ds
    }

    fn recall(idx: &GlassIndex, ds: &crate::dataset::Dataset, ef: usize) -> f64 {
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 10, ef);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        acc / ds.n_queries() as f64
    }

    #[test]
    fn glass_baseline_reaches_high_recall() {
        let ds = dataset();
        let idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let r = recall(&idx, &ds, 128);
        assert!(r > 0.85, "glass recall@10 ef=128: {r}");
    }

    #[test]
    fn crinn_full_matches_or_beats_baseline_recall() {
        let ds = dataset();
        let base = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let crinn = GlassIndex::build(VectorSet::from_dataset(&ds), VariantConfig::crinn_full(), 3);
        let rb = recall(&base, &ds, 96);
        let rc = recall(&crinn, &ds, 96);
        assert!(rc > rb - 0.05, "baseline {rb} vs crinn {rc}");
    }

    #[test]
    fn rerank_improves_over_raw_quantized_order() {
        let ds = dataset();
        let mut cfg = VariantConfig::glass_baseline();
        cfg.refine.rerank_frac = 2.0; // deep rerank
        let idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
        let deep = recall(&idx, &ds, 64);
        let mut shallow_cfg = VariantConfig::glass_baseline();
        shallow_cfg.refine.rerank_frac = 0.2;
        let mut idx2 = GlassIndex::build(VectorSet::from_dataset(&ds), shallow_cfg, 3);
        idx2.set_runtime_knobs(&idx2.config.clone());
        let shallow = recall(&idx2, &ds, 64);
        assert!(deep >= shallow, "deep {deep} shallow {shallow}");
    }

    #[test]
    fn runtime_knob_swap_changes_behavior_without_rebuild() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let before = idx.search(ds.query_vec(0), 10, 64);
        let mut cfg = idx.config.clone();
        cfg.refine.quantized_primary = false;
        idx.set_runtime_knobs(&cfg);
        let after = idx.search(ds.query_vec(0), 10, 64);
        // Same graph, different pipeline; both decent answers.
        assert_eq!(before.len(), after.len());
    }

    #[test]
    fn adaptive_prefetch_is_result_invariant() {
        // The §6.3 prefetch knob now routes the rerank gather through the
        // one-to-many SIMD kernel; it must stay a pure speed dial.
        let ds = dataset();
        let mut cfg = VariantConfig::glass_baseline();
        cfg.refine.adaptive_prefetch = false;
        let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg.clone(), 3);
        let before: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        cfg.refine.adaptive_prefetch = true;
        idx.set_runtime_knobs(&cfg);
        let after: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        assert_eq!(before, after);
    }

    #[test]
    fn candidates_for_rerank_bounded() {
        let ds = dataset();
        let idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let c = idx.candidates_for_rerank(ds.query_vec(0), 10, 64);
        assert!(!c.is_empty());
        assert!(c.len() <= 64);
    }

    /// One dataset per metric for the cross-metric quantized-path tests.
    fn metric_datasets() -> Vec<crate::dataset::Dataset> {
        let mut out = Vec::new();
        let sp = synth::spec("demo-64").unwrap();
        let mut l2 = synth::generate_counts(sp, 1200, 30, 31);
        l2.compute_ground_truth(10);
        out.push(l2);
        let sp = synth::spec("glove-25-angular").unwrap();
        let mut ang = synth::generate_counts(sp, 1200, 30, 32);
        ang.compute_ground_truth(10);
        out.push(ang);
        // No Ip preset: reuse the demo manifold under the Ip convention.
        let sp = synth::spec("demo-64").unwrap();
        let mut ip = synth::generate_counts(sp, 1200, 30, 33);
        ip.metric = crate::distance::Metric::Ip;
        ip.compute_ground_truth(10);
        out.push(ip);
        out
    }

    #[test]
    fn edge_batch_rewrite_is_result_identical_all_metrics() {
        // Acceptance criterion: the one-batch-call-per-gathered-batch
        // quantized beam must return exactly what the per-pair loop
        // returns — ids AND distances — for L2, Angular, and Ip. The i8
        // kernels accumulate in i32, so this is exact, not approximate.
        for ds in metric_datasets() {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.search.edge_batch = false;
            let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg.clone(), 3);
            let per_pair: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            cfg.search.edge_batch = true;
            cfg.search.batch_size = 8;
            idx.set_runtime_knobs(&cfg);
            let batched: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            assert_eq!(per_pair, batched, "metric {:?}", ds.metric);
            // And with the adaptive-prefetch schedule wired into the batch
            // kernel — prefetch must stay a pure speed dial.
            cfg.refine.adaptive_prefetch = true;
            cfg.search.prefetch_depth = 6;
            idx.set_runtime_knobs(&cfg);
            let prefetched: Vec<_> = (0..ds.n_queries())
                .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
                .collect();
            assert_eq!(per_pair, prefetched, "prefetch changed results ({:?})", ds.metric);
        }
    }

    #[test]
    fn quantized_beam_reaches_recall_on_angular_and_ip() {
        // The quantized path was only ever recall-tested under L2; assert
        // the Angular/Ip mappings also drive the beam to useful recall and
        // stay consistent with the full-precision pipeline.
        for ds in metric_datasets() {
            let idx = GlassIndex::build(
                VectorSet::from_dataset(&ds),
                VariantConfig::glass_baseline(),
                3,
            );
            let r = recall(&idx, &ds, 128);
            // Absolute floor for the metrics HNSW is strong on; MIPS has no
            // triangle inequality, so Ip only gets the parity bound below.
            if ds.metric != crate::distance::Metric::Ip {
                assert!(r > 0.8, "quantized recall@10 under {:?}: {r}", ds.metric);
            }
            let mut full = VariantConfig::glass_baseline();
            full.refine.quantized_primary = false;
            let fidx = GlassIndex::build(VectorSet::from_dataset(&ds), full, 3);
            let rf = recall(&fidx, &ds, 128);
            assert!(
                r > rf - 0.1,
                "quantized path lost too much recall under {:?}: {r} vs {rf}",
                ds.metric
            );
        }
    }

    #[test]
    fn prefetch_survives_tiny_dims() {
        // Regression for the `prefetch_code` UB: dims 1..3 quantize to code
        // rows shorter than one f32; the old slice reinterpretation built
        // an out-of-bounds `&[f32]` for them. Run the full quantized
        // pipeline with every prefetch knob on.
        for dim in 1usize..=3 {
            let n = 300;
            let mut rng = crate::util::rng::Rng::new(dim as u64);
            let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
            let vs = VectorSet::new(data.clone(), dim, crate::distance::Metric::L2);
            let mut cfg = VariantConfig::glass_baseline();
            cfg.refine.adaptive_prefetch = true;
            cfg.refine.lookahead = 4;
            cfg.search.prefetch_depth = 8;
            let mut idx = GlassIndex::build(vs, cfg.clone(), 5);
            // Both the sequential-scan and edge-batch beams touch the
            // prefetch paths.
            for edge_batch in [false, true] {
                let mut c = cfg.clone();
                c.search.edge_batch = edge_batch;
                idx.set_runtime_knobs(&c);
                let out = idx.search(&data[0..dim], 5, 32);
                assert!(!out.is_empty(), "dim={dim} edge_batch={edge_batch}");
            }
        }
    }

    #[test]
    fn mutation_quantized_beam_never_surfaces_tombstones() {
        // Delete the full top-10 of a query: the quantized pipeline (beam
        // + rerank) must return only live ids, for both the edge-batch and
        // sequential beam shapes, and for the full-precision fallback.
        let ds = dataset();
        for (edge_batch, quantized) in [(false, true), (true, true), (false, false)] {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.search.edge_batch = edge_batch;
            cfg.refine.quantized_primary = quantized;
            let mut idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
            let q = ds.query_vec(0);
            let doomed = idx.search(q, 10, 128);
            for &id in &doomed {
                idx.delete(id).unwrap();
            }
            let batched: Vec<u32> = idx
                .search_batch(&[q], 10, 128)
                .pop()
                .unwrap()
                .into_iter()
                .map(|(_, i)| i)
                .collect();
            for out in [idx.search(q, 10, 128), batched] {
                assert_eq!(out.len(), 10);
                for id in out {
                    assert!(
                        !doomed.contains(&id),
                        "tombstoned id {id} surfaced \
                         (edge_batch={edge_batch} quantized={quantized})"
                    );
                }
            }
        }
    }

    #[test]
    fn mutation_insert_consolidate_recycle_glass() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let n0 = idx.len();
        let v = ds.query_vec(1).to_vec();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id as usize, n0);
        assert_eq!(idx.quant.len(), n0 + 1, "code row must be appended");
        // The inserted point wins its own query through the quantized
        // pipeline (self-distance quantizes to exactly 0).
        assert_eq!(idx.search(&v, 1, 64), vec![id]);
        idx.delete(id).unwrap();
        assert_eq!(idx.consolidate().unwrap(), 1);
        idx.graph.validate().unwrap();
        let id2 = idx.insert(&v).unwrap();
        assert_eq!(id2, id, "freed slot must be recycled");
        assert_eq!(idx.quant.len(), n0 + 1, "recycle must not grow the codes");
        assert_eq!(idx.search(&v, 1, 64), vec![id2]);
    }

    #[test]
    fn filtered_glass_respects_filter_across_pipeline_shapes() {
        // Every pipeline shape (quantized/full-precision × sequential/
        // edge-batch beams) must honor the allow-list, and `filter = None`
        // must stay bitwise identical to the unfiltered entry points.
        let ds = dataset();
        let n = ds.n_base();
        let filter = FilterBitset::from_predicate(n, |id| id % 3 == 0);
        for (edge_batch, quantized) in [(false, true), (true, true), (false, false)] {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.search.edge_batch = edge_batch;
            cfg.refine.quantized_primary = quantized;
            let idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
            for qi in 0..ds.n_queries().min(8) {
                let q = ds.query_vec(qi);
                assert_eq!(
                    idx.search_filtered_with_dists(q, 10, 128, None),
                    idx.search_with_dists(q, 10, 128),
                    "filter=None diverged (edge_batch={edge_batch} quantized={quantized})"
                );
                let got = idx.search_filtered_with_dists(q, 10, 128, Some(&filter));
                assert_eq!(got.len(), 10);
                assert!(
                    got.iter().all(|&(_, id)| id % 3 == 0),
                    "non-matching id surfaced (edge_batch={edge_batch} quantized={quantized})"
                );
            }
            // Batch == per-query under a filter.
            let queries: Vec<&[f32]> = (0..ds.n_queries().min(8)).map(|qi| ds.query_vec(qi)).collect();
            let batched = idx.search_filtered_batch(&queries, 10, 128, Some(&filter));
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(
                    batched[qi],
                    idx.search_filtered_with_dists(q, 10, 128, Some(&filter)),
                    "filtered batch diverged at query {qi}"
                );
            }
        }
    }

    #[test]
    fn filtered_glass_fallback_is_exact_and_skips_tombstones() {
        // A filter below the fallback threshold routes to the exact scan:
        // results must equal the filtered ground truth, and deleting a
        // matching id must drop it from the scan.
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        let n = ds.n_base();
        let filter = FilterBitset::from_predicate(n, |id| id % 100 == 0); // 15 ids
        assert!(filter.count() <= idx.filtered_fallback_threshold());
        let q = ds.query_vec(0);
        let mut ids = Vec::new();
        let mut dists = Vec::new();
        let want = crate::dataset::gt::topk_pairs_for_query_filtered(
            &ds.base,
            q,
            ds.dim,
            ds.metric,
            10,
            &mut ids,
            &mut dists,
            |i| filter.matches(i),
        );
        assert_eq!(idx.search_filtered_with_dists(q, 10, 128, Some(&filter)), want);
        let victim = want[0].1;
        idx.delete(victim).unwrap();
        let after = idx.search_filtered_with_dists(q, 10, 128, Some(&filter));
        assert!(after.iter().all(|&(_, id)| id != victim));
        // Raising the threshold to 0 sends the same filter through the
        // beam instead; still no non-matching or dead id.
        idx.set_filtered_fallback(0);
        let beamed = idx.search_filtered_with_dists(q, 10, 256, Some(&filter));
        assert!(beamed.iter().all(|&(_, id)| id % 100 == 0 && id != victim));
    }

    #[test]
    fn candidates_for_rerank_honors_quantized_primary() {
        // Pool parity at both points of the action space: reranking the
        // returned candidates in full precision must reproduce
        // `search_with_dists` exactly, whether the pool came from the
        // quantized beam or the full-precision fallback.
        let ds = dataset();
        for quantized in [true, false] {
            let mut cfg = VariantConfig::glass_baseline();
            cfg.refine.quantized_primary = quantized;
            let idx = GlassIndex::build(VectorSet::from_dataset(&ds), cfg, 3);
            for qi in 0..ds.n_queries().min(10) {
                let q = ds.query_vec(qi);
                let want: Vec<u32> = idx
                    .search_with_dists(q, 10, 64)
                    .into_iter()
                    .map(|(_, i)| i)
                    .collect();
                let cands = idx.candidates_for_rerank(q, 10, 64);
                let mut reranked: Vec<(f32, u32)> = cands
                    .iter()
                    .map(|&id| (idx.graph.vectors.distance(q, id), id))
                    .collect();
                reranked.sort_by(crate::anns::heap::dist_cmp);
                reranked.truncate(10);
                let got: Vec<u32> = reranked.into_iter().map(|(_, i)| i).collect();
                assert_eq!(got, want, "quantized_primary={quantized} query {qi}");
            }
        }
    }

    #[test]
    fn glass_pq_beam_reaches_recall_and_stays_schedule_invariant() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        idx.enable_pq(16, 3);
        let r = recall(&idx, &ds, 128);
        // 4-bit codes rank coarser than SQ8, but the exact rerank must
        // still carry the pipeline to useful recall.
        assert!(r > 0.75, "glass-pq recall@10 ef=128: {r}");
        // Edge-batch and prefetch knobs stay pure speed dials on the PQ
        // path (integer ADC sums + one shared decode).
        let mut cfg = idx.config.clone();
        cfg.search.edge_batch = false;
        idx.set_runtime_knobs(&cfg);
        let per_pair: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        cfg.search.edge_batch = true;
        cfg.search.batch_size = 8;
        cfg.refine.adaptive_prefetch = true;
        cfg.search.prefetch_depth = 6;
        idx.set_runtime_knobs(&cfg);
        let batched: Vec<_> = (0..ds.n_queries())
            .map(|qi| idx.search_with_dists(ds.query_vec(qi), 10, 64))
            .collect();
        assert_eq!(per_pair, batched, "pq beam changed under batch/prefetch knobs");
    }

    #[test]
    fn glass_pq_insert_keeps_codes_in_lockstep() {
        let ds = dataset();
        let mut idx = GlassIndex::build(
            VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            3,
        );
        idx.enable_pq(16, 3);
        let n0 = idx.len();
        let v = ds.query_vec(1).to_vec();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id as usize, n0);
        assert_eq!(idx.pq_store().unwrap().len(), n0 + 1, "pq row must be appended");
        // The exact duplicate wins its own query through the PQ beam +
        // exact rerank.
        assert_eq!(idx.search(&v, 1, 64), vec![id]);
        idx.delete(id).unwrap();
        assert_eq!(idx.consolidate().unwrap(), 1);
        let id2 = idx.insert(&v).unwrap();
        assert_eq!(id2, id, "freed slot must be recycled");
        assert_eq!(idx.pq_store().unwrap().len(), n0 + 1, "recycle must not grow pq codes");
        assert_eq!(idx.search(&v, 1, 64), vec![id2]);
    }
}
