//! Neighbor selection heuristic (§2.1's "heuristic pruning strategy").
//!
//! Given candidates sorted nearest-first, keep a candidate only if it is
//! closer to the query/base point than to every already-kept neighbor —
//! this trades pure proximity for angular diversity, preserving the
//! small-world property. Identical logic serves (a) choosing the M links
//! of a new node and (b) re-pruning a node whose adjacency overflowed.

use crate::anns::VectorSet;

/// Select up to `m` diverse neighbors from `candidates` (sorted ascending
/// by distance to the anchor). Returns kept ids, still nearest-first.
///
/// `alpha` > 1 relaxes the diversity test (Vamana's RobustPrune uses the
/// same shape with alpha ≈ 1.2; HNSW uses 1.0).
pub fn select_heuristic(
    vs: &VectorSet,
    candidates: &[(f32, u32)],
    m: usize,
    alpha: f32,
    keep_pruned: bool,
) -> Vec<u32> {
    if candidates.len() <= m {
        return candidates.iter().map(|&(_, i)| i).collect();
    }
    let mut kept: Vec<(f32, u32)> = Vec::with_capacity(m);
    let mut pruned: Vec<(f32, u32)> = Vec::new();
    for &(dist, cand) in candidates {
        if kept.len() >= m {
            break;
        }
        let cv = vs.vec(cand);
        // Diverse iff closer to the anchor than (alpha-scaled) to any kept.
        let diverse = kept
            .iter()
            .all(|&(_, k)| vs.metric.distance(cv, vs.vec(k)) * alpha > dist);
        if diverse {
            kept.push((dist, cand));
        } else if keep_pruned {
            pruned.push((dist, cand));
        }
    }
    // Optionally backfill with the nearest pruned candidates (keepPruned
    // connections from the HNSW paper — maintains connectivity).
    if keep_pruned {
        for &(_, c) in pruned.iter() {
            if kept.len() >= m {
                break;
            }
            kept.push((0.0, c));
        }
    }
    kept.into_iter().map(|(_, i)| i).collect()
}

/// Re-prune an overflowing adjacency list of `node`: gather current
/// neighbors + the new arrival, sort by distance to `node`, re-select.
pub fn reprune(
    vs: &VectorSet,
    node: u32,
    current: &[u32],
    arrival: u32,
    m: usize,
    alpha: f32,
) -> Vec<u32> {
    let nv = vs.vec(node);
    let mut cands: Vec<(f32, u32)> = current
        .iter()
        .chain(std::iter::once(&arrival))
        .map(|&c| (vs.metric.distance(nv, vs.vec(c)), c))
        .collect();
    cands.sort_by(crate::anns::heap::dist_cmp);
    cands.dedup_by_key(|x| x.1);
    select_heuristic(vs, &cands, m, alpha, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    /// Points on a line: diversity heuristic must not keep redundant
    /// same-direction neighbors when a closer one exists.
    #[test]
    fn prefers_diverse_directions() {
        // Anchor at origin; candidates: two clustered right, one left.
        let data = vec![
            0.0, 0.0, // 0 anchor
            1.0, 0.0, // 1 right near
            1.2, 0.0, // 2 right (redundant with 1)
            -1.5, 0.0, // 3 left (diverse)
        ];
        let vs = VectorSet::new(data, 2, Metric::L2);
        let anchor = vs.vec(0);
        let mut cands: Vec<(f32, u32)> = [1u32, 2, 3]
            .iter()
            .map(|&i| (vs.metric.distance(anchor, vs.vec(i)), i))
            .collect();
        cands.sort_by(crate::anns::heap::dist_cmp);
        let kept = select_heuristic(&vs, &cands, 2, 1.0, false);
        assert_eq!(kept, vec![1, 3]); // skips 2: closer to 1 than to anchor
    }

    #[test]
    fn small_candidate_sets_pass_through() {
        let data = vec![0.0, 0.0, 1.0, 0.0];
        let vs = VectorSet::new(data, 2, Metric::L2);
        let kept = select_heuristic(&vs, &[(1.0, 1)], 4, 1.0, false);
        assert_eq!(kept, vec![1]);
    }

    #[test]
    fn keep_pruned_backfills() {
        let data = vec![
            0.0, 0.0, // anchor
            1.0, 0.0, 1.1, 0.0, 1.2, 0.0, 1.3, 0.0, // cluster
        ];
        let vs = VectorSet::new(data, 2, Metric::L2);
        let anchor = vs.vec(0);
        let mut cands: Vec<(f32, u32)> = [1u32, 2, 3, 4]
            .iter()
            .map(|&i| (vs.metric.distance(anchor, vs.vec(i)), i))
            .collect();
        cands.sort_by(crate::anns::heap::dist_cmp);
        // Heuristic path (candidates > m): only the cluster head survives
        // without backfill; keep_pruned tops the list back up to m.
        let no_fill = select_heuristic(&vs, &cands, 3, 1.0, false);
        assert_eq!(no_fill, vec![1]);
        let filled = select_heuristic(&vs, &cands, 3, 1.0, true);
        assert_eq!(filled.len(), 3);
    }

    #[test]
    fn reprune_bounds_degree_and_dedups() {
        let data: Vec<f32> = (0..12).flat_map(|i| vec![i as f32, 0.0]).collect();
        let vs = VectorSet::new(data, 2, Metric::L2);
        let current: Vec<u32> = (1..8).collect();
        let out = reprune(&vs, 0, &current, 1, 4, 1.0);
        assert!(out.len() <= 4);
        let set: std::collections::HashSet<_> = out.iter().collect();
        assert_eq!(set.len(), out.len());
    }

    #[test]
    fn alpha_relaxes_pruning() {
        let data = vec![
            0.0, 0.0, // anchor
            1.0, 0.0, 1.3, 0.1, // near-redundant pair
        ];
        let vs = VectorSet::new(data, 2, Metric::L2);
        let anchor = vs.vec(0);
        let mut cands: Vec<(f32, u32)> = [1u32, 2]
            .iter()
            .map(|&i| (vs.metric.distance(anchor, vs.vec(i)), i))
            .collect();
        cands.sort_by(crate::anns::heap::dist_cmp);
        let strict = select_heuristic(&vs, &cands, 2, 1.0, false);
        let relaxed = select_heuristic(&vs, &cands, 2, 14.0, false);
        assert!(relaxed.len() >= strict.len());
    }
}
