//! HNSW graph storage.
//!
//! Layer 0 is a flat `[n * m0]` u32 array (CSR with fixed stride) — the
//! search hot path walks it with sequential loads and optional prefetch.
//! Upper layers are sparse (`HashMap` per level): only ~n/2^l nodes exist
//! there and they're touched a handful of times per query.
//!
//! `degree0` stores the §6.3 "pre-computed edge metadata": per-node edge
//! counts maintained at build time so searches avoid scanning for the
//! `NONE` sentinel when the refinement knob enables it.

use crate::anns::VectorSet;
use std::collections::HashMap;

/// Adjacency slot sentinel.
pub const NONE: u32 = u32::MAX;

/// Multi-layer navigable small-world graph.
pub struct HnswGraph {
    pub vectors: VectorSet,
    /// Upper-layer max degree.
    pub m: usize,
    /// Layer-0 max degree (`2 * m`, §2.1).
    pub m0: usize,
    /// Level of each node (0 = base layer only).
    pub levels: Vec<u8>,
    /// Flat layer-0 adjacency `[n * m0]`, `NONE`-padded.
    pub layer0: Vec<u32>,
    /// Pre-computed layer-0 degrees (§6.3 metadata).
    pub degree0: Vec<u16>,
    /// Upper layers: `upper[l-1][node]` = neighbor list at level `l`.
    pub upper: Vec<HashMap<u32, Vec<u32>>>,
    /// Global entry point (highest-level node).
    pub entry: u32,
    pub max_level: u8,
    /// Diverse entry points (§6.1 multi-entry architecture). `entry` first,
    /// then by decreasing diversity; tiers for §6.2 slice this list.
    pub entry_points: Vec<u32>,
}

impl HnswGraph {
    pub fn new(vectors: VectorSet, m: usize) -> Self {
        let n = vectors.len();
        HnswGraph {
            vectors,
            m,
            m0: m * 2,
            levels: vec![0; n],
            layer0: vec![NONE; n * m * 2],
            degree0: vec![0; n],
            upper: Vec::new(),
            entry: 0,
            max_level: 0,
            entry_points: vec![0],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.vectors.dim
    }

    /// Full layer-0 adjacency slots of `i` (may contain NONE padding).
    #[inline]
    pub fn neighbors0_slots(&self, i: u32) -> &[u32] {
        let i = i as usize;
        &self.layer0[i * self.m0..(i + 1) * self.m0]
    }

    /// Layer-0 neighbors using the precomputed degree (no sentinel scan).
    #[inline]
    pub fn neighbors0_meta(&self, i: u32) -> &[u32] {
        let d = self.degree0[i as usize] as usize;
        &self.layer0[i as usize * self.m0..i as usize * self.m0 + d]
    }

    /// Layer-0 neighbors by scanning for the sentinel (baseline path).
    #[inline]
    pub fn neighbors0_scan(&self, i: u32) -> &[u32] {
        let slots = self.neighbors0_slots(i);
        let mut d = 0;
        while d < slots.len() && slots[d] != NONE {
            d += 1;
        }
        &slots[..d]
    }

    /// Overwrite the layer-0 neighbor list of `i`.
    pub fn set_neighbors0(&mut self, i: u32, neighbors: &[u32]) {
        debug_assert!(neighbors.len() <= self.m0);
        let start = i as usize * self.m0;
        for (s, &nb) in self.layer0[start..start + self.m0]
            .iter_mut()
            .zip(neighbors.iter().chain(std::iter::repeat(&NONE)))
        {
            *s = nb;
        }
        self.degree0[i as usize] = neighbors.len() as u16;
    }

    /// Append one layer-0 edge if a slot is free; returns false when full.
    pub fn push_neighbor0(&mut self, i: u32, nb: u32) -> bool {
        let d = self.degree0[i as usize] as usize;
        if d >= self.m0 {
            return false;
        }
        self.layer0[i as usize * self.m0 + d] = nb;
        self.degree0[i as usize] = (d + 1) as u16;
        true
    }

    /// Neighbors of `i` at `level` (>= 1).
    pub fn neighbors_upper(&self, level: u8, i: u32) -> &[u32] {
        self.upper
            .get(level as usize - 1)
            .and_then(|m| m.get(&i))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Set neighbors of `i` at `level` (>= 1), growing layers as needed.
    pub fn set_neighbors_upper(&mut self, level: u8, i: u32, neighbors: Vec<u32>) {
        let li = level as usize - 1;
        while self.upper.len() <= li {
            self.upper.push(HashMap::new());
        }
        self.upper[li].insert(i, neighbors);
    }

    /// Approximate resident memory.
    pub fn memory_bytes(&self) -> usize {
        let upper: usize = self
            .upper
            .iter()
            .map(|m| m.values().map(|v| v.len() * 4 + 16).sum::<usize>())
            .sum();
        self.vectors.data.len() * 4 + self.layer0.len() * 4 + self.degree0.len() * 2 + upper
    }

    /// Graph invariants, checked by tests and the property harness:
    /// degrees within bounds, no self-loops, ids valid, `degree0`
    /// consistent with sentinel scan.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.len() as u32;
        for i in 0..n {
            let scan = self.neighbors0_scan(i);
            let meta = self.neighbors0_meta(i);
            if scan != meta {
                return Err(format!("node {i}: degree metadata mismatch"));
            }
            if scan.len() > self.m0 {
                return Err(format!("node {i}: layer0 degree {} > m0", scan.len()));
            }
            for &nb in scan {
                if nb == i {
                    return Err(format!("node {i}: self-loop at layer 0"));
                }
                if nb >= n {
                    return Err(format!("node {i}: bad neighbor id {nb}"));
                }
            }
        }
        for (li, layer) in self.upper.iter().enumerate() {
            for (&i, nbs) in layer {
                if nbs.len() > self.m {
                    return Err(format!("node {i}@L{}: degree {} > m", li + 1, nbs.len()));
                }
                if (self.levels[i as usize] as usize) < li + 1 {
                    return Err(format!("node {i} present at L{} above its level", li + 1));
                }
                for &nb in nbs {
                    if nb == i || nb >= n {
                        return Err(format!("node {i}@L{}: bad neighbor {nb}", li + 1));
                    }
                }
            }
        }
        if n > 0 {
            if self.entry >= n {
                return Err("entry out of range".into());
            }
            if self.levels[self.entry as usize] != self.max_level {
                return Err("entry is not at max level".into());
            }
            for &ep in &self.entry_points {
                if ep >= n {
                    return Err(format!("entry point {ep} out of range"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn empty_graph(n: usize) -> HnswGraph {
        let data = vec![0f32; n * 4];
        HnswGraph::new(VectorSet::new(data, 4, Metric::L2), 4)
    }

    #[test]
    fn set_and_scan_neighbors() {
        let mut g = empty_graph(10);
        g.set_neighbors0(3, &[1, 2, 5]);
        assert_eq!(g.neighbors0_scan(3), &[1, 2, 5]);
        assert_eq!(g.neighbors0_meta(3), &[1, 2, 5]);
        assert_eq!(g.neighbors0_slots(3).len(), 8);
        g.set_neighbors0(3, &[7]);
        assert_eq!(g.neighbors0_meta(3), &[7]);
    }

    #[test]
    fn push_neighbor_respects_capacity() {
        let mut g = empty_graph(10);
        for nb in 0..8u32 {
            assert!(g.push_neighbor0(0, nb + 1));
        }
        assert!(!g.push_neighbor0(0, 9));
        assert_eq!(g.neighbors0_meta(0).len(), 8);
    }

    #[test]
    fn upper_layers_grow_on_demand() {
        let mut g = empty_graph(10);
        g.set_neighbors_upper(3, 2, vec![1]);
        assert_eq!(g.upper.len(), 3);
        assert_eq!(g.neighbors_upper(3, 2), &[1]);
        assert_eq!(g.neighbors_upper(2, 2), &[] as &[u32]);
        assert_eq!(g.neighbors_upper(1, 9), &[] as &[u32]);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = empty_graph(5);
        assert!(g.validate().is_ok());
        // Self-loop.
        g.set_neighbors0(2, &[2]);
        assert!(g.validate().is_err());
        g.set_neighbors0(2, &[]);
        // Metadata mismatch.
        g.layer0[0] = 1;
        assert!(g.validate().is_err());
    }
}
