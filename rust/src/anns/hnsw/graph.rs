//! HNSW graph storage.
//!
//! Layer 0 is a flat `[n * m0]` u32 array (CSR with fixed stride) — the
//! search hot path walks it with sequential loads and optional prefetch.
//! It lives behind a [`Segment`], so a snapshot-served graph reads its
//! adjacency straight out of an mmapped section (zero-copy) and promotes
//! to heap only when the first online insert mutates it. Upper layers
//! are sparse (`HashMap` per level): only ~n/2^l nodes exist there and
//! they're touched a handful of times per query.
//!
//! `degree0` stores the §6.3 "pre-computed edge metadata": per-node edge
//! counts maintained at build time so searches avoid scanning for the
//! `NONE` sentinel when the refinement knob enables it.

use crate::anns::store::region::Segment;
use crate::anns::VectorSet;
use std::collections::HashMap;

/// Adjacency slot sentinel.
pub const NONE: u32 = u32::MAX;

/// Multi-layer navigable small-world graph.
pub struct HnswGraph {
    pub vectors: VectorSet,
    /// Upper-layer max degree.
    pub m: usize,
    /// Layer-0 max degree (`2 * m`, §2.1).
    pub m0: usize,
    /// Level of each node (0 = base layer only).
    pub levels: Vec<u8>,
    /// Flat layer-0 adjacency `[n * m0]`, `NONE`-padded — owned when
    /// built in memory, a mapped section view when snapshot-served.
    pub layer0: Segment<u32>,
    /// Pre-computed layer-0 degrees (§6.3 metadata).
    pub degree0: Vec<u16>,
    /// Upper layers: `upper[l-1][node]` = neighbor list at level `l`.
    pub upper: Vec<HashMap<u32, Vec<u32>>>,
    /// Global entry point (highest-level node).
    pub entry: u32,
    pub max_level: u8,
    /// Diverse entry points (§6.1 multi-entry architecture). `entry` first,
    /// then by decreasing diversity; tiers for §6.2 slice this list.
    pub entry_points: Vec<u32>,
}

impl HnswGraph {
    pub fn new(vectors: VectorSet, m: usize) -> Self {
        let n = vectors.len();
        HnswGraph {
            vectors,
            m,
            m0: m * 2,
            levels: vec![0; n],
            layer0: vec![NONE; n * m * 2].into(),
            degree0: vec![0; n],
            upper: Vec::new(),
            entry: 0,
            max_level: 0,
            entry_points: vec![0],
        }
    }

    /// Reassemble a graph from persisted storage parts (the paged-snapshot
    /// loader) — `layer0` may be a zero-copy view into a mapped section.
    /// Cross-field shape is validated here; edge-level invariants (degree
    /// metadata, neighbor ids, entry level) are the caller's
    /// [`HnswGraph::validate`] pass. Upper layers start empty; the caller
    /// fills them via [`HnswGraph::set_neighbors_upper`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn from_storage(
        vectors: VectorSet,
        m: usize,
        levels: Vec<u8>,
        layer0: Segment<u32>,
        degree0: Vec<u16>,
        entry: u32,
        max_level: u8,
        entry_points: Vec<u32>,
    ) -> Result<HnswGraph, String> {
        let n = vectors.len();
        if m == 0 {
            return Err("graph degree m is 0".to_string());
        }
        if levels.len() != n {
            return Err(format!("levels column has {} rows, expected {n}", levels.len()));
        }
        if degree0.len() != n {
            return Err(format!("degree column has {} rows, expected {n}", degree0.len()));
        }
        if layer0.len() != n * m * 2 {
            return Err(format!(
                "layer0 adjacency has {} slots, expected {}",
                layer0.len(),
                n * m * 2
            ));
        }
        if n > 0 && entry as usize >= n {
            return Err(format!("entry point {entry} out of range for {n} points"));
        }
        if n > 0 && entry_points.is_empty() {
            return Err("entry point list is empty".to_string());
        }
        Ok(HnswGraph {
            vectors,
            m,
            m0: m * 2,
            levels,
            layer0,
            degree0,
            upper: Vec::new(),
            entry,
            max_level,
            entry_points,
        })
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.vectors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.vectors.dim
    }

    /// Full layer-0 adjacency slots of `i` (may contain NONE padding).
    #[inline]
    pub fn neighbors0_slots(&self, i: u32) -> &[u32] {
        let i = i as usize;
        &self.layer0[i * self.m0..(i + 1) * self.m0]
    }

    /// Layer-0 neighbors using the precomputed degree (no sentinel scan).
    #[inline]
    pub fn neighbors0_meta(&self, i: u32) -> &[u32] {
        let d = self.degree0[i as usize] as usize;
        &self.layer0[i as usize * self.m0..i as usize * self.m0 + d]
    }

    /// Layer-0 neighbors by scanning for the sentinel (baseline path).
    #[inline]
    pub fn neighbors0_scan(&self, i: u32) -> &[u32] {
        let slots = self.neighbors0_slots(i);
        let mut d = 0;
        while d < slots.len() && slots[d] != NONE {
            d += 1;
        }
        &slots[..d]
    }

    /// Overwrite the layer-0 neighbor list of `i`.
    pub fn set_neighbors0(&mut self, i: u32, neighbors: &[u32]) {
        debug_assert!(neighbors.len() <= self.m0);
        let start = i as usize * self.m0;
        let end = start + self.m0;
        for (s, &nb) in self.layer0.to_mut()[start..end]
            .iter_mut()
            .zip(neighbors.iter().chain(std::iter::repeat(&NONE)))
        {
            *s = nb;
        }
        self.degree0[i as usize] = neighbors.len() as u16;
    }

    /// Append one layer-0 edge if a slot is free; returns false when full.
    pub fn push_neighbor0(&mut self, i: u32, nb: u32) -> bool {
        let d = self.degree0[i as usize] as usize;
        if d >= self.m0 {
            return false;
        }
        let at = i as usize * self.m0 + d;
        self.layer0.to_mut()[at] = nb;
        self.degree0[i as usize] = (d + 1) as u16;
        true
    }

    /// Neighbors of `i` at `level` (>= 1).
    pub fn neighbors_upper(&self, level: u8, i: u32) -> &[u32] {
        self.upper
            .get(level as usize - 1)
            .and_then(|m| m.get(&i))
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Set neighbors of `i` at `level` (>= 1), growing layers as needed.
    pub fn set_neighbors_upper(&mut self, level: u8, i: u32, neighbors: Vec<u32>) {
        let li = level as usize - 1;
        while self.upper.len() <= li {
            self.upper.push(HashMap::new());
        }
        self.upper[li].insert(i, neighbors);
    }

    /// Append one fresh, unlinked slot holding `v` (online insert).
    /// Returns the new id; the caller links it and sets its level.
    pub fn append_slot(&mut self, v: &[f32]) -> u32 {
        debug_assert_eq!(v.len(), self.vectors.dim);
        let id = self.len() as u32;
        self.vectors.data.extend_from_slice(v);
        self.levels.push(0);
        let m0 = self.m0;
        self.layer0.to_mut().extend(std::iter::repeat(NONE).take(m0));
        self.degree0.push(0);
        id
    }

    /// Recycle a free slot for `v` (online insert after consolidation):
    /// overwrite the vector row and drop every trace of the previous
    /// occupant (adjacency, level, upper-layer entries). Consolidation
    /// already removed all *incoming* edges, so after this the slot is a
    /// fresh unlinked node.
    pub fn reset_slot(&mut self, id: u32, v: &[f32]) {
        debug_assert_eq!(v.len(), self.vectors.dim);
        let i = id as usize;
        self.vectors.data[i * self.vectors.dim..(i + 1) * self.vectors.dim].copy_from_slice(v);
        self.set_neighbors0(id, &[]);
        self.levels[i] = 0;
        for layer in &mut self.upper {
            layer.remove(&id);
        }
    }

    /// Physically drop `pending` nodes (FreshDiskANN-style consolidation):
    ///
    /// 1. every live node that pointed at a dropped node repairs its
    ///    adjacency by **neighbor-of-neighbor reconnection** — candidates
    ///    are its surviving neighbors plus the live neighbors of each
    ///    dropped neighbor, re-selected with the diversity heuristic under
    ///    the layer's degree bound;
    /// 2. the dropped nodes' own adjacency, upper-layer entries and levels
    ///    are cleared (the slots become free and unreachable);
    /// 3. `entry`/`max_level`/`entry_points` are re-anchored on live nodes
    ///    (`is_live` decides liveness — it must also reject previously
    ///    freed slots, not just `pending`).
    ///
    /// Deterministic: repairs depend only on each node's own adjacency and
    /// vector data, never on map iteration order. With `pending` empty
    /// this is a no-op.
    pub fn drop_nodes(&mut self, pending: &[u32], is_live: impl Fn(u32) -> bool) {
        if pending.is_empty() {
            return;
        }
        let n = self.len();
        let mut dropped = vec![false; n];
        for &t in pending {
            dropped[t as usize] = true;
        }

        // --- Layer-0 repair pass over live nodes.
        for u in 0..n as u32 {
            if !is_live(u) {
                continue;
            }
            let nbs = self.neighbors0_meta(u);
            if !nbs.iter().any(|&nb| dropped[nb as usize]) {
                continue;
            }
            let mut cands = self.repair_candidates(u, nbs, &dropped, &is_live, 0);
            cands.truncate(self.m0.max(1) * 4); // bound the reselect cost
            let chosen = crate::anns::hnsw::select::select_heuristic(
                &self.vectors,
                &cands,
                self.m0,
                1.0,
                true,
            );
            self.set_neighbors0(u, &chosen);
        }

        // --- Upper-layer repair (collect first: the maps are borrowed
        // while candidates are gathered).
        for li in 0..self.upper.len() {
            let level = (li + 1) as u8;
            let mut updates: Vec<(u32, Vec<u32>)> = Vec::new();
            for (&u, nbs) in &self.upper[li] {
                if !is_live(u) || !nbs.iter().any(|&nb| dropped[nb as usize]) {
                    continue;
                }
                let cands = self.repair_candidates(u, nbs, &dropped, &is_live, level);
                let chosen = crate::anns::hnsw::select::select_heuristic(
                    &self.vectors,
                    &cands,
                    self.m,
                    1.0,
                    true,
                );
                updates.push((u, chosen));
            }
            for (u, chosen) in updates {
                self.upper[li].insert(u, chosen);
            }
        }

        // --- Clear the dropped nodes themselves.
        for &t in pending {
            self.set_neighbors0(t, &[]);
            self.levels[t as usize] = 0;
            for layer in &mut self.upper {
                layer.remove(&t);
            }
        }

        // --- Re-anchor entry on a live max-level node. Keeping the current
        // entry when it is still live and still maximal makes a
        // no-structural-change consolidate stable.
        let mut best: Option<(u8, u32)> = None;
        for i in 0..n as u32 {
            if is_live(i) {
                let l = self.levels[i as usize];
                if best.map_or(true, |(bl, _)| l > bl) {
                    best = Some((l, i));
                }
            }
        }
        match best {
            Some((l, i)) => {
                if !is_live(self.entry) || self.levels[self.entry as usize] < l {
                    self.entry = i;
                }
                self.max_level = self.levels[self.entry as usize];
            }
            None => {
                // No live nodes left: park the entry on slot 0 (cleared
                // above if it was dropped); searches return empty via the
                // tombstone filter.
                self.entry = 0;
                self.max_level = if n > 0 { self.levels[0] } else { 0 };
            }
        }
        let old_eps = std::mem::take(&mut self.entry_points);
        self.entry_points.push(self.entry);
        self.entry_points
            .extend(old_eps.into_iter().filter(|&ep| is_live(ep) && ep != self.entry));
    }

    /// Candidate pool for repairing `u`'s adjacency at `level`: surviving
    /// neighbors plus live neighbors-of-dropped-neighbors, scored by
    /// distance to `u`, sorted ascending, deduplicated.
    fn repair_candidates(
        &self,
        u: u32,
        nbs: &[u32],
        dropped: &[bool],
        is_live: &impl Fn(u32) -> bool,
        level: u8,
    ) -> Vec<(f32, u32)> {
        let mut ids: Vec<u32> = Vec::with_capacity(nbs.len() * 2);
        for &nb in nbs {
            if dropped[nb as usize] {
                let second: &[u32] = if level == 0 {
                    self.neighbors0_meta(nb)
                } else {
                    self.neighbors_upper(level, nb)
                };
                for &nn in second {
                    if nn != u && is_live(nn) {
                        ids.push(nn);
                    }
                }
            } else if is_live(nb) {
                ids.push(nb);
            }
        }
        let uv = self.vectors.vec(u);
        let mut cands: Vec<(f32, u32)> = ids
            .into_iter()
            .map(|c| (self.vectors.metric.distance(uv, self.vectors.vec(c)), c))
            .collect();
        cands.sort_by(crate::anns::heap::dist_cmp);
        cands.dedup_by_key(|x| x.1);
        cands
    }

    /// Approximate resident memory.
    pub fn memory_bytes(&self) -> usize {
        let upper: usize = self
            .upper
            .iter()
            .map(|m| m.values().map(|v| v.len() * 4 + 16).sum::<usize>())
            .sum();
        self.vectors.data.len() * 4 + self.layer0.len() * 4 + self.degree0.len() * 2 + upper
    }

    /// Graph invariants, checked by tests and the property harness:
    /// degrees within bounds, no self-loops, ids valid, `degree0`
    /// consistent with sentinel scan.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.len() as u32;
        for i in 0..n {
            let scan = self.neighbors0_scan(i);
            let meta = self.neighbors0_meta(i);
            if scan != meta {
                return Err(format!("node {i}: degree metadata mismatch"));
            }
            if scan.len() > self.m0 {
                return Err(format!("node {i}: layer0 degree {} > m0", scan.len()));
            }
            for &nb in scan {
                if nb == i {
                    return Err(format!("node {i}: self-loop at layer 0"));
                }
                if nb >= n {
                    return Err(format!("node {i}: bad neighbor id {nb}"));
                }
            }
        }
        for (li, layer) in self.upper.iter().enumerate() {
            for (&i, nbs) in layer {
                if nbs.len() > self.m {
                    return Err(format!("node {i}@L{}: degree {} > m", li + 1, nbs.len()));
                }
                if (self.levels[i as usize] as usize) < li + 1 {
                    return Err(format!("node {i} present at L{} above its level", li + 1));
                }
                for &nb in nbs {
                    if nb == i || nb >= n {
                        return Err(format!("node {i}@L{}: bad neighbor {nb}", li + 1));
                    }
                }
            }
        }
        if n > 0 {
            if self.entry >= n {
                return Err("entry out of range".into());
            }
            if self.levels[self.entry as usize] != self.max_level {
                return Err("entry is not at max level".into());
            }
            for &ep in &self.entry_points {
                if ep >= n {
                    return Err(format!("entry point {ep} out of range"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn empty_graph(n: usize) -> HnswGraph {
        let data = vec![0f32; n * 4];
        HnswGraph::new(VectorSet::new(data, 4, Metric::L2), 4)
    }

    #[test]
    fn set_and_scan_neighbors() {
        let mut g = empty_graph(10);
        g.set_neighbors0(3, &[1, 2, 5]);
        assert_eq!(g.neighbors0_scan(3), &[1, 2, 5]);
        assert_eq!(g.neighbors0_meta(3), &[1, 2, 5]);
        assert_eq!(g.neighbors0_slots(3).len(), 8);
        g.set_neighbors0(3, &[7]);
        assert_eq!(g.neighbors0_meta(3), &[7]);
    }

    #[test]
    fn push_neighbor_respects_capacity() {
        let mut g = empty_graph(10);
        for nb in 0..8u32 {
            assert!(g.push_neighbor0(0, nb + 1));
        }
        assert!(!g.push_neighbor0(0, 9));
        assert_eq!(g.neighbors0_meta(0).len(), 8);
    }

    #[test]
    fn upper_layers_grow_on_demand() {
        let mut g = empty_graph(10);
        g.set_neighbors_upper(3, 2, vec![1]);
        assert_eq!(g.upper.len(), 3);
        assert_eq!(g.neighbors_upper(3, 2), &[1]);
        assert_eq!(g.neighbors_upper(2, 2), &[] as &[u32]);
        assert_eq!(g.neighbors_upper(1, 9), &[] as &[u32]);
    }

    #[test]
    fn mutation_slots_append_and_reset() {
        let mut g = empty_graph(3);
        let id = g.append_slot(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(id, 3);
        assert_eq!(g.len(), 4);
        assert_eq!(g.vectors.vec(3), &[1.0, 2.0, 3.0, 4.0]);
        assert!(g.neighbors0_meta(3).is_empty());
        g.set_neighbors0(3, &[0, 1]);
        g.set_neighbors_upper(2, 3, vec![1]);
        g.levels[3] = 2;
        g.reset_slot(3, &[9.0, 9.0, 9.0, 9.0]);
        assert_eq!(g.vectors.vec(3), &[9.0, 9.0, 9.0, 9.0]);
        assert!(g.neighbors0_meta(3).is_empty());
        assert_eq!(g.levels[3], 0);
        assert_eq!(g.neighbors_upper(2, 3), &[] as &[u32]);
        g.validate().unwrap();
    }

    #[test]
    fn mutation_drop_nodes_reconnects_neighbor_of_neighbor() {
        // A path 0 - 1 - 2 (layer 0): dropping 1 must leave 0 and 2
        // reconnected through the neighbor-of-neighbor candidates.
        let data = vec![
            0.0, 0.0, 0.0, 0.0, // 0
            1.0, 0.0, 0.0, 0.0, // 1 (to drop)
            2.0, 0.0, 0.0, 0.0, // 2
        ];
        let mut g = HnswGraph::new(VectorSet::new(data, 4, Metric::L2), 4);
        g.set_neighbors0(0, &[1]);
        g.set_neighbors0(1, &[0, 2]);
        g.set_neighbors0(2, &[1]);
        let dead = [1u32];
        g.drop_nodes(&dead, |id| id != 1);
        assert_eq!(g.neighbors0_meta(0), &[2], "0 must reconnect to 2");
        assert_eq!(g.neighbors0_meta(2), &[0], "2 must reconnect to 0");
        assert!(g.neighbors0_meta(1).is_empty(), "dropped node cleared");
        assert!(g.entry != 1 && !g.entry_points.contains(&1));
        g.validate().unwrap();
        // Empty pending list: strict no-op.
        let before = g.layer0.clone();
        g.drop_nodes(&[], |_| true);
        assert_eq!(g.layer0, before);
    }

    #[test]
    fn validate_catches_corruption() {
        let mut g = empty_graph(5);
        assert!(g.validate().is_ok());
        // Self-loop.
        g.set_neighbors0(2, &[2]);
        assert!(g.validate().is_err());
        g.set_neighbors0(2, &[]);
        // Metadata mismatch.
        g.layer0.to_mut()[0] = 1;
        assert!(g.validate().is_err());
    }
}
