//! HNSW search: greedy upper-layer descent + layer-0 beam search.
//!
//! Implements every §6.2 knob:
//! * **multi-tier entry selection** — `entry_tiers` + budget thresholds
//!   admit additional diverse entry points as `ef` grows;
//! * **batch edge processing** — unvisited neighbors are gathered, then the
//!   whole batch is evaluated with one one-to-many SIMD kernel call
//!   ([`crate::distance::simd`]) whose internal prefetch pipelining turns
//!   dependent random loads into a software pipeline;
//! * **early termination** — convergence detection on consecutive
//!   non-improving expansions;
//! * **prefetch depth/locality** — `_mm_prefetch` hints while walking
//!   adjacency.
//!
//! The same layer search (minus the search-module knobs) backs graph
//! construction via [`search_layer`].

use crate::anns::filter::Admit;
use crate::anns::heap::{dist_cmp, MinQueue, TopK};
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::tombstones::Tombstones;
use crate::anns::visited::VisitedSet;
use crate::distance::prefetch;
use crate::variants::SearchKnobs;

/// Reusable per-query search state, checked out of the shared
/// [`crate::anns::scratch::ScratchPool`] by every index type (not just
/// HNSW): the visited set and frontier back the graph beams, and the
/// gather/distance buffers feed the one-to-many kernels in GLASS, IVF and
/// brute force.
pub struct SearchContext {
    pub visited: VisitedSet,
    pub frontier: MinQueue,
    /// Batch buffer for the edge-batching knob (and id gathers generally).
    pub batch: Vec<u32>,
    /// Distance buffer filled by the one-to-many kernel, aligned with
    /// `batch`.
    pub dists: Vec<f32>,
    /// `(dist, id)` pair buffer — IVF cell ranking and similar gathers
    /// that would otherwise allocate per query.
    pub cands: Vec<(f32, u32)>,
}

impl SearchContext {
    pub fn new(n: usize) -> Self {
        SearchContext {
            visited: VisitedSet::new(n),
            frontier: MinQueue::with_capacity(256),
            batch: Vec::with_capacity(64),
            dists: Vec::with_capacity(64),
            cands: Vec::new(),
        }
    }

    pub fn ensure(&mut self, n: usize) {
        self.visited.resize(n);
    }
}

/// Greedy 1-NN descent through levels `max..=1`, returning the layer-0
/// entry and its distance.
pub fn greedy_descent(graph: &HnswGraph, q: &[f32]) -> (f32, u32) {
    let mut cur = graph.entry;
    let mut curd = graph.vectors.distance(q, cur);
    for level in (1..=graph.max_level).rev() {
        loop {
            let mut improved = false;
            for &nb in graph.neighbors_upper(level, cur) {
                let d = graph.vectors.distance(q, nb);
                if dist_cmp(&(d, nb), &(curd, cur)) == std::cmp::Ordering::Less {
                    cur = nb;
                    curd = d;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
    }
    (curd, cur)
}

/// Full k-NN query with the §6.2 knobs. Returns `(dist, id)` nearest-first.
pub fn search(
    graph: &HnswGraph,
    knobs: &SearchKnobs,
    ctx: &mut SearchContext,
    q: &[f32],
    k: usize,
    ef: usize,
) -> Vec<(f32, u32)> {
    search_filtered(graph, knobs, ctx, q, k, ef, None)
}

/// [`search`] with an optional tombstone filter (mutable indexes).
/// Tombstoned nodes stay fully *traversable* — they seed and extend the
/// frontier exactly as live nodes do, preserving graph connectivity — but
/// they never enter the result pool, so a dead id cannot surface and the
/// beam bound is computed over live candidates only. With `deleted: None`
/// (or an empty bitset — callers pass `None` then) the code path is
/// identical to the pre-mutability search.
#[allow(clippy::too_many_arguments)]
pub fn search_filtered(
    graph: &HnswGraph,
    knobs: &SearchKnobs,
    ctx: &mut SearchContext,
    q: &[f32],
    k: usize,
    ef: usize,
    deleted: Option<&Tombstones>,
) -> Vec<(f32, u32)> {
    search_admit(graph, knobs, ctx, q, k, ef, Admit::live_only(deleted))
}

/// [`search_filtered`] under the full admission predicate: liveness AND an
/// optional per-id allow-list ([`crate::anns::FilterBitset`]). Dead and
/// non-matching nodes stay traversable but are filtered at `results.push`,
/// so with `Admit::none()` / `Admit::live_only(None)` the path is
/// byte-identical to [`search`].
#[allow(clippy::too_many_arguments)]
pub fn search_admit(
    graph: &HnswGraph,
    knobs: &SearchKnobs,
    ctx: &mut SearchContext,
    q: &[f32],
    k: usize,
    ef: usize,
    admit: Admit<'_>,
) -> Vec<(f32, u32)> {
    if graph.is_empty() {
        return Vec::new();
    }
    let entry = greedy_descent(graph, q);
    let scorer = GraphScorer {
        graph,
        q,
        depth: knobs.prefetch_depth,
        locality: knobs.prefetch_locality,
    };
    let mut out = beam_search0(
        &scorer,
        knobs,
        ctx,
        entry,
        &graph.entry_points,
        ef.max(k),
        &admit,
    );
    out.truncate(k);
    out
}

/// Scoring/adjacency interface walked by [`beam_search0`]: the exact
/// f32 implementation lives here ([`GraphScorer`]); the SQ8 quantized
/// implementation lives in `anns::glass`. Only representation-specific
/// operations belong on the scorer — the beam's control flow (entry
/// tiers, frontier/result admission, edge batching, early termination)
/// has exactly one copy.
pub(crate) trait BeamScorer {
    /// Distance from the query to `id`.
    fn score(&self, id: u32) -> f32;
    /// One-to-many kernel for the edge-batching knob; fills `out` aligned
    /// with `ids`.
    fn score_batch(&self, ids: &[u32], out: &mut Vec<f32>);
    /// Layer-0 adjacency of `u`.
    fn neighbors(&self, u: u32) -> &[u32];
    /// Warm the prefetch window before a sequential scan of `neighbors`
    /// (no-op where the representation needs none).
    fn warmup(&self, neighbors: &[u32]);
    /// Sliding-window prefetch issued while evaluating `neighbors[j]`.
    fn lookahead(&self, neighbors: &[u32], j: usize);
}

/// Exact-distance scorer over the HNSW layer-0 graph.
struct GraphScorer<'a> {
    graph: &'a HnswGraph,
    q: &'a [f32],
    depth: usize,
    locality: i32,
}

impl BeamScorer for GraphScorer<'_> {
    fn score(&self, id: u32) -> f32 {
        self.graph.vectors.distance(self.q, id)
    }

    fn score_batch(&self, ids: &[u32], out: &mut Vec<f32>) {
        self.graph
            .vectors
            .distance_batch_with(self.q, ids, self.depth, self.locality, out);
    }

    fn neighbors(&self, u: u32) -> &[u32] {
        self.graph.neighbors0_meta(u)
    }

    fn warmup(&self, neighbors: &[u32]) {
        if self.depth > 0 {
            for &nb in neighbors.iter().take(self.depth) {
                prefetch(self.graph.vectors.vec(nb), self.locality);
            }
        }
    }

    fn lookahead(&self, neighbors: &[u32], j: usize) {
        if self.depth > 0 {
            if let Some(&ahead) = neighbors.get(j + self.depth) {
                prefetch(self.graph.vectors.vec(ahead), self.locality);
            }
        }
    }
}

/// THE layer-0 beam: entry seeding (greedy entry + §6.2 entry tiers),
/// frontier admission, result admission via `admit`, edge batching, and
/// early termination — one copy shared by the exact (HNSW) and quantized
/// (GLASS) beams. PR 2's entry-selection bug had to be fixed in two
/// copy-pasted versions of this loop; keeping the predicate
/// generalization here means it cannot diverge again.
///
/// Dead/non-matching nodes stay fully traversable (they seed and extend
/// the frontier, preserving connectivity) but never enter the result
/// pool, so the beam bound is computed over admitted candidates only.
/// Returns the full sorted pool (up to `ef` entries); callers truncate to
/// `k` or hand the pool to a reranker.
pub(crate) fn beam_search0<S: BeamScorer>(
    scorer: &S,
    knobs: &SearchKnobs,
    ctx: &mut SearchContext,
    entry: (f32, u32),
    entry_points: &[u32],
    ef: usize,
    admit: &Admit<'_>,
) -> Vec<(f32, u32)> {
    ctx.visited.clear();
    ctx.frontier.clear();
    let mut results = TopK::new(ef.max(1));

    // --- Multi-tier entry selection (§6.2). Tier 1: the greedy-descended
    // global entry. Tiers 2/3 admit extra diverse entry points when the
    // search budget crosses the thresholds.
    let (d0, e0) = entry;
    ctx.visited.insert(e0);
    ctx.frontier.push(d0, e0);
    if admit.allows(e0) {
        results.push(d0, e0);
    }
    let extra = match (knobs.entry_tiers, ef) {
        (t, ef) if t >= 3 && ef >= knobs.tier_budget_2 => entry_points.len(),
        (t, ef) if t >= 2 && ef >= knobs.tier_budget_1 => 3,
        // Tier 1 must use only the greedy-descended entry: admitting
        // `entry_points[0]` here silently ran tier-2 behavior and skewed
        // every entry_tiers ablation.
        _ => 0,
    };
    for &ep in entry_points.iter().take(extra) {
        if ctx.visited.insert(ep) {
            let d = scorer.score(ep);
            ctx.frontier.push(d, ep);
            if admit.allows(ep) {
                results.push(d, ep);
            }
        }
    }

    // --- Layer-0 beam search.
    let mut no_improve = 0usize;
    let patience = knobs.patience.max(1) * 4; // expansions, not single edges
    while let Some((d, u)) = ctx.frontier.pop() {
        if d > results.bound() {
            break;
        }
        let neighbors = scorer.neighbors(u);
        let mut improved = false;

        if knobs.edge_batch {
            // Gather unvisited neighbors in batches, then evaluate each
            // batch with one one-to-many kernel call — prefetch is
            // pipelined inside the kernel (§6.2), turning the dependent
            // random loads into a software pipeline.
            let bs = knobs.batch_size.max(1);
            let mut idx = 0;
            while idx < neighbors.len() {
                ctx.batch.clear();
                while idx < neighbors.len() && ctx.batch.len() < bs {
                    let nb = neighbors[idx];
                    idx += 1;
                    if ctx.visited.insert(nb) {
                        ctx.batch.push(nb);
                    }
                }
                scorer.score_batch(&ctx.batch, &mut ctx.dists);
                for (&nb, &dnb) in ctx.batch.iter().zip(ctx.dists.iter()) {
                    if dnb < results.bound() {
                        if admit.allows(nb) && results.push(dnb, nb) {
                            improved = true;
                        }
                        ctx.frontier.push(dnb, nb);
                    }
                }
            }
        } else {
            // Baseline: sequential scan with a sliding lookahead window —
            // warm the scorer's prefetch window, then keep prefetching
            // ahead of `neighbors[j]` while evaluating it (the old code
            // only prefetched the first `depth` neighbors one step ahead).
            scorer.warmup(neighbors);
            for (j, &nb) in neighbors.iter().enumerate() {
                scorer.lookahead(neighbors, j);
                if !ctx.visited.insert(nb) {
                    continue;
                }
                let dnb = scorer.score(nb);
                if dnb < results.bound() {
                    if admit.allows(nb) && results.push(dnb, nb) {
                        improved = true;
                    }
                    ctx.frontier.push(dnb, nb);
                }
            }
        }

        // --- Early termination with convergence detection (§6.2).
        if knobs.early_termination {
            if improved {
                no_improve = 0;
            } else {
                no_improve += 1;
                if no_improve >= patience && results.is_full() {
                    break;
                }
            }
        }
    }

    results.into_sorted()
}

/// Construction-time layer search: beam search at an arbitrary `level`
/// from a single entry, returning up to `ef` candidates sorted ascending.
/// Prefetch knobs come from the construction module.
#[allow(clippy::too_many_arguments)]
pub fn search_layer(
    graph: &HnswGraph,
    q: &[f32],
    entry: (f32, u32),
    ef: usize,
    level: u8,
    visited: &mut VisitedSet,
    frontier: &mut MinQueue,
    prefetch_depth: usize,
    prefetch_locality: i32,
) -> Vec<(f32, u32)> {
    visited.clear();
    frontier.clear();
    let mut results = TopK::new(ef.max(1));
    visited.insert(entry.1);
    frontier.push(entry.0, entry.1);
    results.push(entry.0, entry.1);

    while let Some((d, u)) = frontier.pop() {
        if d > results.bound() {
            break;
        }
        let neighbors: &[u32] = if level == 0 {
            graph.neighbors0_meta(u)
        } else {
            graph.neighbors_upper(level, u)
        };
        // Sliding lookahead window (same shape as the query path above).
        if prefetch_depth > 0 {
            for &nb in neighbors.iter().take(prefetch_depth) {
                prefetch(graph.vectors.vec(nb), prefetch_locality);
            }
        }
        for (j, &nb) in neighbors.iter().enumerate() {
            if prefetch_depth > 0 {
                if let Some(&ahead) = neighbors.get(j + prefetch_depth) {
                    prefetch(graph.vectors.vec(ahead), prefetch_locality);
                }
            }
            if !visited.insert(nb) {
                continue;
            }
            let dnb = graph.vectors.distance(q, nb);
            if dnb < results.bound() {
                results.push(dnb, nb);
                frontier.push(dnb, nb);
            }
        }
    }
    results.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::VectorSet;
    use crate::distance::Metric;
    use crate::variants::ConstructionKnobs;

    fn grid_graph() -> HnswGraph {
        // 100 points on a 10x10 grid, built with default knobs.
        let mut data = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                data.push(i as f32);
                data.push(j as f32);
            }
        }
        let vs = VectorSet::new(data, 2, Metric::L2);
        crate::anns::hnsw::builder::build(vs, &ConstructionKnobs::default(), 1)
    }

    #[test]
    fn finds_exact_nn_on_grid() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let knobs = SearchKnobs::default();
        for (qx, qy, want) in [(0.1, 0.1, 0u32), (9.2, 8.9, 99), (4.9, 5.1, 55)] {
            let out = search(&g, &knobs, &mut ctx, &[qx, qy], 1, 32);
            assert_eq!(out[0].1, want, "query ({qx},{qy})");
        }
    }

    #[test]
    fn knob_combinations_preserve_correctness() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let q = [3.4, 6.6];
        let base = search(&g, &SearchKnobs::default(), &mut ctx, &q, 5, 64);
        for knobs in [
            SearchKnobs {
                edge_batch: true,
                batch_size: 8,
                ..SearchKnobs::default()
            },
            SearchKnobs {
                entry_tiers: 3,
                tier_budget_1: 16,
                tier_budget_2: 32,
                ..SearchKnobs::default()
            },
            SearchKnobs::crinn_discovered(),
        ] {
            let got = search(&g, &knobs, &mut ctx, &q, 5, 64);
            let base_ids: Vec<u32> = base.iter().map(|x| x.1).collect();
            let got_ids: Vec<u32> = got.iter().map(|x| x.1).collect();
            assert_eq!(base_ids, got_ids, "knobs {knobs:?}");
        }
    }

    #[test]
    fn early_termination_still_finds_nn() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let knobs = SearchKnobs {
            early_termination: true,
            patience: 1,
            ..SearchKnobs::default()
        };
        let out = search(&g, &knobs, &mut ctx, &[7.1, 2.0], 1, 16);
        assert_eq!(out[0].1, 72);
    }

    #[test]
    fn results_sorted_and_distinct() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let out = search(
            &g,
            &SearchKnobs::crinn_discovered(),
            &mut ctx,
            &[5.0, 5.0],
            10,
            64,
        );
        assert_eq!(out.len(), 10);
        for w in out.windows(2) {
            assert!(dist_cmp(&w[0], &w[1]) != std::cmp::Ordering::Greater);
        }
        let ids: std::collections::HashSet<u32> = out.iter().map(|x| x.1).collect();
        assert_eq!(ids.len(), 10);
    }

    #[test]
    fn tier1_ignores_extra_entry_points() {
        // Two structurally identical multi-entry graphs, one with its
        // entry-point set emptied. A tier-1 search must not touch
        // `graph.entry_points` at all, so results AND visited-node counts
        // must match exactly (the old `_ => 1` fallback admitted
        // `entry_points[0]` and silently ran tier-2 behavior).
        let mut data = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                data.push(i as f32);
                data.push(j as f32);
            }
        }
        let knobs_build = ConstructionKnobs {
            num_entry_points: 5,
            ..Default::default()
        };
        let g = crate::anns::hnsw::builder::build(
            VectorSet::new(data.clone(), 2, Metric::L2),
            &knobs_build,
            1,
        );
        assert!(g.entry_points.len() >= 2, "need a multi-entry graph");
        let mut bare = crate::anns::hnsw::builder::build(
            VectorSet::new(data, 2, Metric::L2),
            &knobs_build,
            1,
        );
        bare.entry_points.clear();

        let tier1 = SearchKnobs::default();
        assert_eq!(tier1.entry_tiers, 1);
        let mut ctx = SearchContext::new(g.len());
        for q in [[0.3f32, 9.1], [5.2, 4.8], [9.7, 0.2]] {
            let a = search(&g, &tier1, &mut ctx, &q, 5, 32);
            let va = ctx.visited.count();
            let b = search(&bare, &tier1, &mut ctx, &q, 5, 32);
            let vb = ctx.visited.count();
            assert_eq!(a, b, "tier-1 results depend on entry_points");
            assert_eq!(va, vb, "tier-1 search visited entry_points nodes");
        }

        // Sanity: tier 3 with crossed budgets really does seed the extra
        // entries (visits at least as many nodes as the bare graph).
        let tier3 = SearchKnobs {
            entry_tiers: 3,
            tier_budget_1: 8,
            tier_budget_2: 16,
            ..Default::default()
        };
        search(&g, &tier3, &mut ctx, &[0.3, 9.1], 5, 32);
        let v3 = ctx.visited.count();
        search(&bare, &tier3, &mut ctx, &[0.3, 9.1], 5, 32);
        let v3_bare = ctx.visited.count();
        assert!(v3 >= v3_bare, "tier-3 should seed extra entries ({v3} < {v3_bare})");
    }

    #[test]
    fn tombstoned_nodes_filtered_but_traversable() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let knobs = SearchKnobs::default();
        let q = [4.9f32, 5.1];
        let base = search_filtered(&g, &knobs, &mut ctx, &q, 5, 64, None);
        assert_eq!(base[0].1, 55);
        // Tombstone the true NN (and a second nearby node): they must
        // vanish from results while the rest of the ranking is preserved.
        let mut dead = crate::anns::tombstones::Tombstones::new(g.len());
        dead.set(55);
        dead.set(45);
        let got = search_filtered(&g, &knobs, &mut ctx, &q, 5, 64, Some(&dead));
        assert!(got.iter().all(|&(_, id)| id != 55 && id != 45));
        let want: Vec<(f32, u32)> = search_filtered(&g, &knobs, &mut ctx, &q, 7, 64, None)
            .into_iter()
            .filter(|&(_, id)| id != 55 && id != 45)
            .take(5)
            .collect();
        assert_eq!(got, want, "filtered beam must keep the live ranking");
        // An empty bitset behaves exactly like no bitset.
        let none = crate::anns::tombstones::Tombstones::new(g.len());
        assert_eq!(
            search_filtered(&g, &knobs, &mut ctx, &q, 5, 64, Some(&none)),
            base
        );
    }

    #[test]
    fn filtered_beam_respects_allow_list_and_none_is_identical() {
        let g = grid_graph();
        let mut ctx = SearchContext::new(g.len());
        let knobs = SearchKnobs::default();
        let q = [4.9f32, 5.1];
        let base = search(&g, &knobs, &mut ctx, &q, 5, 64);
        // No filter at all: bit-identical to the plain search.
        assert_eq!(
            search_admit(&g, &knobs, &mut ctx, &q, 5, 64, Admit::none()),
            base
        );
        // Allow only even ids: every result must be even, and the ranking
        // must equal the post-filtered unfiltered ranking (the beam covers
        // the whole 100-point component at ef=64... results are a subset).
        let filter = crate::anns::FilterBitset::from_predicate(g.len(), |id| id % 2 == 0);
        let admit = Admit {
            deleted: None,
            filter: Some(&filter),
        };
        let got = search_admit(&g, &knobs, &mut ctx, &q, 5, 64, admit);
        assert_eq!(got.len(), 5);
        assert!(got.iter().all(|&(_, id)| id % 2 == 0));
        // Conjunction with tombstones: a dead-but-matching id never
        // surfaces either.
        let mut dead = crate::anns::tombstones::Tombstones::new(g.len());
        dead.set(got[0].1);
        let both = Admit {
            deleted: Some(&dead),
            filter: Some(&filter),
        };
        let again = search_admit(&g, &knobs, &mut ctx, &q, 5, 64, both);
        assert!(again.iter().all(|&(_, id)| id != got[0].1 && id % 2 == 0));
    }

    #[test]
    fn empty_graph_returns_empty() {
        let vs = VectorSet::new(vec![], 2, Metric::L2);
        let g = HnswGraph::new(vs, 4);
        let mut ctx = SearchContext::new(0);
        assert!(search(&g, &SearchKnobs::default(), &mut ctx, &[0.0, 0.0], 3, 8).is_empty());
    }
}
