//! HNSW graph construction (§2.1) with the §6.1 knobs.
//!
//! Incremental insertion: each vector draws a level from the exponential
//! distribution (`floor(-ln(U) * mL)`, `mL = 1/ln(M)` — the skip-list-like
//! hierarchy the paper describes), greedy-descends from the current entry
//! to its level, then beam-searches each layer down to 0 with the
//! (possibly adaptive, §6.1) construction `ef`, linking to the
//! heuristic-selected M (upper) / 2M (layer 0) neighbors and re-pruning
//! overflowing adjacency lists.
//!
//! After insertion the §6.1 multi-entry-point architecture selects up to
//! `num_entry_points` mutually-distant nodes for the search tiers.

use crate::anns::heap::{dist_cmp, MinQueue};
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::hnsw::search::search_layer;
use crate::anns::hnsw::select;
use crate::anns::visited::VisitedSet;
use crate::anns::VectorSet;
use crate::util::rng::Rng;
use crate::variants::ConstructionKnobs;

/// Build an HNSW graph. Deterministic for a given `(vs, knobs, seed)`.
pub fn build(vs: VectorSet, knobs: &ConstructionKnobs, seed: u64) -> HnswGraph {
    let n = vs.len();
    let mut graph = HnswGraph::new(vs, knobs.m.max(2));
    if n == 0 {
        return graph;
    }
    let mut rng = Rng::new(seed ^ 0x5EED);
    let ml = 1.0 / (graph.m as f64).ln();
    let ef_c = knobs.effective_ef().max(8);

    let mut visited = VisitedSet::new(n);
    let mut frontier = MinQueue::with_capacity(ef_c * 2);

    // Node 0 seeds the graph.
    graph.entry = 0;
    graph.levels[0] = sample_level(&mut rng, ml);
    graph.max_level = graph.levels[0];

    for i in 1..n as u32 {
        let level = sample_level(&mut rng, ml);
        graph.levels[i as usize] = level;
        insert(&mut graph, knobs, i, level, ef_c, &mut visited, &mut frontier);
        if level > graph.max_level {
            graph.max_level = level;
            graph.entry = i;
        }
    }

    select_entry_points(&mut graph, knobs, &mut rng);
    graph
}

/// Draw a node level from the exponential distribution (shared by batch
/// build and online insert — both sample the same hierarchy).
pub(crate) fn sample_level(rng: &mut Rng, ml: f64) -> u8 {
    let u = 1.0 - rng.next_f64(); // (0, 1]
    ((-u.ln() * ml) as usize).min(31) as u8
}

/// Link node `i` (vector already stored, level already assigned) into the
/// graph: greedy descent above its level, beam-searched candidates and
/// heuristic selection per layer, bidirectional links with overflow
/// re-pruning. This is the one insertion body — `build` calls it for every
/// point of a batch build, and `MutableAnnIndex::insert` calls it for each
/// online arrival, so online inserts produce the same edge quality as a
/// from-scratch build.
pub(crate) fn insert(
    graph: &mut HnswGraph,
    knobs: &ConstructionKnobs,
    i: u32,
    level: u8,
    ef_c: usize,
    visited: &mut VisitedSet,
    frontier: &mut MinQueue,
) {
    let q = graph.vectors.vec(i).to_vec();
    // Greedy descent through layers above the node's level.
    let mut cur = graph.entry;
    let mut curd = graph.vectors.distance(&q, cur);
    let top = graph.max_level;
    for l in ((level + 1)..=top).rev() {
        loop {
            let mut improved = false;
            for &nb in graph.neighbors_upper(l, cur) {
                let d = graph.vectors.distance(&q, nb);
                if dist_cmp(&(d, nb), &(curd, cur)) == std::cmp::Ordering::Less {
                    cur = nb;
                    curd = d;
                    improved = true;
                }
            }
            if !improved {
                break;
            }
        }
    }

    // Connect at each layer from min(level, top) down to 0.
    let mut entry = (curd, cur);
    for l in (0..=level.min(top)).rev() {
        let cands = search_layer(
            graph,
            &q,
            entry,
            ef_c,
            l,
            visited,
            frontier,
            knobs.prefetch_depth,
            knobs.prefetch_locality,
        );
        let max_deg = if l == 0 { graph.m0 } else { graph.m };
        let chosen = select::select_heuristic(&graph.vectors, &cands, max_deg.min(knobs.m), 1.0, true);

        if l == 0 {
            graph.set_neighbors0(i, &chosen);
        } else {
            graph.set_neighbors_upper(l, i, chosen.clone());
        }
        // Bidirectional links with overflow re-pruning.
        for &nb in &chosen {
            add_link(graph, l, nb, i);
        }
        if let Some(&(d, c)) = cands.first() {
            entry = (d, c);
        }
    }
}

/// Add edge `from -> to` at layer `l`, re-pruning on overflow.
fn add_link(graph: &mut HnswGraph, l: u8, from: u32, to: u32) {
    if from == to {
        return;
    }
    if l == 0 {
        if !graph.push_neighbor0(from, to) {
            let current: Vec<u32> = graph.neighbors0_meta(from).to_vec();
            let pruned = select::reprune(&graph.vectors, from, &current, to, graph.m0, 1.0);
            graph.set_neighbors0(from, &pruned);
        }
    } else {
        let mut current = graph.neighbors_upper(l, from).to_vec();
        if current.contains(&to) {
            return;
        }
        if current.len() < graph.m {
            current.push(to);
            graph.set_neighbors_upper(l, from, current);
        } else {
            let pruned = select::reprune(&graph.vectors, from, &current, to, graph.m, 1.0);
            graph.set_neighbors_upper(l, from, pruned);
        }
    }
}

/// §6.1 multi-entry-point selection: greedily pick nodes whose pairwise
/// distance exceeds the `entry_diversity` quantile of sampled distances.
fn select_entry_points(graph: &mut HnswGraph, knobs: &ConstructionKnobs, rng: &mut Rng) {
    let n = graph.len();
    graph.entry_points = vec![graph.entry];
    let want = knobs.num_entry_points.clamp(1, 9);
    if want == 1 || n < 4 {
        return;
    }
    // Distance scale: sample random pairs.
    let mut dists: Vec<f32> = (0..64.min(n * n))
        .map(|_| {
            let a = rng.next_below(n) as u32;
            let b = rng.next_below(n) as u32;
            graph.vectors.distance(graph.vectors.vec(a), b)
        })
        .collect();
    dists.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let qi = ((dists.len() - 1) as f64 * knobs.entry_diversity.clamp(0.0, 0.99)) as usize;
    let threshold = dists[qi];

    // Candidates: prefer high-level nodes (cheap navigators), fall back to
    // random samples.
    let mut cands: Vec<u32> = (0..n as u32)
        .filter(|&i| graph.levels[i as usize] >= 1)
        .collect();
    if cands.len() < want * 4 {
        cands.extend(rng.sample_indices(n, (want * 8).min(n)).into_iter().map(|x| x as u32));
    }
    for &c in &cands {
        if graph.entry_points.len() >= want {
            break;
        }
        if graph.entry_points.contains(&c) {
            continue;
        }
        let diverse = graph
            .entry_points
            .iter()
            .all(|&ep| graph.vectors.distance(graph.vectors.vec(ep), c) > threshold);
        if diverse {
            graph.entry_points.push(c);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::Metric;

    fn random_vs(n: usize, dim: usize, seed: u64) -> VectorSet {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian_f32()).collect();
        VectorSet::new(data, dim, Metric::L2)
    }

    #[test]
    fn build_satisfies_invariants() {
        let g = build(random_vs(800, 16, 1), &ConstructionKnobs::default(), 2);
        g.validate().expect("invariants");
        assert_eq!(g.len(), 800);
    }

    #[test]
    fn build_deterministic() {
        let k = ConstructionKnobs::default();
        let a = build(random_vs(300, 8, 3), &k, 9);
        let b = build(random_vs(300, 8, 3), &k, 9);
        assert_eq!(a.layer0, b.layer0);
        assert_eq!(a.levels, b.levels);
        assert_eq!(a.entry, b.entry);
        assert_eq!(a.entry_points, b.entry_points);
    }

    #[test]
    fn layer0_connected_enough() {
        // Every node must have at least one layer-0 neighbor (n > 1).
        let g = build(random_vs(500, 12, 4), &ConstructionKnobs::default(), 5);
        for i in 0..500u32 {
            assert!(
                !g.neighbors0_meta(i).is_empty(),
                "node {i} disconnected at layer 0"
            );
        }
    }

    #[test]
    fn level_distribution_decays() {
        let g = build(random_vs(4000, 4, 6), &ConstructionKnobs::default(), 7);
        let l0 = g.levels.iter().filter(|&&l| l == 0).count();
        let l1 = g.levels.iter().filter(|&&l| l == 1).count();
        let l2p = g.levels.iter().filter(|&&l| l >= 2).count();
        assert!(l0 > l1 && l1 > l2p, "l0={l0} l1={l1} l2+={l2p}");
        // Geometric-ish: level-1 fraction near 1/M ± slack.
        let frac = l1 as f64 / 4000.0;
        assert!(frac > 0.01 && frac < 0.2, "level-1 fraction {frac}");
    }

    #[test]
    fn multi_entry_points_selected_and_diverse() {
        let mut knobs = ConstructionKnobs::default();
        knobs.num_entry_points = 5;
        knobs.entry_diversity = 0.3;
        let g = build(random_vs(600, 8, 8), &knobs, 9);
        assert!(g.entry_points.len() > 1, "got {:?}", g.entry_points.len());
        assert!(g.entry_points.len() <= 5);
        assert_eq!(g.entry_points[0], g.entry);
        let set: std::collections::HashSet<_> = g.entry_points.iter().collect();
        assert_eq!(set.len(), g.entry_points.len());
    }

    #[test]
    fn adaptive_ef_builds_valid_graph() {
        let knobs = ConstructionKnobs::crinn_discovered();
        let g = build(random_vs(400, 8, 10), &knobs, 11);
        g.validate().expect("invariants with crinn knobs");
    }

    #[test]
    fn single_point_graph() {
        let g = build(random_vs(1, 4, 12), &ConstructionKnobs::default(), 13);
        assert_eq!(g.len(), 1);
        g.validate().unwrap();
    }
}
