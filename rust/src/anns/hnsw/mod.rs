//! HNSW — the paper's §2 backbone.
//!
//! * [`graph`] — multi-layer graph storage (flat CSR-style layer 0, sparse
//!   upper layers), entry-point sets, precomputed degree metadata.
//! * [`builder`] — incremental insertion with exponential level sampling,
//!   beam-searched neighbor candidates and diversity-heuristic pruning.
//! * [`search`] — greedy upper-layer descent + layer-0 beam search, with
//!   every §6 search-module knob (multi-tier entries, edge batching,
//!   prefetch, early termination).
//! * [`select`] — the neighbor-selection heuristic shared by build & prune.

pub mod builder;
pub mod graph;
pub mod search;
pub mod select;

pub use graph::HnswGraph;

use crate::anns::filter::{Admit, FilterBitset, DEFAULT_FILTERED_FALLBACK};
use crate::anns::scratch::ScratchPool;
use crate::anns::tombstones::Tombstones;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::util::rng::Rng;
use crate::variants::{ConstructionKnobs, SearchKnobs};

/// A built HNSW index with an attached search configuration.
///
/// Per-query state comes from the shared
/// [`ScratchPool`]: a single RAII checkout per
/// query (or per *batch* — [`AnnIndex::search_batch`] drives every query
/// in a batch through one pooled [`search::SearchContext`]).
///
/// The index is mutable ([`MutableAnnIndex`]): online inserts reuse the
/// batch builder's insertion body (same level sampling, same heuristic
/// linking), deletes tombstone a [`Tombstones`] bit consulted by the
/// filtered beam, and consolidation repairs edges via
/// [`HnswGraph::drop_nodes`] while recycling freed slots.
pub struct HnswIndex {
    pub graph: HnswGraph,
    pub knobs: SearchKnobs,
    construction: ConstructionKnobs,
    label: String,
    scratch: ScratchPool,
    deleted: Tombstones,
    /// Consolidated slots awaiting reuse (still marked in `deleted`).
    free: Vec<u32>,
    /// Level-sampling stream for online inserts (deterministic per seed).
    rng: Rng,
    /// Selectivity crossover for filtered search (see
    /// [`AnnIndex::filtered_fallback_threshold`]).
    filtered_fallback: usize,
}

impl HnswIndex {
    /// Build from vectors with the given construction/search knobs.
    pub fn build(
        vs: VectorSet,
        construction: &ConstructionKnobs,
        search_knobs: SearchKnobs,
        seed: u64,
    ) -> Self {
        let graph = builder::build(vs, construction, seed);
        let deleted = Tombstones::new(graph.len());
        HnswIndex {
            graph,
            knobs: search_knobs,
            construction: construction.clone(),
            label: "hnsw".to_string(),
            scratch: ScratchPool::new(),
            deleted,
            free: Vec::new(),
            rng: Rng::new(seed ^ 0x11FE_11FE),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    /// Tune the selectivity crossover: filters with at most this many
    /// matching ids take the exact-scan fallback instead of the beam.
    pub fn set_filtered_fallback(&mut self, threshold: usize) {
        self.filtered_fallback = threshold;
    }

    /// The tombstone filter handed to the beam (see
    /// [`Tombstones::filter_ref`]).
    fn tombstone_ref(&self) -> Option<&Tombstones> {
        self.deleted.filter_ref()
    }

    /// Shared body of the filtered search/batch entry points: selectivity
    /// fallback for very selective filters, else the admission-filtered
    /// beam. `filter = None` is exactly the unfiltered path.
    fn search_one_filtered(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut search::SearchContext,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        if let Some(f) = filter {
            if f.count() <= self.filtered_fallback {
                return crate::anns::filtered_exact_fallback(
                    &self.graph.vectors,
                    query,
                    k,
                    &mut ctx.batch,
                    &mut ctx.dists,
                    self.tombstone_ref(),
                    f,
                );
            }
        }
        search::search_admit(
            &self.graph,
            &self.knobs,
            ctx,
            query,
            k,
            ef,
            Admit {
                deleted: self.tombstone_ref(),
                filter,
            },
        )
    }
}

impl AnnIndex for HnswIndex {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        search::search_filtered(
            &self.graph,
            &self.knobs,
            &mut ctx,
            query,
            k,
            ef,
            self.tombstone_ref(),
        )
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One scratch checkout for the whole batch; each search fully
        // resets the context, so results are bitwise identical to the
        // per-query path.
        let mut ctx = self.scratch.checkout(self.graph.len());
        let deleted = self.tombstone_ref();
        queries
            .iter()
            .map(|q| search::search_filtered(&self.graph, &self.knobs, &mut ctx, q, k, ef, deleted))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        self.search_one_filtered(query, k, ef, &mut ctx, filter)
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        queries
            .iter()
            .map(|q| self.search_one_filtered(q, k, ef, &mut ctx, filter))
            .collect()
    }

    fn filtered_fallback_threshold(&self) -> usize {
        self.filtered_fallback
    }

    fn len(&self) -> usize {
        self.graph.len()
    }

    fn memory_bytes(&self) -> usize {
        self.graph.memory_bytes()
    }
}

/// The one online-insert body shared by [`HnswIndex`] and
/// `GlassIndex` (same level sampling, slot lifecycle, entry anchoring and
/// builder linking — duplicating these subtle edge cases per index is how
/// they drift). `on_slot(id, recycled)` runs right after the slot holds
/// the new vector, before linking — GLASS keeps its SQ8 code rows in sync
/// there; plain HNSW passes a no-op.
#[allow(clippy::too_many_arguments)]
pub(crate) fn insert_point(
    graph: &mut HnswGraph,
    construction: &ConstructionKnobs,
    scratch: &ScratchPool,
    deleted: &mut Tombstones,
    free: &mut Vec<u32>,
    rng: &mut Rng,
    vec: &[f32],
    mut on_slot: impl FnMut(u32, bool),
) -> crate::Result<u32> {
    crate::anns::validate_insert_vec(vec, graph.dim())?;
    let level = builder::sample_level(rng, 1.0 / (graph.m as f64).ln());
    let id = match free.pop() {
        Some(id) => {
            graph.reset_slot(id, vec);
            deleted.clear(id);
            on_slot(id, true);
            id
        }
        None => {
            let id = graph.append_slot(vec);
            deleted.resize(graph.len());
            on_slot(id, false);
            id
        }
    };
    graph.levels[id as usize] = level;
    if graph.len() - deleted.count() == 1 {
        // First (or only) live point: it anchors the hierarchy. (The
        // graph may still hold dead slots — they are disconnected, so
        // descending from them would strand the beam.)
        graph.entry = id;
        graph.max_level = level;
        graph.entry_points = vec![id];
        return Ok(id);
    }
    let ef_c = construction.effective_ef().max(8);
    let mut guard = scratch.checkout(graph.len());
    let ctx: &mut search::SearchContext = &mut guard;
    builder::insert(
        graph,
        construction,
        id,
        level,
        ef_c,
        &mut ctx.visited,
        &mut ctx.frontier,
    );
    if level > graph.max_level {
        graph.max_level = level;
        graph.entry = id;
    }
    // Keep the §6.1 multi-entry architecture alive under growth: the
    // batch builder selects its diverse entry-point set once, at the end
    // of a build — a path an online-grown index never takes, which would
    // silently degrade every `entry_tiers >= 2` search to tier-1. Online
    // maintenance is capacity-fill rather than diversity-sampled: admit
    // upper-level arrivals (rare by construction — P(level >= 1) = 1/M,
    // so they are naturally spread) into spare tier capacity, and move a
    // newly promoted global entry to the head of the list.
    let cap = construction.num_entry_points.clamp(1, 9);
    if graph.entry == id {
        graph.entry_points.retain(|&ep| ep != id);
        graph.entry_points.insert(0, id);
        graph.entry_points.truncate(cap);
    } else if level >= 1 && graph.entry_points.len() < cap && !graph.entry_points.contains(&id) {
        graph.entry_points.push(id);
    }
    Ok(id)
}

/// The one consolidation lifecycle shared by the graph indexes
/// ([`HnswIndex`] and `GlassIndex`): compute the pending set, repair the
/// graph around it ([`HnswGraph::drop_nodes`]), hand the slots to the
/// free list. Returns the number of points dropped (0 = strict no-op).
pub(crate) fn consolidate_graph(
    graph: &mut HnswGraph,
    deleted: &Tombstones,
    free: &mut Vec<u32>,
) -> usize {
    let pending = deleted.pending(free);
    if pending.is_empty() {
        return 0;
    }
    graph.drop_nodes(&pending, |id| !deleted.contains(id));
    free.extend(&pending);
    pending.len()
}

impl MutableAnnIndex for HnswIndex {
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        insert_point(
            &mut self.graph,
            &self.construction,
            &self.scratch,
            &mut self.deleted,
            &mut self.free,
            &mut self.rng,
            vec,
            |_, _| {},
        )
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        self.deleted.delete(id)
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        Ok(consolidate_graph(&mut self.graph, &self.deleted, &mut self.free))
    }

    fn live_count(&self) -> usize {
        self.graph.len() - self.deleted.count()
    }

    fn deleted_count(&self) -> usize {
        self.deleted.count() - self.free.len()
    }

    fn is_deleted(&self, id: u32) -> bool {
        self.deleted.contains(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::distance::Metric;

    fn small_dataset() -> crate::dataset::Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1500, 50, 3);
        ds.compute_ground_truth(10);
        ds
    }

    fn recall_of(index: &dyn AnnIndex, ds: &crate::dataset::Dataset, ef: usize) -> f64 {
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = index.search(ds.query_vec(qi), 10, ef);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        acc / ds.n_queries() as f64
    }

    #[test]
    fn hnsw_reaches_high_recall() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.9, "recall@10 ef=128 was {r}");
    }

    #[test]
    fn recall_monotone_in_ef() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let lo = recall_of(&idx, &ds, 10);
        let hi = recall_of(&idx, &ds, 200);
        assert!(hi >= lo, "lo={lo} hi={hi}");
        assert!(hi > 0.95, "hi={hi}");
    }

    #[test]
    fn search_deterministic() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::crinn_discovered(),
            7,
        );
        for qi in 0..5 {
            let a = idx.search(ds.query_vec(qi), 10, 64);
            let b = idx.search(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn crinn_knobs_do_not_break_recall() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::crinn_discovered(),
            SearchKnobs::crinn_discovered(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.9, "crinn-knob recall@10 was {r}");
    }

    #[test]
    fn mutation_insert_delete_consolidate_roundtrip() {
        let ds = small_dataset();
        let mut idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let n0 = idx.len();
        // Insert a point: it must come back as its own nearest neighbor.
        let v: Vec<f32> = ds.query_vec(0).to_vec();
        let id = idx.insert(&v).unwrap();
        assert_eq!(id as usize, n0);
        assert_eq!(idx.len(), n0 + 1);
        assert_eq!(idx.live_count(), n0 + 1);
        let top = idx.search_with_dists(&v, 1, 64);
        assert_eq!(top[0], (0.0, id), "inserted point must be its own NN");
        // Delete it: it must vanish from results immediately.
        idx.delete(id).unwrap();
        assert!(idx.is_deleted(id));
        assert_eq!(idx.deleted_count(), 1);
        assert!(idx.search(&v, 10, 64).iter().all(|&i| i != id));
        assert!(idx.delete(id).is_err(), "double delete must error");
        // Consolidate: slot freed, graph stays valid, id gets recycled.
        assert_eq!(idx.consolidate().unwrap(), 1);
        assert_eq!(idx.consolidate().unwrap(), 0, "no pending => no-op");
        assert_eq!(idx.deleted_count(), 0);
        assert_eq!(idx.live_count(), n0);
        idx.graph.validate().expect("graph valid after consolidate");
        let id2 = idx.insert(&v).unwrap();
        assert_eq!(id2, id, "freed slot must be recycled");
        assert_eq!(idx.len(), n0 + 1);
        assert_eq!(idx.search(&v, 1, 64), vec![id2]);
        idx.graph.validate().expect("graph valid after recycle");
    }

    #[test]
    fn mutation_insert_matches_dimension_check() {
        let ds = small_dataset();
        let mut idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        assert!(idx.insert(&[1.0, 2.0]).is_err(), "wrong dim must error");
        assert!(idx.delete(1_000_000).is_err(), "out of range must error");
        // Non-finite rows would permanently corrupt neighbor selection —
        // rejected at the door, index untouched.
        let n0 = idx.len();
        assert!(idx.insert(&vec![f32::NAN; 64]).is_err(), "NaN row accepted");
        assert!(idx.insert(&vec![f32::INFINITY; 64]).is_err(), "Inf row accepted");
        assert_eq!(idx.len(), n0, "rejected insert must not grow the index");
    }

    #[test]
    fn mutation_insert_into_empty_index() {
        let vs = VectorSet::new(Vec::new(), 4, crate::distance::Metric::L2);
        let mut idx = HnswIndex::build(
            vs,
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            1,
        );
        assert!(idx.search(&[0.0; 4], 3, 16).is_empty());
        let a = idx.insert(&[0.0, 0.0, 0.0, 0.0]).unwrap();
        let b = idx.insert(&[1.0, 0.0, 0.0, 0.0]).unwrap();
        let c = idx.insert(&[2.0, 0.0, 0.0, 0.0]).unwrap();
        assert_eq!((a, b, c), (0, 1, 2));
        idx.graph.validate().unwrap();
        assert_eq!(idx.search(&[1.9, 0.0, 0.0, 0.0], 2, 16), vec![c, b]);
        // Delete everything: searches go empty, never panic.
        for id in [a, b, c] {
            idx.delete(id).unwrap();
        }
        assert!(idx.search(&[0.0; 4], 3, 16).is_empty());
        assert_eq!(idx.consolidate().unwrap(), 3);
        assert!(idx.search(&[0.0; 4], 3, 16).is_empty());
        // And the graph comes back from the dead via slot reuse.
        let d = idx.insert(&[5.0, 0.0, 0.0, 0.0]).unwrap();
        assert!(idx.is_deleted(a) || d == a || d == b || d == c);
        assert_eq!(idx.search(&[5.0, 0.0, 0.0, 0.0], 1, 16), vec![d]);
        idx.graph.validate().unwrap();
    }

    #[test]
    fn mutation_grown_index_keeps_multi_entry_architecture() {
        // An index grown purely through online inserts must not silently
        // lose the §6.1 multi-entry feature: the batch builder's one-shot
        // entry-point selection never runs for it, so insert_point has to
        // fill tier capacity as upper-level nodes arrive.
        let knobs = ConstructionKnobs {
            num_entry_points: 5,
            ..ConstructionKnobs::default()
        };
        let vs = VectorSet::new(Vec::new(), 8, crate::distance::Metric::L2);
        let mut idx = HnswIndex::build(vs, &knobs, SearchKnobs::default(), 9);
        let mut rng = crate::util::rng::Rng::new(99);
        for _ in 0..400 {
            let v: Vec<f32> = (0..8).map(|_| rng.next_gaussian_f32()).collect();
            idx.insert(&v).unwrap();
        }
        idx.graph.validate().unwrap();
        let eps = &idx.graph.entry_points;
        assert!(eps.len() >= 2, "online growth never filled entry tiers: {eps:?}");
        assert!(eps.len() <= 5);
        assert_eq!(eps[0], idx.graph.entry, "global entry must head the tier list");
        let set: std::collections::HashSet<_> = eps.iter().collect();
        assert_eq!(set.len(), eps.len(), "duplicate entry points");
        assert!(eps.iter().all(|&ep| (ep as usize) < idx.len()));
        // A tier-3 search actually uses them and stays well-formed.
        let tier3 = SearchKnobs {
            entry_tiers: 3,
            tier_budget_1: 8,
            tier_budget_2: 16,
            ..SearchKnobs::default()
        };
        let mut probe = idx;
        probe.knobs = tier3;
        let out = probe.search_with_dists(&[0.0; 8], 10, 64);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn filtered_hnsw_search_and_fallback() {
        let ds = small_dataset();
        let mut idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let n = ds.n_base();
        // filter=None is bitwise the unfiltered path.
        for qi in 0..5 {
            let q = ds.query_vec(qi);
            assert_eq!(
                idx.search_filtered_with_dists(q, 10, 64, None),
                idx.search_with_dists(q, 10, 64)
            );
        }
        // ~50% selective beam-path filter: results stay inside the set.
        let half = crate::anns::FilterBitset::from_predicate(n, |id| id % 2 == 0);
        assert!(half.count() > idx.filtered_fallback_threshold());
        let got = idx.search_filtered_with_dists(ds.query_vec(0), 10, 64, Some(&half));
        assert_eq!(got.len(), 10);
        assert!(got.iter().all(|&(_, id)| id % 2 == 0));
        // Very selective filter: exact fallback equals the filtered oracle
        // and skips tombstones.
        let rare = crate::anns::FilterBitset::from_predicate(n, |id| id % 100 == 0);
        assert!(rare.count() <= idx.filtered_fallback_threshold());
        let q = ds.query_vec(1);
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        let want = crate::dataset::gt::topk_pairs_for_query_filtered(
            &ds.base,
            q,
            ds.dim,
            ds.metric,
            10,
            &mut ids,
            &mut dists,
            |i| rare.matches(i),
        );
        assert_eq!(idx.search_filtered_with_dists(q, 10, 64, Some(&rare)), want);
        idx.delete(want[0].1).unwrap();
        let after = idx.search_filtered_with_dists(q, 10, 64, Some(&rare));
        assert!(after.iter().all(|&(_, id)| id != want[0].1));
        // Filtered batch == filtered per-query.
        let queries: Vec<&[f32]> = (0..5).map(|qi| ds.query_vec(qi)).collect();
        let batched = idx.search_filtered_batch(&queries, 10, 64, Some(&half));
        for (qi, q) in queries.iter().enumerate() {
            assert_eq!(
                batched[qi],
                idx.search_filtered_with_dists(q, 10, 64, Some(&half))
            );
        }
    }

    #[test]
    fn angular_metric_works() {
        let sp = synth::spec("glove-25-angular").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 5);
        ds.compute_ground_truth(10);
        assert_eq!(ds.metric, Metric::Angular);
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.85, "angular recall {r}");
    }
}
