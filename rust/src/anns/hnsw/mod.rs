//! HNSW — the paper's §2 backbone.
//!
//! * [`graph`] — multi-layer graph storage (flat CSR-style layer 0, sparse
//!   upper layers), entry-point sets, precomputed degree metadata.
//! * [`builder`] — incremental insertion with exponential level sampling,
//!   beam-searched neighbor candidates and diversity-heuristic pruning.
//! * [`search`] — greedy upper-layer descent + layer-0 beam search, with
//!   every §6 search-module knob (multi-tier entries, edge batching,
//!   prefetch, early termination).
//! * [`select`] — the neighbor-selection heuristic shared by build & prune.

pub mod builder;
pub mod graph;
pub mod search;
pub mod select;

pub use graph::HnswGraph;

use crate::anns::scratch::ScratchPool;
use crate::anns::{AnnIndex, VectorSet};
use crate::variants::{ConstructionKnobs, SearchKnobs};

/// A built HNSW index with an attached search configuration.
///
/// Per-query state comes from the shared
/// [`ScratchPool`]: a single RAII checkout per
/// query (or per *batch* — [`AnnIndex::search_batch`] drives every query
/// in a batch through one pooled [`search::SearchContext`]).
pub struct HnswIndex {
    pub graph: HnswGraph,
    pub knobs: SearchKnobs,
    label: String,
    scratch: ScratchPool,
}

impl HnswIndex {
    /// Build from vectors with the given construction/search knobs.
    pub fn build(
        vs: VectorSet,
        construction: &ConstructionKnobs,
        search_knobs: SearchKnobs,
        seed: u64,
    ) -> Self {
        let graph = builder::build(vs, construction, seed);
        HnswIndex {
            graph,
            knobs: search_knobs,
            label: "hnsw".to_string(),
            scratch: ScratchPool::new(),
        }
    }

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }
}

impl AnnIndex for HnswIndex {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.graph.len());
        search::search(&self.graph, &self.knobs, &mut ctx, query, k, ef)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        // One scratch checkout for the whole batch; each search fully
        // resets the context, so results are bitwise identical to the
        // per-query path.
        let mut ctx = self.scratch.checkout(self.graph.len());
        queries
            .iter()
            .map(|q| search::search(&self.graph, &self.knobs, &mut ctx, q, k, ef))
            .collect()
    }

    fn len(&self) -> usize {
        self.graph.len()
    }

    fn memory_bytes(&self) -> usize {
        self.graph.memory_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;
    use crate::distance::Metric;

    fn small_dataset() -> crate::dataset::Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1500, 50, 3);
        ds.compute_ground_truth(10);
        ds
    }

    fn recall_of(index: &dyn AnnIndex, ds: &crate::dataset::Dataset, ef: usize) -> f64 {
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = index.search(ds.query_vec(qi), 10, ef);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        acc / ds.n_queries() as f64
    }

    #[test]
    fn hnsw_reaches_high_recall() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.9, "recall@10 ef=128 was {r}");
    }

    #[test]
    fn recall_monotone_in_ef() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let lo = recall_of(&idx, &ds, 10);
        let hi = recall_of(&idx, &ds, 200);
        assert!(hi >= lo, "lo={lo} hi={hi}");
        assert!(hi > 0.95, "hi={hi}");
    }

    #[test]
    fn search_deterministic() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::crinn_discovered(),
            7,
        );
        for qi in 0..5 {
            let a = idx.search(ds.query_vec(qi), 10, 64);
            let b = idx.search(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn crinn_knobs_do_not_break_recall() {
        let ds = small_dataset();
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::crinn_discovered(),
            SearchKnobs::crinn_discovered(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.9, "crinn-knob recall@10 was {r}");
    }

    #[test]
    fn angular_metric_works() {
        let sp = synth::spec("glove-25-angular").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 5);
        ds.compute_ground_truth(10);
        assert_eq!(ds.metric, Metric::Angular);
        let idx = HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &ConstructionKnobs::default(),
            SearchKnobs::default(),
            7,
        );
        let r = recall_of(&idx, &ds, 128);
        assert!(r > 0.85, "angular recall {r}");
    }
}
