//! Shared search-scratch pool.
//!
//! Every index needs per-query mutable state — an epoch visited set, a
//! frontier heap, gather/distance buffers — that is expensive to allocate
//! per query and must not be shared between concurrent queries. Before the
//! batch-first refactor each index kept its own private
//! `Mutex<Vec<SearchContext>>` (HNSW and GLASS duplicated the exact
//! checkout/checkin code; IVF, Vamana and NNDescent allocated per query).
//! [`ScratchPool`] is the one implementation they all share now.
//!
//! Discipline:
//! * **Single guard scope.** [`ScratchPool::checkout`] returns a RAII
//!   [`Scratch`] guard; checkin is its `Drop`. Callers can no longer leak a
//!   context on an early return or panic, and the old two-statement
//!   pop/push pattern (one mutex round-trip at each end of every search)
//!   collapses into one checkout whose lock is held only for the `pop` —
//!   [`SearchContext::ensure`] growth runs after the guard is released, so
//!   a cold resize never blocks other queries.
//! * **One checkout per batch.** `search_batch` implementations check out
//!   a single context and drive every query in the batch through it, so
//!   pool traffic amortizes to one checkout/checkin pair per *batch*
//!   instead of two mutex round-trips per *query*. Contexts fully reset
//!   per search (epoch-cleared visited set, cleared heaps/buffers), which
//!   is what makes batch results bitwise identical to per-query results.

use crate::anns::hnsw::search::SearchContext;
use std::ops::{Deref, DerefMut};
use std::sync::Mutex;

/// A pool of reusable [`SearchContext`]s, shared by every index type.
#[derive(Default)]
pub struct ScratchPool {
    pool: Mutex<Vec<SearchContext>>,
}

impl ScratchPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Check out a context grown to cover `n` nodes. The pool lock is held
    /// only for the `pop`; creation/growth happens outside it. The context
    /// returns to the pool when the guard drops.
    pub fn checkout(&self, n: usize) -> Scratch<'_> {
        let ctx = self.pool.lock().unwrap().pop();
        let mut ctx = ctx.unwrap_or_else(|| SearchContext::new(n));
        ctx.ensure(n);
        Scratch {
            pool: self,
            ctx: Some(ctx),
        }
    }

    /// Number of idle contexts (tests/metrics).
    pub fn idle(&self) -> usize {
        self.pool.lock().unwrap().len()
    }
}

/// RAII checkout of a [`SearchContext`]; derefs to the context and checks
/// it back in on drop.
pub struct Scratch<'a> {
    pool: &'a ScratchPool,
    ctx: Option<SearchContext>,
}

impl Deref for Scratch<'_> {
    type Target = SearchContext;
    fn deref(&self) -> &SearchContext {
        self.ctx.as_ref().expect("ctx present until drop")
    }
}

impl DerefMut for Scratch<'_> {
    fn deref_mut(&mut self) -> &mut SearchContext {
        self.ctx.as_mut().expect("ctx present until drop")
    }
}

impl Drop for Scratch<'_> {
    fn drop(&mut self) {
        if let Some(ctx) = self.ctx.take() {
            self.pool.pool.lock().unwrap().push(ctx);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contexts_are_reused_after_checkin() {
        let pool = ScratchPool::new();
        assert_eq!(pool.idle(), 0);
        {
            let _a = pool.checkout(100);
            assert_eq!(pool.idle(), 0);
        }
        assert_eq!(pool.idle(), 1);
        {
            let _a = pool.checkout(100);
            assert_eq!(pool.idle(), 0, "idle context must be reused, not duplicated");
        }
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn concurrent_checkouts_get_distinct_contexts() {
        let pool = ScratchPool::new();
        let mut a = pool.checkout(10);
        let mut b = pool.checkout(10);
        a.batch.push(1);
        b.batch.push(2);
        assert_eq!(a.batch, vec![1]);
        assert_eq!(b.batch, vec![2]);
        drop(a);
        drop(b);
        assert_eq!(pool.idle(), 2);
    }

    #[test]
    fn checkout_grows_visited_set() {
        let pool = ScratchPool::new();
        {
            let _small = pool.checkout(10);
        }
        let mut big = pool.checkout(1000);
        // Insert near the top of the grown range — would panic if `ensure`
        // had not resized the recycled context.
        big.visited.clear();
        assert!(big.visited.insert(999));
    }
}
