//! Index persistence: save/load built GLASS/HNSW indexes.
//!
//! A deployment builds once and serves many times — ann-benchmarks and
//! every production store persist their graphs. Format: a little-endian
//! binary container (`CRNN` magic + version) carrying the vector set, the
//! layered graph, the quantized codes, and the variant configuration
//! (encoded through the same action space the RL uses, which keeps the
//! format stable as knobs evolve).

use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::VectorSet;
use crate::distance::quant::QuantizedStore;
use crate::distance::Metric;
use crate::variants::{decode_action, encode_action, Module, VariantConfig};
use crate::bail;
use crate::util::error::{Context, Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CRNN";
const VERSION: u32 = 1;

struct W<'a, T: Write>(&'a mut T);

impl<'a, T: Write> W<'a, T> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u8s(&mut self, v: &[u8]) -> Result<()> {
        self.u64(v.len() as u64)?;
        self.0.write_all(v)?;
        Ok(())
    }
}

struct R<'a, T: Read> {
    inner: &'a mut T,
    /// Total file size in bytes — the sanity cap for every `u64` length
    /// field. A valid field can never describe more payload than the file
    /// holds, so anything larger is corruption (or a hostile header) and
    /// must return `Err` instead of feeding `vec![0u8; huge]` and
    /// OOM-aborting the process.
    limit: u64,
}

impl<'a, T: Read> R<'a, T> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    /// Read a `u64` element count and validate it against the file size
    /// (overflow-checked multiply by the per-element byte width) before any
    /// allocation sized by it.
    fn len(&mut self, elem_bytes: u64) -> Result<usize> {
        let n = self.u64()?;
        let bytes = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| Error::msg(format!("corrupt index: length field {n} overflows")))?;
        crate::ensure!(
            bytes <= self.limit,
            "corrupt index: length field {n} ({bytes} bytes) exceeds file size {}",
            self.limit
        );
        Ok(n as usize)
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.len(1)?;
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
}

/// Save a built GLASS index (graph + codes + config) to `path`.
pub fn save_glass(idx: &crate::anns::glass::GlassIndex, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut bw = BufWriter::new(f);
    let mut w = W(&mut bw);
    w.0.write_all(MAGIC)?;
    w.u32(VERSION)?;
    // Vector set.
    let g = &idx.graph;
    w.u32(g.vectors.dim as u32)?;
    w.u32(match g.vectors.metric {
        Metric::L2 => 0,
        Metric::Angular => 1,
        Metric::Ip => 2,
    })?;
    w.f32s(&g.vectors.data)?;
    // Graph.
    w.u32(g.m as u32)?;
    w.u32(g.entry)?;
    w.u32(g.max_level as u32)?;
    w.u8s(&g.levels)?;
    w.u32s(&g.layer0)?;
    w.u32s(&g.entry_points)?;
    w.u32(g.upper.len() as u32)?;
    for layer in &g.upper {
        w.u64(layer.len() as u64)?;
        // Deterministic output: sort by node id.
        let mut keys: Vec<u32> = layer.keys().copied().collect();
        keys.sort_unstable();
        for k in keys {
            w.u32(k)?;
            w.u32s(&layer[&k])?;
        }
    }
    // Config (via the stable action encoding).
    for module in Module::ALL {
        let a = encode_action(&idx.config, module);
        w.u64(a.len() as u64)?;
        for v in a {
            w.f64(v)?;
        }
    }
    bw.flush()?;
    Ok(())
}

/// Load a GLASS index saved with [`save_glass`]. Codes and degree
/// metadata are rebuilt from the payload (cheaper than storing them and
/// immune to quantizer-version drift).
pub fn load_glass(path: &Path) -> Result<crate::anns::glass::GlassIndex> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let limit = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut br = BufReader::new(f);
    let mut r = R { inner: &mut br, limit };
    let mut magic = [0u8; 4];
    r.inner.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a CRINN index file");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported index version {version}");
    }
    let dim = r.u32()? as usize;
    let metric = match r.u32()? {
        0 => Metric::L2,
        1 => Metric::Angular,
        2 => Metric::Ip,
        m => bail!("bad metric tag {m}"),
    };
    let data = r.f32s()?;
    let vs = VectorSet::new(data, dim, metric);

    let m = r.u32()? as usize;
    let entry = r.u32()?;
    let max_level = r.u32()? as u8;
    let levels = r.u8s()?;
    let layer0 = r.u32s()?;
    let entry_points = r.u32s()?;
    let n_layers = r.u32()? as usize;

    let quant = QuantizedStore::build(&vs.data, dim);
    let mut graph = HnswGraph::new(vs, m);
    crate::ensure!(graph.layer0.len() == layer0.len(), "layer0 size mismatch");
    graph.layer0 = layer0;
    graph.levels = levels;
    graph.entry = entry;
    graph.max_level = max_level;
    graph.entry_points = entry_points;
    // Rebuild degree metadata from the sentinel layout.
    for i in 0..graph.len() as u32 {
        graph.degree0[i as usize] = graph.neighbors0_scan(i).len() as u16;
    }
    for l in 0..n_layers {
        // Each upper-layer entry is at least 12 bytes (u32 key + u64 len).
        let count = r.len(12)?;
        for _ in 0..count {
            let k = r.u32()?;
            let nbs = r.u32s()?;
            graph.set_neighbors_upper((l + 1) as u8, k, nbs);
        }
    }
    // Config.
    let mut config = VariantConfig::glass_baseline();
    for module in Module::ALL {
        let len = r.len(8)?;
        let mut a = Vec::with_capacity(len);
        for _ in 0..len {
            a.push(r.f64()?);
        }
        config = decode_action(&config, module, &a);
    }
    graph
        .validate()
        .map_err(|e| Error::msg(format!("loaded graph invalid: {e}")))?;
    Ok(crate::anns::glass::GlassIndex::from_parts(graph, quant, config))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::glass::GlassIndex;
    use crate::anns::AnnIndex;
    use crate::dataset::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn glass_roundtrip_identical_results() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 77);
        ds.compute_ground_truth(10);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("roundtrip.idx");
        save_glass(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(loaded.len(), idx.len());
        for qi in 0..ds.n_queries() {
            let a = idx.search(ds.query_vec(qi), 10, 64);
            let b = loaded.search(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b, "query {qi} diverged after reload");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.idx");
        std::fs::write(&path, b"not an index").unwrap();
        assert!(load_glass(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_file() {
        // A valid index cut off at various points must error cleanly (no
        // panic, no abort) — both mid-payload and mid-length-field.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 79);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let path = tmp("truncated.idx");
        save_glass(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for frac in [0.05, 0.3, 0.6, 0.95] {
            let cut = (full.len() as f64 * frac) as usize;
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(load_glass(&path).is_err(), "truncated at {cut}/{} loaded", full.len());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_huge_length_fields() {
        // A hostile header whose u64 length field dwarfs the file must be
        // rejected by the file-size sanity cap before any allocation — the
        // old code fed it straight to `vec![0u8; n * 4]` and OOM-aborted.
        // Also cover the overflow case where `n * 4` wraps u64.
        for huge in [u64::MAX, u64::MAX / 2, 1u64 << 40] {
            let mut f = Vec::new();
            f.extend_from_slice(MAGIC);
            f.extend_from_slice(&VERSION.to_le_bytes());
            f.extend_from_slice(&64u32.to_le_bytes()); // dim
            f.extend_from_slice(&0u32.to_le_bytes()); // metric = L2
            f.extend_from_slice(&huge.to_le_bytes()); // f32s length field
            let path = tmp(&format!("hugelen_{huge:x}.idx"));
            std::fs::write(&path, &f).unwrap();
            let err = load_glass(&path);
            assert!(err.is_err(), "length {huge} accepted");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(msg.contains("corrupt index"), "unexpected error: {msg}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn config_survives_roundtrip() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 78);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("config.idx");
        save_glass(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(
            loaded.config.search.early_termination,
            idx.config.search.early_termination
        );
        assert_eq!(loaded.config.construction.m, idx.config.construction.m);
        assert_eq!(
            loaded.config.refine.precomputed_metadata,
            idx.config.refine.precomputed_metadata
        );
        std::fs::remove_file(&path).ok();
    }
}
