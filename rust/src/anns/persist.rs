//! Index persistence: save/load built GLASS/HNSW indexes.
//!
//! A deployment builds once and serves many times — ann-benchmarks and
//! every production store persist their graphs. Format: a little-endian
//! binary container (`CRNN` magic + version) carrying the vector set, the
//! layered graph, the quantized codes, and the variant configuration
//! (encoded through the same action space the RL uses, which keeps the
//! format stable as knobs evolve).

use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::VectorSet;
use crate::distance::quant::QuantizedStore;
use crate::distance::Metric;
use crate::variants::{decode_action, encode_action, Module, VariantConfig};
use crate::bail;
use crate::util::error::{Context, Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"CRNN";
const VERSION: u32 = 1;

struct W<'a, T: Write>(&'a mut T);

impl<'a, T: Write> W<'a, T> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u8s(&mut self, v: &[u8]) -> Result<()> {
        self.u64(v.len() as u64)?;
        self.0.write_all(v)?;
        Ok(())
    }
}

struct R<'a, T: Read>(&'a mut T);

impl<'a, T: Read> R<'a, T> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.0.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.0.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let mut raw = vec![0u8; n * 4];
        self.0.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u64()? as usize;
        let mut raw = vec![0u8; n * 4];
        self.0.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.u64()? as usize;
        let mut v = vec![0u8; n];
        self.0.read_exact(&mut v)?;
        Ok(v)
    }
}

/// Save a built GLASS index (graph + codes + config) to `path`.
pub fn save_glass(idx: &crate::anns::glass::GlassIndex, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut bw = BufWriter::new(f);
    let mut w = W(&mut bw);
    w.0.write_all(MAGIC)?;
    w.u32(VERSION)?;
    // Vector set.
    let g = &idx.graph;
    w.u32(g.vectors.dim as u32)?;
    w.u32(match g.vectors.metric {
        Metric::L2 => 0,
        Metric::Angular => 1,
        Metric::Ip => 2,
    })?;
    w.f32s(&g.vectors.data)?;
    // Graph.
    w.u32(g.m as u32)?;
    w.u32(g.entry)?;
    w.u32(g.max_level as u32)?;
    w.u8s(&g.levels)?;
    w.u32s(&g.layer0)?;
    w.u32s(&g.entry_points)?;
    w.u32(g.upper.len() as u32)?;
    for layer in &g.upper {
        w.u64(layer.len() as u64)?;
        // Deterministic output: sort by node id.
        let mut keys: Vec<u32> = layer.keys().copied().collect();
        keys.sort_unstable();
        for k in keys {
            w.u32(k)?;
            w.u32s(&layer[&k])?;
        }
    }
    // Config (via the stable action encoding).
    for module in Module::ALL {
        let a = encode_action(&idx.config, module);
        w.u64(a.len() as u64)?;
        for v in a {
            w.f64(v)?;
        }
    }
    bw.flush()?;
    Ok(())
}

/// Load a GLASS index saved with [`save_glass`]. Codes and degree
/// metadata are rebuilt from the payload (cheaper than storing them and
/// immune to quantizer-version drift).
pub fn load_glass(path: &Path) -> Result<crate::anns::glass::GlassIndex> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut br = BufReader::new(f);
    let mut r = R(&mut br);
    let mut magic = [0u8; 4];
    r.0.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a CRINN index file");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported index version {version}");
    }
    let dim = r.u32()? as usize;
    let metric = match r.u32()? {
        0 => Metric::L2,
        1 => Metric::Angular,
        2 => Metric::Ip,
        m => bail!("bad metric tag {m}"),
    };
    let data = r.f32s()?;
    let vs = VectorSet::new(data, dim, metric);

    let m = r.u32()? as usize;
    let entry = r.u32()?;
    let max_level = r.u32()? as u8;
    let levels = r.u8s()?;
    let layer0 = r.u32s()?;
    let entry_points = r.u32s()?;
    let n_layers = r.u32()? as usize;

    let quant = QuantizedStore::build(&vs.data, dim);
    let mut graph = HnswGraph::new(vs, m);
    crate::ensure!(graph.layer0.len() == layer0.len(), "layer0 size mismatch");
    graph.layer0 = layer0;
    graph.levels = levels;
    graph.entry = entry;
    graph.max_level = max_level;
    graph.entry_points = entry_points;
    // Rebuild degree metadata from the sentinel layout.
    for i in 0..graph.len() as u32 {
        graph.degree0[i as usize] = graph.neighbors0_scan(i).len() as u16;
    }
    for l in 0..n_layers {
        let count = r.u64()? as usize;
        for _ in 0..count {
            let k = r.u32()?;
            let nbs = r.u32s()?;
            graph.set_neighbors_upper((l + 1) as u8, k, nbs);
        }
    }
    // Config.
    let mut config = VariantConfig::glass_baseline();
    for module in Module::ALL {
        let len = r.u64()? as usize;
        let mut a = Vec::with_capacity(len);
        for _ in 0..len {
            a.push(r.f64()?);
        }
        config = decode_action(&config, module, &a);
    }
    graph
        .validate()
        .map_err(|e| Error::msg(format!("loaded graph invalid: {e}")))?;
    Ok(crate::anns::glass::GlassIndex::from_parts(graph, quant, config))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::glass::GlassIndex;
    use crate::anns::AnnIndex;
    use crate::dataset::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn glass_roundtrip_identical_results() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 77);
        ds.compute_ground_truth(10);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("roundtrip.idx");
        save_glass(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(loaded.len(), idx.len());
        for qi in 0..ds.n_queries() {
            let a = idx.search(ds.query_vec(qi), 10, 64);
            let b = loaded.search(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b, "query {qi} diverged after reload");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.idx");
        std::fs::write(&path, b"not an index").unwrap();
        assert!(load_glass(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn config_survives_roundtrip() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 78);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("config.idx");
        save_glass(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(
            loaded.config.search.early_termination,
            idx.config.search.early_termination
        );
        assert_eq!(loaded.config.construction.m, idx.config.construction.m);
        assert_eq!(
            loaded.config.refine.precomputed_metadata,
            idx.config.refine.precomputed_metadata
        );
        std::fs::remove_file(&path).ok();
    }
}
