//! Vamana baseline (DiskANN's graph; ParlayANN's flagship implementation).
//!
//! Construction: two passes over a random insertion order; each node beam-
//! searches from the medoid, then RobustPrune(α) selects its out-edges;
//! reverse edges are added with the same pruning rule. α > 1 keeps longer
//! "highway" edges that cut hop counts — the property that makes
//! Vamana/ParlayANN fast at high recall.
//!
//! Search: single-layer beam from the medoid (no hierarchy).

use crate::anns::filter::{Admit, FilterBitset, DEFAULT_FILTERED_FALLBACK};
use crate::anns::heap::{dist_cmp, MinQueue, TopK};
use crate::anns::scratch::ScratchPool;
use crate::anns::visited::VisitedSet;
use crate::anns::{AnnIndex, MutableAnnIndex, VectorSet};
use crate::util::rng::Rng;

/// Build parameters (ParlayANN-ish defaults).
#[derive(Clone, Debug)]
pub struct VamanaParams {
    /// Graph degree bound R.
    pub degree: usize,
    /// Construction beam width L.
    pub build_beam: usize,
    /// RobustPrune slack α.
    pub alpha: f32,
    /// Number of passes.
    pub passes: usize,
}

impl Default for VamanaParams {
    fn default() -> Self {
        VamanaParams {
            degree: 32,
            build_beam: 128,
            alpha: 1.2,
            passes: 2,
        }
    }
}

/// Built Vamana index.
pub struct VamanaIndex {
    pub vectors: VectorSet,
    /// Flat `[n * degree]` adjacency, `u32::MAX` padded.
    graph: Vec<u32>,
    /// Cached out-degrees (computed once at build; §Perf: recomputing per
    /// query cost ~35% of query time at n=8k).
    degrees: Vec<u16>,
    degree: usize,
    medoid: u32,
    scratch: ScratchPool,
    /// Selectivity crossover for filtered search (see
    /// [`AnnIndex::filtered_fallback_threshold`]).
    filtered_fallback: usize,
}

const NONE: u32 = u32::MAX;

impl VamanaIndex {
    pub fn build(vectors: VectorSet, params: VamanaParams, seed: u64) -> Self {
        let n = vectors.len();
        let r = params.degree.max(4);
        let mut graph = vec![NONE; n * r];
        let mut degrees = vec![0u16; n];
        if n == 0 {
            return VamanaIndex {
                vectors,
                graph,
                degrees: Vec::new(),
                degree: r,
                medoid: 0,
                scratch: ScratchPool::new(),
                filtered_fallback: DEFAULT_FILTERED_FALLBACK,
            };
        }
        let mut rng = Rng::new(seed ^ 0xABBA);

        // Medoid approximation: the sampled point nearest the sample mean.
        let medoid = approx_medoid(&vectors, &mut rng);

        // Random initial graph.
        for i in 0..n {
            let mut got = 0;
            while got < r.min(n - 1).min(8) {
                let c = rng.next_below(n) as u32;
                if c as usize != i
                    && !graph[i * r..i * r + got].contains(&c)
                {
                    graph[i * r + got] = c;
                    got += 1;
                }
            }
            degrees[i] = got as u16;
        }

        let mut visited = VisitedSet::new(n);
        let mut frontier = MinQueue::with_capacity(params.build_beam * 2);
        let mut order: Vec<u32> = (0..n as u32).collect();

        for _pass in 0..params.passes {
            rng.shuffle(&mut order);
            for &i in &order {
                // Beam search for the candidate pool.
                let pool = beam_from(
                    &vectors,
                    &graph,
                    &degrees,
                    r,
                    medoid,
                    vectors.vec(i),
                    params.build_beam,
                    &mut visited,
                    &mut frontier,
                );
                let cands: Vec<(f32, u32)> =
                    pool.into_iter().filter(|&(_, c)| c != i).collect();
                let chosen = crate::anns::hnsw::select::select_heuristic(
                    &vectors,
                    &cands,
                    r,
                    params.alpha,
                    true,
                );
                set_neighbors(&mut graph, &mut degrees, r, i, &chosen);
                // Reverse edges with pruning on overflow.
                for &nb in &chosen {
                    add_reverse(&vectors, &mut graph, &mut degrees, r, nb, i, params.alpha);
                }
            }
        }

        VamanaIndex {
            degrees: degrees.clone(),
            vectors,
            graph,
            degree: r,
            medoid,
            scratch: ScratchPool::new(),
            filtered_fallback: DEFAULT_FILTERED_FALLBACK,
        }
    }

    /// Tune the selectivity crossover: filters with at most this many
    /// matching ids take the exact-scan fallback instead of the beam.
    pub fn set_filtered_fallback(&mut self, threshold: usize) {
        self.filtered_fallback = threshold;
    }

    #[inline]
    /// Out-neighbors of node `i` (public for inspection/tests).
    pub fn neighbors(&self, i: u32) -> &[u32] {
        let s = &self.graph[i as usize * self.degree..(i as usize + 1) * self.degree];
        let mut d = 0;
        while d < s.len() && s[d] != NONE {
            d += 1;
        }
        &s[..d]
    }
}

fn approx_medoid(vs: &VectorSet, rng: &mut Rng) -> u32 {
    let n = vs.len();
    let sample = rng.sample_indices(n, n.min(256));
    let dim = vs.dim;
    let mut mean = vec![0f32; dim];
    for &i in &sample {
        for (m, v) in mean.iter_mut().zip(vs.vec(i as u32)) {
            *m += v;
        }
    }
    for m in mean.iter_mut() {
        *m /= sample.len() as f32;
    }
    sample
        .iter()
        .map(|&i| (vs.metric.distance(&mean, vs.vec(i as u32)), i as u32))
        .min_by(|a, b| dist_cmp(a, b))
        .map(|(_, i)| i)
        .unwrap_or(0)
}

#[allow(clippy::too_many_arguments)]
fn beam_from(
    vs: &VectorSet,
    graph: &[u32],
    degrees: &[u16],
    r: usize,
    entry: u32,
    q: &[f32],
    beam: usize,
    visited: &mut VisitedSet,
    frontier: &mut MinQueue,
) -> Vec<(f32, u32)> {
    beam_from_admit(
        vs,
        graph,
        degrees,
        r,
        entry,
        q,
        beam,
        visited,
        frontier,
        &Admit::none(),
    )
}

/// [`beam_from`] under an admission predicate: non-matching nodes stay
/// traversable (they extend the frontier) but never enter the result pool
/// — the same discipline as the HNSW/GLASS shared beam. `Admit::none()`
/// keeps construction and unfiltered search on the exact pre-filter path.
#[allow(clippy::too_many_arguments)]
fn beam_from_admit(
    vs: &VectorSet,
    graph: &[u32],
    degrees: &[u16],
    r: usize,
    entry: u32,
    q: &[f32],
    beam: usize,
    visited: &mut VisitedSet,
    frontier: &mut MinQueue,
    admit: &Admit<'_>,
) -> Vec<(f32, u32)> {
    visited.clear();
    frontier.clear();
    let mut results = TopK::new(beam.max(1));
    let d0 = vs.distance(q, entry);
    visited.insert(entry);
    frontier.push(d0, entry);
    if admit.allows(entry) {
        results.push(d0, entry);
    }
    while let Some((d, u)) = frontier.pop() {
        if d > results.bound() {
            break;
        }
        let deg = degrees[u as usize] as usize;
        for &nb in &graph[u as usize * r..u as usize * r + deg] {
            if !visited.insert(nb) {
                continue;
            }
            let dnb = vs.distance(q, nb);
            if dnb < results.bound() {
                if admit.allows(nb) {
                    results.push(dnb, nb);
                }
                frontier.push(dnb, nb);
            }
        }
    }
    results.into_sorted()
}

fn set_neighbors(graph: &mut [u32], degrees: &mut [u16], r: usize, i: u32, chosen: &[u32]) {
    let i = i as usize;
    for (slot, nb) in graph[i * r..(i + 1) * r]
        .iter_mut()
        .zip(chosen.iter().chain(std::iter::repeat(&NONE)))
    {
        *slot = *nb;
    }
    degrees[i] = chosen.len().min(r) as u16;
}

fn add_reverse(
    vs: &VectorSet,
    graph: &mut [u32],
    degrees: &mut [u16],
    r: usize,
    from: u32,
    to: u32,
    alpha: f32,
) {
    let fi = from as usize;
    let deg = degrees[fi] as usize;
    if graph[fi * r..fi * r + deg].contains(&to) {
        return;
    }
    if deg < r {
        graph[fi * r + deg] = to;
        degrees[fi] = (deg + 1) as u16;
    } else {
        let current: Vec<u32> = graph[fi * r..fi * r + deg].to_vec();
        let pruned = crate::anns::hnsw::select::reprune(vs, from, &current, to, r, alpha);
        set_neighbors(graph, degrees, r, from, &pruned);
    }
}

impl VamanaIndex {
    /// One beam search with caller-provided scratch — the shared body of
    /// the (filtered and unfiltered) search and batch entry points.
    /// `filter = None` is exactly the pre-filter path (Vamana is static,
    /// so the admission predicate is the filter alone).
    fn search_one(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        ctx: &mut crate::anns::hnsw::search::SearchContext,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        if self.vectors.is_empty() {
            return Vec::new();
        }
        if let Some(f) = filter {
            if f.count() <= self.filtered_fallback {
                return crate::anns::filtered_exact_fallback(
                    &self.vectors,
                    query,
                    k,
                    &mut ctx.batch,
                    &mut ctx.dists,
                    None,
                    f,
                );
            }
        }
        let mut out = beam_from_admit(
            &self.vectors,
            &self.graph,
            &self.degrees,
            self.degree,
            self.medoid,
            query,
            ef.max(k),
            &mut ctx.visited,
            &mut ctx.frontier,
            &Admit {
                deleted: None,
                filter,
            },
        );
        out.truncate(k);
        out
    }
}

impl AnnIndex for VamanaIndex {
    fn name(&self) -> String {
        "parlayann".to_string()
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        self.search_one(query, k, ef, &mut ctx, None)
    }

    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, None))
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        self.search_one(query, k, ef, &mut ctx, filter)
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let mut ctx = self.scratch.checkout(self.vectors.len());
        queries
            .iter()
            .map(|q| self.search_one(q, k, ef, &mut ctx, filter))
            .collect()
    }

    fn filtered_fallback_threshold(&self) -> usize {
        self.filtered_fallback
    }

    fn len(&self) -> usize {
        self.vectors.len()
    }

    fn memory_bytes(&self) -> usize {
        self.vectors.data.len() * 4 + self.graph.len() * 4
    }
}

/// Vamana does not support online mutation yet: its RobustPrune(α)
/// highway edges assume the two-pass batch build, and FreshDiskANN-style
/// streaming inserts for it are a project of their own. Every mutating
/// method reports `Unsupported` so the coordinator's uniform update path
/// fails the request instead of the process; the read-side accessors fall
/// back to the static defaults (everything live).
impl MutableAnnIndex for VamanaIndex {
    fn insert(&mut self, _vec: &[f32]) -> crate::Result<u32> {
        crate::bail!("Unsupported: vamana does not implement online insert (rebuild instead)")
    }

    fn delete(&mut self, _id: u32) -> crate::Result<()> {
        crate::bail!("Unsupported: vamana does not implement delete (rebuild instead)")
    }

    fn consolidate(&mut self) -> crate::Result<usize> {
        crate::bail!("Unsupported: vamana does not implement consolidate")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn vamana_reaches_good_recall() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1000, 40, 41);
        ds.compute_ground_truth(10);
        let idx = VamanaIndex::build(VectorSet::from_dataset(&ds), VamanaParams::default(), 1);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = idx.search(ds.query_vec(qi), 10, 128);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "vamana recall {recall}");
    }

    #[test]
    fn filtered_vamana_beam_and_fallback_paths() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 900, 8, 43);
        let mut idx = VamanaIndex::build(VectorSet::from_dataset(&ds), VamanaParams::default(), 1);
        let n = idx.len() as u32;
        // filter=None is bitwise identical to the unfiltered path.
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            assert_eq!(
                idx.search_filtered_with_dists(q, 10, 96, None),
                idx.search_with_dists(q, 10, 96)
            );
        }
        // A wide filter (beam path): every result matches.
        let third = FilterBitset::from_predicate(n as usize, |id| id % 3 == 0);
        assert!(third.count() > idx.filtered_fallback);
        for qi in 0..ds.n_queries() {
            let found = idx.search_filtered(ds.query_vec(qi), 10, 96, Some(&third));
            assert!(!found.is_empty());
            assert!(found.iter().all(|&id| id % 3 == 0), "leak in {found:?}");
        }
        // A rare filter routes to the exact fallback and equals the oracle.
        let rare = FilterBitset::from_predicate(n as usize, |id| id % 90 == 0);
        assert!(rare.count() <= idx.filtered_fallback);
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            let want = crate::dataset::gt::topk_pairs_for_query_filtered(
                &idx.vectors.data,
                q,
                idx.vectors.dim,
                idx.vectors.metric,
                5,
                &mut ids,
                &mut dists,
                |i| rare.matches(i),
            );
            assert_eq!(idx.search_filtered_with_dists(q, 5, 96, Some(&rare)), want);
        }
        // Forcing the beam path on the rare filter still never leaks.
        idx.set_filtered_fallback(0);
        for qi in 0..ds.n_queries() {
            let found = idx.search_filtered(ds.query_vec(qi), 5, 96, Some(&rare));
            assert!(found.iter().all(|&id| id % 90 == 0));
        }
        idx.set_filtered_fallback(DEFAULT_FILTERED_FALLBACK);
        // Filtered batch == filtered per-query.
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
        for f in [None, Some(&third), Some(&rare)] {
            let batched = idx.search_filtered_batch(&queries, 10, 96, f);
            for (qi, q) in queries.iter().enumerate() {
                assert_eq!(batched[qi], idx.search_filtered_with_dists(q, 10, 96, f));
            }
        }
    }

    #[test]
    fn degrees_bounded() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 500, 10, 42);
        let idx = VamanaIndex::build(VectorSet::from_dataset(&ds), VamanaParams::default(), 2);
        for i in 0..500u32 {
            assert!(idx.neighbors(i).len() <= idx.degree);
            assert!(!idx.neighbors(i).contains(&i), "self loop at {i}");
        }
    }

    #[test]
    fn deterministic_build() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 10, 43);
        let a = VamanaIndex::build(VectorSet::from_dataset(&ds), VamanaParams::default(), 7);
        let b = VamanaIndex::build(VectorSet::from_dataset(&ds), VamanaParams::default(), 7);
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.medoid, b.medoid);
    }
}
