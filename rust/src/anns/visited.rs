//! Epoch-stamped visited set.
//!
//! The classic ANNS trick: instead of clearing a bitset per query (O(n)) or
//! hashing (cache-hostile), keep a `u32` stamp per node and bump the epoch
//! each query. This sits on the innermost search loop — one of the §Perf
//! targets (vs. `HashSet`, measured in `benches/micro_graph`).

/// Visited-set with O(1) reset.
#[derive(Clone, Debug)]
pub struct VisitedSet {
    stamps: Vec<u32>,
    epoch: u32,
}

impl VisitedSet {
    pub fn new(n: usize) -> Self {
        VisitedSet {
            stamps: vec![0; n],
            epoch: 0,
        }
    }

    /// Start a new query. O(1) except on epoch wraparound (every 2^32).
    #[inline]
    pub fn clear(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.stamps.fill(0);
            self.epoch = 1;
        }
    }

    /// Mark `i`; returns true if it was not yet visited this epoch.
    #[inline]
    pub fn insert(&mut self, i: u32) -> bool {
        let s = &mut self.stamps[i as usize];
        if *s == self.epoch {
            false
        } else {
            *s = self.epoch;
            true
        }
    }

    /// Check without marking.
    #[inline]
    pub fn contains(&self, i: u32) -> bool {
        self.stamps[i as usize] == self.epoch
    }

    /// Number of nodes visited this epoch. O(n) scan — for tests and
    /// search statistics, not the hot path.
    pub fn count(&self) -> usize {
        self.stamps.iter().filter(|&&s| s == self.epoch).count()
    }

    /// Grow to accommodate `n` nodes (incremental insertion).
    pub fn resize(&mut self, n: usize) {
        if n > self.stamps.len() {
            self.stamps.resize(n, 0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_and_reset() {
        let mut v = VisitedSet::new(8);
        v.clear();
        assert!(v.insert(3));
        assert!(!v.insert(3));
        assert!(v.contains(3));
        assert!(!v.contains(4));
        assert_eq!(v.count(), 1);
        v.clear();
        assert!(!v.contains(3));
        assert_eq!(v.count(), 0);
        assert!(v.insert(3));
    }

    #[test]
    fn epoch_wraparound_is_correct() {
        let mut v = VisitedSet::new(4);
        v.epoch = u32::MAX - 1;
        v.clear(); // -> MAX
        assert!(v.insert(0));
        v.clear(); // wraps -> full reset to epoch 1
        assert_eq!(v.epoch, 1);
        assert!(!v.contains(0));
        assert!(v.insert(0));
    }

    #[test]
    fn resize_preserves_semantics() {
        let mut v = VisitedSet::new(2);
        v.clear();
        v.insert(1);
        v.resize(10);
        assert!(v.contains(1));
        assert!(v.insert(9));
    }
}
