//! Durable restore and compaction: the glue between the paged snapshot
//! (`anns::persist`) and the mutation log ([`super::wal::VectorLog`]).
//!
//! Restart is **map the snapshot, replay the log tail**: the snapshot
//! persists the insert-level RNG state and the free-slot list, so
//! replaying the logged mutations in order reproduces *exactly* the ids
//! and graph the live index had — [`restore_glass`] asserts the replayed
//! id of every logged insert against the id the log recorded at ack
//! time, and refuses a snapshot/log pair that disagrees.
//!
//! Compaction ([`compact_glass`]) folds the log into the snapshot:
//! consolidate tombstones, write a fresh v3 snapshot, truncate the log.
//! The snapshot write lands before the truncate, so a crash between the
//! two leaves a log whose replay fails loudly (id mismatch against the
//! already-folded snapshot) rather than one that silently lost acked
//! mutations.

use super::wal::{LogRecord, VectorLog};
use crate::anns::glass::GlassIndex;
use crate::anns::{MetadataStore, MutableAnnIndex};
use crate::util::error::{Context, Result};
use std::path::Path;

/// A restored serving state: the index with the log tail replayed, its
/// metadata store, and the recovered log handle positioned for further
/// appends.
pub struct RestoredGlass {
    pub index: GlassIndex,
    pub metadata: MetadataStore,
    pub log: VectorLog,
    /// Log records replayed on top of the snapshot.
    pub replayed: usize,
}

/// Restore a serving state from `snapshot` + `log_path`. `mmap` selects
/// zero-copy serving of the snapshot's big sections (the first replayed
/// insert promotes them copy-on-write). A missing log file is an empty
/// log; a torn log tail is dropped (see [`VectorLog::recover`]).
pub fn restore_glass(snapshot: &Path, log_path: &Path, mmap: bool) -> Result<RestoredGlass> {
    let (mut index, metadata) = if mmap {
        crate::anns::persist::load_glass_mmap_with_metadata(snapshot)
    } else {
        crate::anns::persist::load_glass_with_metadata(snapshot)
    }
    .with_context(|| format!("load snapshot {snapshot:?}"))?;
    let mut metadata = metadata.unwrap_or_default();

    let (records, log) = VectorLog::recover(log_path)?;
    let replayed = records.len();
    for (i, record) in records.into_iter().enumerate() {
        apply_record(&mut index, &mut metadata, &record)
            .with_context(|| format!("replay log record {i} for id {}", record.id()))?;
    }
    Ok(RestoredGlass {
        index,
        metadata,
        log,
        replayed,
    })
}

/// Apply one log record to the restored state. Insert replay must
/// reproduce the id the log recorded — the snapshot carries the RNG and
/// free-list state that makes id assignment deterministic, so a mismatch
/// means the snapshot and log are not a pair.
pub fn apply_record(
    index: &mut GlassIndex,
    metadata: &mut MetadataStore,
    record: &LogRecord,
) -> Result<()> {
    match record {
        LogRecord::Vector { id, vector } => {
            let got = index.insert(vector)?;
            crate::ensure!(
                got == *id,
                "replayed insert assigned id {got} but the log acked id {id} \
                 (snapshot and log are not a matching pair)"
            );
        }
        LogRecord::Metadata { id, tenant, tags } => {
            let tags: Vec<&str> = tags.iter().map(|t| t.as_str()).collect();
            metadata.set_for(*id, tenant.as_deref(), &tags);
        }
        LogRecord::Tombstone { id } => index.delete(*id)?,
    }
    Ok(())
}

/// What [`compact_glass`] folded away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompactionStats {
    /// Tombstoned points physically dropped by consolidation.
    pub dropped: usize,
    /// Log bytes truncated after the snapshot absorbed them.
    pub log_bytes_truncated: u64,
    /// Log records truncated.
    pub log_records_truncated: u64,
}

/// Fold the mutation log into the snapshot: consolidate pending
/// tombstones, write a fresh v3 snapshot (index + metadata) to
/// `snapshot`, then truncate the log. Search results over the live set
/// are preserved — consolidation repairs the graph around dropped
/// points but never changes which points are live.
pub fn compact_glass(
    index: &mut GlassIndex,
    metadata: &MetadataStore,
    log: &mut VectorLog,
    snapshot: &Path,
) -> Result<CompactionStats> {
    let dropped = index.consolidate()?;
    crate::anns::persist::save_glass_with_metadata(index, metadata, snapshot)
        .with_context(|| format!("write compacted snapshot {snapshot:?}"))?;
    let stats = CompactionStats {
        dropped,
        log_bytes_truncated: log.bytes(),
        log_records_truncated: log.records(),
    };
    log.truncate()?;
    Ok(stats)
}
