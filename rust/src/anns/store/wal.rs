//! Append-only mutation log ([`VectorLog`]): the durability half of the
//! storage tier. A mutable deployment writes every acked insert/delete
//! through the log *before* replying; after a crash, restart is "map the
//! last snapshot, replay the log tail" (see [`super::durable`]).
//!
//! ## On-disk format
//!
//! A flat sequence of self-delimiting frames, no file header:
//!
//! ```text
//! [len: u32 LE] [crc: u64 LE] [payload: len bytes]
//! ```
//!
//! `crc` is the same FNV-1a-64 the snapshot section directory uses
//! (`persist::sections::checksum`) over the payload bytes. The payload is
//! `[tag: u8] [id: u32 LE] [body]`:
//!
//! * tag 1, **vector**: `n: u32` then `n` little-endian `f32`s — one
//!   acked insert, with the id the index assigned;
//! * tag 2, **metadata**: `has_tenant: u8`, optional length-prefixed
//!   tenant bytes, `n_tags: u32`, then length-prefixed tag strings — the
//!   tenant/tags recorded for an insert's assigned id;
//! * tag 3, **tombstone**: empty body — one acked delete.
//!
//! ## Torn-tail discipline
//!
//! `write(2)` during a crash can leave a *prefix* of the final frame on
//! disk. [`VectorLog::recover`] scans frames from the start; an
//! incomplete header, a length running past end-of-file, or a checksum
//! mismatch **on the final frame** is the torn tail — recovery truncates
//! the file back to the last whole frame and keeps going. A checksum
//! mismatch with more frames *after* it cannot be a torn write and is
//! reported as corruption (`Err`), never silently skipped: every frame
//! before it was acked to a client.
//!
//! Appends are one buffered `write_all` per frame followed by
//! `sync_data` — a frame is either fully submitted to the OS or not
//! written at all, and the ack never races the bytes.

use super::super::persist::sections;
use crate::util::error::{Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// One replayable mutation, decoded from a log frame.
#[derive(Clone, Debug, PartialEq)]
pub enum LogRecord {
    /// An acked insert: the index assigned `id` to `vector`.
    Vector { id: u32, vector: Vec<f32> },
    /// Tenant/tags recorded for an insert's assigned id.
    Metadata {
        id: u32,
        tenant: Option<String>,
        tags: Vec<String>,
    },
    /// An acked delete of `id`.
    Tombstone { id: u32 },
}

impl LogRecord {
    /// The id this record mutates.
    pub fn id(&self) -> u32 {
        match self {
            LogRecord::Vector { id, .. }
            | LogRecord::Metadata { id, .. }
            | LogRecord::Tombstone { id } => *id,
        }
    }
}

const TAG_VECTOR: u8 = 1;
const TAG_METADATA: u8 = 2;
const TAG_TOMBSTONE: u8 = 3;

/// Frame header: `len: u32` + `crc: u64`.
const FRAME_HEADER: usize = 12;

/// The append-only mutation log. One writer at a time (the serving layer
/// wraps it in a mutex); readers only exist at recovery.
pub struct VectorLog {
    file: File,
    path: PathBuf,
    /// Bytes of whole frames currently in the file.
    bytes: u64,
    /// Frames appended or recovered through this handle.
    records: u64,
    /// Fault-injection seam: when set, every append fails before writing
    /// anything, as a full disk or yanked volume would. Serving tests use
    /// it to pin the applied-but-not-logged ack path.
    poison: bool,
}

impl VectorLog {
    /// Create (or truncate to empty) the log at `path`.
    pub fn create(path: &Path) -> Result<VectorLog> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .with_context(|| format!("create mutation log {path:?}"))?;
        Ok(VectorLog {
            file,
            path: path.to_path_buf(),
            bytes: 0,
            records: 0,
            poison: false,
        })
    }

    /// Open the log at `path` (a missing file is an empty log), decode
    /// every whole frame, truncate a torn tail, and return the decoded
    /// records alongside the handle positioned for appending.
    pub fn recover(path: &Path) -> Result<(Vec<LogRecord>, VectorLog)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(false)
            .open(path)
            .with_context(|| format!("open mutation log {path:?}"))?;
        let mut data = Vec::new();
        file.read_to_end(&mut data)
            .with_context(|| format!("read mutation log {path:?}"))?;

        let mut records = Vec::new();
        let mut at = 0usize; // start of the frame being examined
        loop {
            let remaining = data.len() - at;
            if remaining == 0 {
                break; // clean log
            }
            if remaining < FRAME_HEADER {
                break; // torn header
            }
            let len = u32::from_le_bytes(data[at..at + 4].try_into().unwrap()) as usize;
            let crc = u64::from_le_bytes(data[at + 4..at + 12].try_into().unwrap());
            if len > remaining - FRAME_HEADER {
                break; // torn payload
            }
            let payload = &data[at + FRAME_HEADER..at + FRAME_HEADER + len];
            if sections::checksum(payload) != crc {
                // A bad checksum on the *final* frame is the torn tail; a
                // bad frame with whole frames after it is corruption of
                // data that was already acked.
                crate::ensure!(
                    at + FRAME_HEADER + len == data.len(),
                    "mutation log {path:?} corrupt at offset {at}: checksum mismatch mid-log"
                );
                break;
            }
            records.push(decode_payload(payload).with_context(|| {
                format!("mutation log {path:?} frame at offset {at}")
            })?);
            at += FRAME_HEADER + len;
        }
        if at < data.len() {
            // Drop exactly the torn tail: everything before `at` was a
            // whole, checksummed frame.
            file.set_len(at as u64)
                .with_context(|| format!("truncate torn tail of {path:?}"))?;
            file.sync_data()
                .with_context(|| format!("sync mutation log {path:?}"))?;
        }
        use std::io::Seek;
        file.seek(std::io::SeekFrom::Start(at as u64))
            .with_context(|| format!("seek mutation log {path:?}"))?;
        let n = records.len() as u64;
        Ok((
            records,
            VectorLog {
                file,
                path: path.to_path_buf(),
                bytes: at as u64,
                records: n,
                poison: false,
            },
        ))
    }

    /// Append one acked insert; durable (fsync'd) before return.
    pub fn append_vector(&mut self, id: u32, vector: &[f32]) -> Result<()> {
        let mut payload = Vec::with_capacity(9 + vector.len() * 4);
        payload.push(TAG_VECTOR);
        payload.extend_from_slice(&id.to_le_bytes());
        payload.extend_from_slice(&(vector.len() as u32).to_le_bytes());
        for x in vector {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        self.append_frame(&payload)
    }

    /// Append the tenant/tags recorded for an insert's assigned id;
    /// durable before return.
    pub fn append_metadata(&mut self, id: u32, tenant: Option<&str>, tags: &[&str]) -> Result<()> {
        let mut payload = Vec::new();
        payload.push(TAG_METADATA);
        payload.extend_from_slice(&id.to_le_bytes());
        match tenant {
            Some(t) => {
                payload.push(1);
                payload.extend_from_slice(&(t.len() as u32).to_le_bytes());
                payload.extend_from_slice(t.as_bytes());
            }
            None => payload.push(0),
        }
        payload.extend_from_slice(&(tags.len() as u32).to_le_bytes());
        for t in tags {
            payload.extend_from_slice(&(t.len() as u32).to_le_bytes());
            payload.extend_from_slice(t.as_bytes());
        }
        self.append_frame(&payload)
    }

    /// Append one acked delete; durable before return.
    pub fn append_tombstone(&mut self, id: u32) -> Result<()> {
        let mut payload = Vec::with_capacity(5);
        payload.push(TAG_TOMBSTONE);
        payload.extend_from_slice(&id.to_le_bytes());
        self.append_frame(&payload)
    }

    /// Make every subsequent append fail without writing (fault
    /// injection — see the `poison` field).
    pub fn poison_appends(&mut self, on: bool) {
        self.poison = on;
    }

    fn append_frame(&mut self, payload: &[u8]) -> Result<()> {
        crate::ensure!(
            !self.poison,
            "mutation log {:?}: append failed (injected fault)",
            self.path
        );
        let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&sections::checksum(payload).to_le_bytes());
        frame.extend_from_slice(payload);
        self.file
            .write_all(&frame)
            .with_context(|| format!("append to mutation log {:?}", self.path))?;
        self.file
            .sync_data()
            .with_context(|| format!("sync mutation log {:?}", self.path))?;
        self.bytes += frame.len() as u64;
        self.records += 1;
        Ok(())
    }

    /// Drop every frame (log compaction: the snapshot now owns the
    /// state the log was protecting).
    pub fn truncate(&mut self) -> Result<()> {
        use std::io::Seek;
        self.file
            .set_len(0)
            .with_context(|| format!("truncate mutation log {:?}", self.path))?;
        self.file
            .seek(std::io::SeekFrom::Start(0))
            .with_context(|| format!("seek mutation log {:?}", self.path))?;
        self.file
            .sync_data()
            .with_context(|| format!("sync mutation log {:?}", self.path))?;
        self.bytes = 0;
        self.records = 0;
        Ok(())
    }

    /// Bytes of whole frames currently in the file.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Frames appended or recovered through this handle.
    pub fn records(&self) -> u64 {
        self.records
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Decode one checksummed payload. The checksum already matched, so a
/// malformed payload here is a hard error (writer bug or tampering), not
/// a torn write.
fn decode_payload(payload: &[u8]) -> Result<LogRecord> {
    let mut c = Cursor(payload);
    let tag = c.u8()?;
    let id = c.u32()?;
    let rec = match tag {
        TAG_VECTOR => {
            let n = c.u32()? as usize;
            crate::ensure!(
                c.0.len() == n * 4,
                "vector record body is {} bytes, expected {}",
                c.0.len(),
                n * 4
            );
            let mut vector = Vec::with_capacity(n);
            for _ in 0..n {
                vector.push(f32::from_le_bytes(c.take(4)?.try_into().unwrap()));
            }
            LogRecord::Vector { id, vector }
        }
        TAG_METADATA => {
            let tenant = match c.u8()? {
                0 => None,
                1 => Some(c.string()?),
                b => crate::bail!("metadata record has bad tenant marker {b}"),
            };
            let n = c.u32()? as usize;
            crate::ensure!(n <= c.0.len(), "metadata record claims {n} tags in {} bytes", c.0.len());
            let mut tags = Vec::with_capacity(n);
            for _ in 0..n {
                tags.push(c.string()?);
            }
            LogRecord::Metadata { id, tenant, tags }
        }
        TAG_TOMBSTONE => LogRecord::Tombstone { id },
        t => crate::bail!("unknown mutation log record tag {t}"),
    };
    crate::ensure!(c.0.is_empty(), "trailing bytes in mutation log record");
    Ok(rec)
}

/// Bounds-checked cursor over a payload slice.
struct Cursor<'a>(&'a [u8]);

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(self.0.len() >= n, "mutation log record truncated");
        let (head, rest) = self.0.split_at(n);
        self.0 = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| crate::util::error::Error::msg("mutation log string is not UTF-8".into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{name}.wal", std::process::id()))
    }

    #[test]
    fn wal_roundtrips_all_record_kinds() {
        let path = tmp("roundtrip");
        let mut log = VectorLog::create(&path).unwrap();
        log.append_vector(7, &[1.0, -2.5, 0.0]).unwrap();
        log.append_metadata(7, Some("t1"), &["hot", "eu"]).unwrap();
        log.append_metadata(8, None, &[]).unwrap();
        log.append_tombstone(3).unwrap();
        assert_eq!(log.records(), 4);
        let written = log.bytes();
        drop(log);

        let (records, log) = VectorLog::recover(&path).unwrap();
        assert_eq!(log.bytes(), written, "recovery found every appended byte");
        assert_eq!(
            records,
            vec![
                LogRecord::Vector {
                    id: 7,
                    vector: vec![1.0, -2.5, 0.0]
                },
                LogRecord::Metadata {
                    id: 7,
                    tenant: Some("t1".to_string()),
                    tags: vec!["hot".to_string(), "eu".to_string()]
                },
                LogRecord::Metadata {
                    id: 8,
                    tenant: None,
                    tags: vec![]
                },
                LogRecord::Tombstone { id: 3 },
            ]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_missing_file_is_empty_log() {
        let path = tmp("missing");
        std::fs::remove_file(&path).ok();
        let (records, log) = VectorLog::recover(&path).unwrap();
        assert!(records.is_empty());
        assert_eq!(log.bytes(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_truncate_empties_the_log_and_appends_continue() {
        let path = tmp("truncate");
        let mut log = VectorLog::create(&path).unwrap();
        log.append_vector(0, &[1.0]).unwrap();
        log.truncate().unwrap();
        assert_eq!((log.bytes(), log.records()), (0, 0));
        log.append_tombstone(9).unwrap();
        drop(log);
        let (records, _) = VectorLog::recover(&path).unwrap();
        assert_eq!(records, vec![LogRecord::Tombstone { id: 9 }]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_poisoned_appends_fail_without_writing() {
        let path = tmp("poison");
        let mut log = VectorLog::create(&path).unwrap();
        log.append_vector(1, &[0.5]).unwrap();
        let before = log.bytes();
        log.poison_appends(true);
        assert!(log.append_tombstone(2).is_err());
        assert!(log.append_vector(3, &[1.0]).is_err());
        assert_eq!(log.bytes(), before, "a failed append writes nothing");
        log.poison_appends(false);
        log.append_tombstone(4).unwrap();
        drop(log);
        let (records, _) = VectorLog::recover(&path).unwrap();
        assert_eq!(
            records,
            vec![
                LogRecord::Vector {
                    id: 1,
                    vector: vec![0.5]
                },
                LogRecord::Tombstone { id: 4 },
            ]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_mid_log_corruption_is_an_error_not_a_skip() {
        let path = tmp("midlog");
        let mut log = VectorLog::create(&path).unwrap();
        log.append_vector(0, &[1.0]).unwrap();
        log.append_tombstone(1).unwrap();
        drop(log);
        // Flip one payload byte of the FIRST frame: the checksum mismatch
        // is followed by a whole valid frame, so this is corruption.
        let mut data = std::fs::read(&path).unwrap();
        data[FRAME_HEADER + 2] ^= 0x40;
        std::fs::write(&path, &data).unwrap();
        let err = format!("{:#}", VectorLog::recover(&path).unwrap_err());
        assert!(err.contains("checksum mismatch mid-log"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn wal_valid_checksum_but_malformed_payload_is_an_error() {
        let path = tmp("malformed");
        // Hand-build a frame whose payload has an unknown tag but a
        // correct checksum: recovery must refuse, not truncate.
        let payload = [99u8, 0, 0, 0, 0];
        let mut frame = Vec::new();
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&sections::checksum(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        std::fs::write(&path, &frame).unwrap();
        let err = format!("{:#}", VectorLog::recover(&path).unwrap_err());
        assert!(err.contains("unknown mutation log record tag 99"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
