//! Read-only byte regions and typed copy-on-write views over them.
//!
//! [`MappedRegion`] abstracts "a contiguous run of immutable bytes":
//! either a private read-only `mmap(2)` of a snapshot file (zero-copy
//! serving — pages fault in on first touch and stay evictable) or a
//! heap buffer (tests, non-unix targets, and files read the classic
//! way). The mmap shim is declared locally over the raw C ABI — this
//! crate takes no dependencies, `libc` included — and is compiled only
//! on 64-bit unix; everywhere else [`MappedRegion::map_file`] silently
//! degrades to a heap read, so callers never branch on platform.
//!
//! [`Segment`] is the typed view index structures store: a flat `[T]`
//! array that is either owned (built in memory, mutated freely) or a
//! slice straight into a mapped region (validated once at construction:
//! bounds and alignment). Mutation promotes a mapped segment to an owned
//! copy first ([`Segment::to_mut`]) — copy-on-write at the whole-array
//! granularity, which is exactly the mutability the mutable indexes
//! need (a served snapshot flips to heap on the first insert).
//!
//! The snapshot format stores raw little-endian payloads and serves
//! them as native-endian slices; the identity only holds on LE hosts.
#[cfg(target_endian = "big")]
compile_error!("the paged snapshot format assumes a little-endian host");

use crate::util::error::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Plain-old-data element types a [`Segment`] may carry: fixed-width
/// primitives with no padding, no invalid bit patterns, and no drop
/// glue, so a byte region reinterpreted as `[T]` is always valid.
///
/// # Safety
///
/// Implementors must be inhabited for every bit pattern of their size.
pub unsafe trait Pod: Copy + 'static {}
unsafe impl Pod for i8 {}
unsafe impl Pod for u8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// Reinterpret a Pod slice as its raw little-endian bytes (the host is
/// guaranteed LE by the `compile_error!` above) — how section writers
/// serialize flat arrays without a per-element loop.
pub fn as_bytes<T: Pod>(v: &[T]) -> &[u8] {
    // Safety: T is Pod (no padding, any bit pattern valid), the length
    // math cannot overflow for an existing allocation.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v)) }
}

#[cfg(all(unix, target_pointer_width = "64"))]
mod ffi {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

enum Backing {
    /// Heap bytes in a `u64` buffer, so the base pointer is 8-byte
    /// aligned for every Pod type even without mmap's page alignment.
    Heap(#[allow(dead_code)] Vec<u64>),
    #[cfg(all(unix, target_pointer_width = "64"))]
    Mmap {
        base: *mut std::ffi::c_void,
        map_len: usize,
    },
}

/// A contiguous, immutable, 8-byte-aligned byte region — mmap-backed or
/// heap-backed (see the module docs).
pub struct MappedRegion {
    ptr: *const u8,
    len: usize,
    backing: Backing,
}

// Safety: the region is immutable after construction (PROT_READ mapping
// or a never-mutated heap buffer), so shared access across threads is
// sound; the raw pointers are what inhibit the auto impls.
unsafe impl Send for MappedRegion {}
unsafe impl Sync for MappedRegion {}

impl MappedRegion {
    /// Wrap owned bytes in a heap-backed region (copies once into an
    /// 8-byte-aligned buffer).
    pub fn from_vec(bytes: Vec<u8>) -> MappedRegion {
        let mut words = vec![0u64; bytes.len().div_ceil(8)];
        // Safety: the u64 buffer holds at least bytes.len() bytes.
        unsafe {
            std::ptr::copy_nonoverlapping(
                bytes.as_ptr(),
                words.as_mut_ptr() as *mut u8,
                bytes.len(),
            );
        }
        MappedRegion {
            ptr: words.as_ptr() as *const u8,
            len: bytes.len(),
            backing: Backing::Heap(words),
        }
    }

    /// Read `path` entirely into a heap-backed region.
    pub fn read_file(path: &Path) -> Result<MappedRegion> {
        let bytes = std::fs::read(path).with_context(|| format!("read {path:?}"))?;
        Ok(MappedRegion::from_vec(bytes))
    }

    /// Map `path` read-only. Zero-copy on 64-bit unix; on other targets
    /// (and for empty files, which `mmap` rejects) this degrades to
    /// [`MappedRegion::read_file`] so callers never branch on platform.
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn map_file(path: &Path) -> Result<MappedRegion> {
        use std::os::unix::io::AsRawFd;
        let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let len = f
            .metadata()
            .with_context(|| format!("stat {path:?}"))?
            .len() as usize;
        if len == 0 {
            return Ok(MappedRegion::from_vec(Vec::new()));
        }
        // Safety: a fresh private read-only mapping of a file we hold
        // open; the fd may close after mmap returns (POSIX keeps the
        // mapping alive).
        let base = unsafe {
            ffi::mmap(
                std::ptr::null_mut(),
                len,
                ffi::PROT_READ,
                ffi::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        crate::ensure!(
            base as isize != -1,
            "mmap of {path:?} ({len} bytes) failed"
        );
        Ok(MappedRegion {
            ptr: base as *const u8,
            len,
            backing: Backing::Mmap { base, map_len: len },
        })
    }

    /// Heap fallback for targets without the mmap shim.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn map_file(path: &Path) -> Result<MappedRegion> {
        MappedRegion::read_file(path)
    }

    /// Total bytes in the region.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True when the region is an actual file mapping (not heap bytes).
    pub fn is_mmap(&self) -> bool {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            matches!(self.backing, Backing::Mmap { .. })
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            false
        }
    }

    /// The whole region as bytes.
    pub fn as_slice(&self) -> &[u8] {
        // Safety: ptr/len describe the live backing; for len == 0 the
        // pointer is dangling-but-aligned (empty Vec), which zero-length
        // slices permit.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// A typed view of `len` elements of `T` starting at byte `offset`:
    /// overflow-checked bounds, element alignment verified against the
    /// actual address. This is the one gate between untrusted file bytes
    /// and a `&[T]` — every failure is a corrupt/hostile file, never UB.
    pub fn view<T: Pod>(&self, offset: usize, len: usize) -> Result<&[T]> {
        if len == 0 {
            return Ok(&[]);
        }
        let elem = std::mem::size_of::<T>();
        let bytes = len
            .checked_mul(elem)
            .ok_or_else(|| crate::util::error::Error::msg("section view length overflows"))?;
        let end = offset
            .checked_add(bytes)
            .ok_or_else(|| crate::util::error::Error::msg("section view offset overflows"))?;
        crate::ensure!(
            end <= self.len,
            "section view [{offset}, {end}) exceeds region size {}",
            self.len
        );
        let addr = self.ptr as usize + offset;
        crate::ensure!(
            addr % std::mem::align_of::<T>() == 0,
            "section view at offset {offset} is misaligned for {}-byte elements",
            std::mem::align_of::<T>()
        );
        // Safety: bounds and alignment checked above; T is Pod so any
        // bit pattern is a valid value; the region is immutable.
        Ok(unsafe { std::slice::from_raw_parts(addr as *const T, len) })
    }
}

impl Drop for MappedRegion {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        if let Backing::Mmap { base, map_len } = &self.backing {
            // Safety: unmapping the exact mapping we created.
            unsafe {
                ffi::munmap(*base, *map_len);
            }
        }
    }
}

impl std::fmt::Debug for MappedRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MappedRegion")
            .field("len", &self.len)
            .field("mmap", &self.is_mmap())
            .finish()
    }
}

enum Repr<T: Pod> {
    Owned(Vec<T>),
    Mapped {
        region: Arc<MappedRegion>,
        offset: usize,
        len: usize,
    },
}

/// A flat `[T]` array that is either owned or a validated slice into a
/// shared [`MappedRegion`] — the copy-on-write storage behind the graph
/// adjacency and the SQ8 code matrix. Reads go through `Deref<[T]>`
/// either way; the first mutation of a mapped segment promotes it to an
/// owned copy ([`Segment::to_mut`]).
pub struct Segment<T: Pod>(Repr<T>);

impl<T: Pod> Segment<T> {
    /// A segment viewing `len` elements at byte `offset` of `region`.
    /// Bounds and alignment are validated here, once — after this,
    /// every read is infallible.
    pub fn from_region(region: Arc<MappedRegion>, offset: usize, len: usize) -> Result<Segment<T>> {
        region.view::<T>(offset, len)?;
        Ok(Segment(Repr::Mapped { region, offset, len }))
    }

    /// The elements as a slice (also available through `Deref`).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        match &self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { region, offset, len } => region
                .view::<T>(*offset, *len)
                .expect("segment validated at construction"),
        }
    }

    /// Mutable access, promoting a mapped segment to an owned copy
    /// first (copy-on-write).
    pub fn to_mut(&mut self) -> &mut Vec<T> {
        if let Repr::Mapped { .. } = self.0 {
            self.0 = Repr::Owned(self.as_slice().to_vec());
        }
        match &mut self.0 {
            Repr::Owned(v) => v,
            Repr::Mapped { .. } => unreachable!("promoted above"),
        }
    }

    /// True while the segment still reads straight out of a mapped
    /// region (i.e. no mutation has promoted it to heap).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, Repr::Mapped { .. })
    }
}

impl<T: Pod> From<Vec<T>> for Segment<T> {
    fn from(v: Vec<T>) -> Segment<T> {
        Segment(Repr::Owned(v))
    }
}

impl<T: Pod> std::ops::Deref for Segment<T> {
    type Target = [T];
    #[inline]
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Pod> Clone for Segment<T> {
    fn clone(&self) -> Segment<T> {
        match &self.0 {
            Repr::Owned(v) => Segment(Repr::Owned(v.clone())),
            Repr::Mapped { region, offset, len } => Segment(Repr::Mapped {
                region: Arc::clone(region),
                offset: *offset,
                len: *len,
            }),
        }
    }
}

impl<T: Pod + PartialEq> PartialEq for Segment<T> {
    fn eq(&self, other: &Segment<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: Pod + std::fmt::Debug> std::fmt::Debug for Segment<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.as_slice().fmt(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn region_heap_and_mmap_bytes_identical() {
        let bytes: Vec<u8> = (0..=255u8).cycle().take(4096 + 13).collect();
        let path = tmp("region_bytes.bin");
        std::fs::write(&path, &bytes).unwrap();
        let heap = MappedRegion::read_file(&path).unwrap();
        let mapped = MappedRegion::map_file(&path).unwrap();
        assert_eq!(heap.as_slice(), &bytes[..]);
        assert_eq!(mapped.as_slice(), &bytes[..]);
        assert_eq!(heap.len(), mapped.len());
        #[cfg(all(unix, target_pointer_width = "64"))]
        assert!(mapped.is_mmap());
        assert!(!heap.is_mmap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn region_empty_file_and_missing_file() {
        let path = tmp("region_empty.bin");
        std::fs::write(&path, b"").unwrap();
        let r = MappedRegion::map_file(&path).unwrap();
        assert!(r.is_empty());
        assert_eq!(r.as_slice(), &[] as &[u8]);
        assert!(r.view::<u32>(0, 0).unwrap().is_empty());
        std::fs::remove_file(&path).ok();
        assert!(MappedRegion::map_file(&path).is_err());
        assert!(MappedRegion::read_file(&path).is_err());
    }

    #[test]
    fn view_checks_bounds_and_alignment() {
        let mut bytes = vec![0u8; 64];
        for (i, b) in bytes.iter_mut().enumerate() {
            *b = i as u8;
        }
        let r = MappedRegion::from_vec(bytes);
        // A valid aligned u32 view reads LE words.
        let v: &[u32] = r.view(4, 2).unwrap();
        assert_eq!(v, &[u32::from_le_bytes([4, 5, 6, 7]), u32::from_le_bytes([8, 9, 10, 11])]);
        // Out of bounds: length, offset, and overflowing combinations.
        assert!(r.view::<u32>(0, 17).is_err());
        assert!(r.view::<u8>(65, 1).is_err());
        assert!(r.view::<u64>(usize::MAX - 2, 1).is_err());
        assert!(r.view::<u64>(0, usize::MAX / 4).is_err());
        // Misaligned offset for 4-byte elements (heap base is 8-aligned).
        assert!(r.view::<u32>(2, 1).is_err());
        // Zero-length views are fine anywhere in range.
        assert!(r.view::<u64>(64, 0).is_ok());
    }

    #[test]
    fn segment_cow_promotes_on_mutation() {
        let bytes: Vec<u8> = (0u32..32).flat_map(|x| x.to_le_bytes()).collect();
        let region = Arc::new(MappedRegion::from_vec(bytes));
        let mut seg: Segment<u32> = Segment::from_region(Arc::clone(&region), 0, 32).unwrap();
        assert!(seg.is_mapped());
        assert_eq!(seg[5], 5);
        assert_eq!(seg.len(), 32);
        // Clones share the region; mutation promotes only the mutated one.
        let frozen = seg.clone();
        seg.to_mut()[5] = 99;
        assert!(!seg.is_mapped());
        assert!(frozen.is_mapped());
        assert_eq!(seg[5], 99);
        assert_eq!(frozen[5], 5);
        assert_ne!(seg, frozen);
        // Owned round-trip.
        let owned: Segment<u32> = vec![1, 2, 3].into();
        assert!(!owned.is_mapped());
        assert_eq!(&owned[..], &[1, 2, 3]);
    }

    #[test]
    fn segment_from_region_rejects_bad_views() {
        let region = Arc::new(MappedRegion::from_vec(vec![0u8; 40]));
        assert!(Segment::<u32>::from_region(Arc::clone(&region), 0, 10).is_ok());
        assert!(Segment::<u32>::from_region(Arc::clone(&region), 0, 11).is_err());
        assert!(Segment::<u32>::from_region(Arc::clone(&region), 2, 1).is_err());
        assert!(Segment::<u64>::from_region(region, 48, 1).is_err());
    }

    #[test]
    fn as_bytes_roundtrip() {
        let v: Vec<u32> = vec![1, 0x01020304, u32::MAX];
        let b = as_bytes(&v);
        assert_eq!(b.len(), 12);
        assert_eq!(&b[4..8], &[4, 3, 2, 1]);
        let f: Vec<f32> = vec![1.5, -2.25];
        assert_eq!(as_bytes(&f).len(), 8);
        assert_eq!(&as_bytes(&f)[0..4], &1.5f32.to_le_bytes());
        assert!(as_bytes::<u64>(&[]).is_empty());
    }
}
