//! Disk-resident storage tier: the byte-level substrate under the paged
//! snapshot format and the mutation log.
//!
//! * [`region`] — [`region::MappedRegion`], a read-only byte region that
//!   is either `mmap(2)`-backed (zero-copy serving straight out of the
//!   page cache) or heap-backed (tests, non-unix targets, small files),
//!   plus [`region::Segment`], the copy-on-write typed view the graph
//!   adjacency and SQ8 code arrays live behind;
//! * [`pq`] — [`pq::PqStore`], 4-bit product-quantized codebooks + packed
//!   code rows (the ADC fast-scan substrate, DESIGN.md §PQ-Fast-Scan),
//!   both `Segment`-backed so snapshots serve them from mmap;
//! * [`wal`] — [`wal::VectorLog`], the append-only mutation log: every
//!   acked insert/delete is a checksummed, fsync'd frame, and recovery
//!   drops exactly the torn tail;
//! * [`durable`] — restart (map the snapshot, replay the log tail) and
//!   compaction (fold the log into a fresh snapshot, truncate it).

pub mod durable;
pub mod pq;
pub mod region;
pub mod wal;

pub use durable::{compact_glass, restore_glass, CompactionStats, RestoredGlass};
pub use pq::PqStore;
pub use region::{MappedRegion, Segment};
pub use wal::{LogRecord, VectorLog};
