//! Disk-resident storage tier: the byte-level substrate under the paged
//! snapshot format and the mutation log.
//!
//! * [`region`] — [`region::MappedRegion`], a read-only byte region that
//!   is either `mmap(2)`-backed (zero-copy serving straight out of the
//!   page cache) or heap-backed (tests, non-unix targets, small files),
//!   plus [`region::Segment`], the copy-on-write typed view the graph
//!   adjacency and SQ8 code arrays live behind.

pub mod region;

pub use region::{MappedRegion, Segment};
