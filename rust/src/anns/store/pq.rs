//! 4-bit product-quantized vector storage (DESIGN.md §PQ-Fast-Scan).
//!
//! A [`PqStore`] is the PQ sibling of `distance::quant::QuantizedStore`:
//! it splits each `dim`-dimensional vector into `m` subspaces of
//! `ds = ceil(dim / m)` dims (the tail subspace zero-padded), trains 16
//! centroids per subspace with deterministic seeded k-means (4-bit codes),
//! and stores each vector as `(m + 1) / 2` packed bytes — two codes per
//! byte, low nibble = even subspace `2p`, high nibble = odd subspace
//! `2p + 1`. That is 1/8 the bytes of SQ8 per dim when `m = dim / 4`, and
//! ≤ 1/8 of f32 whenever `m ≤ dim / 2` (asserted by the size test below).
//!
//! Search-time distances are asymmetric (ADC): the query builds a
//! [`PqLut`] of per-subspace distance tables once, then every row costs
//! `m` u8 table lookups (`distance::simd` fast-scan kernels). Approximate
//! by construction — callers re-rank survivors in exact f32, same contract
//! as the SQ8 path.
//!
//! Codebooks are **frozen after training**: `append`/`reencode` only run
//! the encoder, so an insert never perturbs existing rows and rebuilds are
//! bit-stable — the same freeze discipline as `QuantizedStore.scale`.

use super::region::Segment;
use crate::distance::simd::{self, PqLut, PQ_BLOCK};
use crate::distance::Metric;
use crate::util::rng::Rng;

/// Centroids per subspace — fixed at 16 so one code is one nibble.
pub const PQ_K: usize = 16;

/// Rows sampled for codebook training (matches the IVF k-means cap).
const TRAIN_SAMPLE: usize = 20_000;

/// Lloyd iterations per subspace. Fixed (not a knob): PQ codebook quality
/// saturates fast at k=16, and a fixed count keeps builds deterministic
/// and cheap.
const LLOYD_ITERS: usize = 12;

/// Clamp a requested subquantizer count to what the dimensionality (and
/// the u16-accumulator overflow bound of the fast-scan kernel) supports.
pub fn clamp_m(dim: usize, m: usize) -> usize {
    m.clamp(1, dim.min(256))
}

/// 4-bit PQ codebooks + packed code rows. Both live in [`Segment`]s so a
/// v3 snapshot can serve them straight from an mmap.
pub struct PqStore {
    dim: usize,
    /// Subquantizer count (`1 ..= min(dim, 256)`).
    m: usize,
    /// Dims per subspace (`ceil(dim / m)`; the last subspace is
    /// zero-padded past `dim`).
    ds: usize,
    /// `m × 16 × ds` f32, row-major `[subspace][centroid][dim]`, padding
    /// dims stored as 0.0 so they contribute nothing to L2 or dot tables.
    codebooks: Segment<f32>,
    /// `n × row_bytes` packed rows.
    codes: Segment<u8>,
}

impl PqStore {
    /// Train codebooks on `data` (row-major `n × dim`) and encode every
    /// row. Deterministic for a fixed `(data, dim, m, seed)`.
    pub fn build(data: &[f32], dim: usize, m: usize, seed: u64) -> PqStore {
        assert!(dim > 0, "pq dim must be positive");
        assert_eq!(data.len() % dim, 0, "pq data not a multiple of dim");
        let m = clamp_m(dim, m);
        let ds = dim.div_ceil(m);
        let n = data.len() / dim;
        let codebooks = train_codebooks(data, dim, m, ds, seed);
        let mut store = PqStore {
            dim,
            m,
            ds,
            codebooks: Segment::from(codebooks),
            codes: Segment::from(Vec::new()),
        };
        let mut packed = Vec::with_capacity(n * store.row_bytes());
        for i in 0..n {
            store.encode_into(&data[i * dim..(i + 1) * dim], &mut packed);
        }
        store.codes = Segment::from(packed);
        store
    }

    /// Reassemble from snapshot sections. Every structural property is
    /// re-derived and checked — a hostile file gets an error, not a panic.
    pub fn from_parts(
        dim: usize,
        m: usize,
        codebooks: Segment<f32>,
        codes: Segment<u8>,
    ) -> Result<PqStore, String> {
        if dim == 0 {
            return Err("pq store: dim must be positive".into());
        }
        if m < 1 || m > dim.min(256) {
            return Err(format!("pq store: m={m} out of range [1, {}]", dim.min(256)));
        }
        let ds = dim.div_ceil(m);
        if codebooks.len() != m * PQ_K * ds {
            return Err(format!(
                "pq store: codebook length {} != m*16*ds = {}",
                codebooks.len(),
                m * PQ_K * ds
            ));
        }
        if let Some(bad) = codebooks.iter().find(|v| !v.is_finite()) {
            return Err(format!("pq store: non-finite codebook entry {bad}"));
        }
        let row_bytes = (m + 1) / 2;
        if codes.len() % row_bytes != 0 {
            return Err(format!(
                "pq store: code bytes {} not a multiple of row stride {row_bytes}",
                codes.len()
            ));
        }
        Ok(PqStore { dim, m, ds, codebooks, codes })
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Dims per subspace.
    pub fn ds(&self) -> usize {
        self.ds
    }

    /// Packed bytes per row.
    pub fn row_bytes(&self) -> usize {
        (self.m + 1) / 2
    }

    pub fn len(&self) -> usize {
        self.codes.len() / self.row_bytes()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Packed row `i`.
    pub fn code(&self, i: usize) -> &[u8] {
        let rb = self.row_bytes();
        &self.codes[i * rb..(i + 1) * rb]
    }

    /// The whole packed code matrix (row-major).
    pub fn codes(&self) -> &[u8] {
        &self.codes
    }

    /// The raw codebook array (`m × 16 × ds` f32).
    pub fn codebooks(&self) -> &[f32] {
        &self.codebooks
    }

    /// Centroid `c` of subspace `j`.
    fn centroid(&self, j: usize, c: usize) -> &[f32] {
        let at = (j * PQ_K + c) * self.ds;
        &self.codebooks[at..at + self.ds]
    }

    /// Encode one vector against the frozen codebooks: nearest centroid
    /// per subspace in (zero-padded) subspace L2, ties to the lowest
    /// index. Deterministic, and independent of every other row.
    pub fn encode(&self, v: &[f32]) -> Vec<u8> {
        let mut row = Vec::with_capacity(self.row_bytes());
        self.encode_into(v, &mut row);
        row
    }

    fn encode_into(&self, v: &[f32], out: &mut Vec<u8>) {
        assert_eq!(v.len(), self.dim, "pq encode dim mismatch");
        let mut nibbles = [0u8; 2];
        for j in 0..self.m {
            let mut best = f32::INFINITY;
            let mut code = 0u8;
            for c in 0..PQ_K {
                let d = sub_l2(v, self.dim, j, self.ds, self.centroid(j, c));
                if d < best {
                    best = d;
                    code = c as u8;
                }
            }
            nibbles[j & 1] = code;
            if j & 1 == 1 {
                out.push(nibbles[0] | (nibbles[1] << 4));
            }
        }
        if self.m & 1 == 1 {
            // Odd m: the final high nibble is the phantom subspace, always 0.
            out.push(nibbles[0]);
        }
    }

    /// Append one vector's codes (codebooks frozen — existing rows are
    /// untouched, mirroring `QuantizedStore::append`).
    pub fn append(&mut self, v: &[f32]) {
        let row = self.encode(v);
        self.codes.to_mut().extend_from_slice(&row);
    }

    /// Re-encode row `i` in place (slot recycling).
    pub fn reencode(&mut self, i: usize, v: &[f32]) {
        let row = self.encode(v);
        let rb = self.row_bytes();
        self.codes.to_mut()[i * rb..(i + 1) * rb].copy_from_slice(&row);
    }

    /// Build the query's quantized ADC tables. O(m · 16 · ds) f32 work
    /// once per query; every row afterwards costs `m` u8 lookups.
    pub fn lut(&self, metric: Metric, q: &[f32]) -> PqLut {
        assert_eq!(q.len(), self.dim, "pq query dim mismatch");
        let mut raw = vec![0f32; self.m * PQ_K];
        for j in 0..self.m {
            for c in 0..PQ_K {
                let cb = self.centroid(j, c);
                let mut acc = 0f32;
                for d in 0..self.ds {
                    let full = j * self.ds + d;
                    let qv = if full < self.dim { q[full] } else { 0.0 };
                    match metric {
                        Metric::L2 => {
                            let diff = qv - cb[d];
                            acc += diff * diff;
                        }
                        // Angular (1 - <q,b>) and Ip (-<q,b>) both reduce
                        // to summed -<q_j, c>; the additive constant rides
                        // in the LUT bias below.
                        Metric::Angular | Metric::Ip => acc -= qv * cb[d],
                    }
                }
                raw[j * PQ_K + c] = acc;
            }
        }
        let metric_bias = match metric {
            Metric::Angular => 1.0,
            Metric::L2 | Metric::Ip => 0.0,
        };
        PqLut::quantize(&raw, self.m, metric_bias)
    }

    /// ADC distance from a prepared LUT to row `i`, in metric units.
    pub fn distance(&self, lut: &PqLut, i: usize) -> f32 {
        lut.decode(simd::pq_adc(lut, self.code(i)))
    }

    /// One-to-many ADC distances (bitwise identical to per-pair
    /// [`PqStore::distance`] calls, any prefetch schedule).
    pub fn distance_batch(&self, lut: &PqLut, ids: &[u32], out: &mut Vec<f32>) {
        simd::pq_adc_batch(lut, ids, &self.codes, out);
    }

    /// [`PqStore::distance_batch`] with an explicit prefetch schedule.
    pub fn distance_batch_with(
        &self,
        lut: &PqLut,
        ids: &[u32],
        lookahead: usize,
        locality: i32,
        out: &mut Vec<f32>,
    ) {
        simd::pq_adc_batch_with(lut, ids, &self.codes, lookahead, locality, out);
    }

    /// Bytes of quantized state: packed codes + f32 codebooks. This is
    /// the figure the ≤ 1/8-of-f32 acceptance test audits.
    pub fn bytes(&self) -> usize {
        self.codes.len() + self.codebooks.len() * 4
    }

    /// Whether codes are currently served from an mmap.
    pub fn is_mapped(&self) -> bool {
        self.codes.is_mapped()
    }
}

/// Squared L2 between the `j`-th zero-padded subspace of `v` and one
/// centroid row.
fn sub_l2(v: &[f32], dim: usize, j: usize, ds: usize, centroid: &[f32]) -> f32 {
    let mut acc = 0f32;
    for d in 0..ds {
        let full = j * ds + d;
        let qv = if full < dim { v[full] } else { 0.0 };
        let diff = qv - centroid[d];
        acc += diff * diff;
    }
    acc
}

/// Per-subspace 16-centroid k-means (k-means++ seeding + fixed Lloyd
/// iterations over a deterministic sample). Always plain subspace L2 —
/// the standard PQ training objective for every serving metric.
fn train_codebooks(data: &[f32], dim: usize, m: usize, ds: usize, seed: u64) -> Vec<f32> {
    let n = data.len() / dim;
    let mut rng = Rng::new(seed ^ 0x5051_4641_5354_5343); // "PQFASTSC" stream tag
    let mut codebooks = vec![0f32; m * PQ_K * ds];
    if n == 0 {
        return codebooks;
    }
    let sample_n = n.min(TRAIN_SAMPLE);
    let sample = rng.sample_indices(n, sample_n);
    // Padded per-sample subvectors, rebuilt per subspace.
    let mut sub = vec![0f32; sample_n * ds];
    for j in 0..m {
        for (s, &i) in sample.iter().enumerate() {
            for d in 0..ds {
                let full = j * ds + d;
                sub[s * ds + d] = if full < dim { data[i * dim + full] } else { 0.0 };
            }
        }
        let cb = &mut codebooks[j * PQ_K * ds..(j + 1) * PQ_K * ds];
        train_subspace(&sub, sample_n, ds, cb, &mut rng);
    }
    codebooks
}

fn l2(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// One subspace's k-means over `sample_n` rows of `ds` dims into
/// `cb` (`16 × ds`). Empty clusters keep their previous centroid.
fn train_subspace(sub: &[f32], sample_n: usize, ds: usize, cb: &mut [f32], rng: &mut Rng) {
    let row = |i: usize| &sub[i * ds..(i + 1) * ds];
    // k-means++ seeding.
    let first = rng.next_below(sample_n);
    cb[..ds].copy_from_slice(row(first));
    let mut d2: Vec<f32> = (0..sample_n).map(|i| l2(&cb[..ds], row(i))).collect();
    for c in 1..PQ_K {
        let total: f64 = d2.iter().map(|&x| x as f64).sum();
        let pick = if total <= 0.0 {
            rng.next_below(sample_n)
        } else {
            let mut t = rng.next_f64() * total;
            let mut idx = 0;
            for (j, &x) in d2.iter().enumerate() {
                t -= x as f64;
                if t <= 0.0 {
                    idx = j;
                    break;
                }
            }
            idx
        };
        cb[c * ds..(c + 1) * ds].copy_from_slice(row(pick));
        for (j, d) in d2.iter_mut().enumerate() {
            let nd = l2(&cb[c * ds..(c + 1) * ds], row(j));
            if nd < *d {
                *d = nd;
            }
        }
    }
    // Lloyd iterations.
    let mut assign = vec![0u8; sample_n];
    for _ in 0..LLOYD_ITERS {
        for i in 0..sample_n {
            let mut best = f32::INFINITY;
            let mut a = 0u8;
            for c in 0..PQ_K {
                let d = l2(&cb[c * ds..(c + 1) * ds], row(i));
                if d < best {
                    best = d;
                    a = c as u8;
                }
            }
            assign[i] = a;
        }
        let mut sums = vec![0f64; PQ_K * ds];
        let mut counts = [0usize; PQ_K];
        for i in 0..sample_n {
            let c = assign[i] as usize;
            counts[c] += 1;
            for (s, &v) in sums[c * ds..(c + 1) * ds].iter_mut().zip(row(i)) {
                *s += v as f64;
            }
        }
        for c in 0..PQ_K {
            if counts[c] > 0 {
                for (dst, s) in cb[c * ds..(c + 1) * ds].iter_mut().zip(&sums[c * ds..(c + 1) * ds]) {
                    *dst = (*s / counts[c] as f64) as f32;
                }
            }
        }
    }
}

/// Bytes of one position-major fast-scan block (32 rows).
pub fn block_bytes(row_bytes: usize) -> usize {
    PQ_BLOCK * row_bytes
}

/// Scatter one packed row into a position-major block buffer at `slot`
/// (the cell-local position). Grows `blocks` by one zeroed block whenever
/// `slot` crosses a 32-row boundary; zero padding is harmless — tail
/// slots decode against table entry 0 and are discarded by the scanner.
pub fn scatter_row(blocks: &mut Vec<u8>, row_bytes: usize, slot: usize, row: &[u8]) {
    debug_assert_eq!(row.len(), row_bytes);
    let block = slot / PQ_BLOCK;
    let lane = slot % PQ_BLOCK;
    let base = block * block_bytes(row_bytes);
    if blocks.len() < base + block_bytes(row_bytes) {
        blocks.resize(base + block_bytes(row_bytes), 0);
    }
    for (p, &b) in row.iter().enumerate() {
        blocks[base + p * PQ_BLOCK + lane] = b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gaussian_rows(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * dim).map(|_| rng.next_gaussian_f32()).collect()
    }

    #[test]
    fn pq_build_is_deterministic_for_seed() {
        let data = gaussian_rows(300, 25, 7);
        let a = PqStore::build(&data, 25, 8, 42);
        let b = PqStore::build(&data, 25, 8, 42);
        assert_eq!(a.codebooks(), b.codebooks());
        assert_eq!(a.codes(), b.codes());
    }

    #[test]
    fn pq_append_and_reencode_match_build_encoding() {
        let data = gaussian_rows(200, 16, 3);
        let mut store = PqStore::build(&data, 16, 4, 11);
        let original = store.code(17).to_vec();
        // Re-encoding the same vector against frozen codebooks is a no-op.
        store.reencode(17, &data[17 * 16..18 * 16]);
        assert_eq!(store.code(17), &original[..]);
        // Appending a copy reproduces the original row's code exactly.
        store.append(&data[17 * 16..18 * 16]);
        assert_eq!(store.code(store.len() - 1), &original[..]);
    }

    #[test]
    fn pq_shape_corners_including_m_not_dividing_dim_and_odd_m() {
        for &(dim, m) in &[(1usize, 1usize), (7, 3), (25, 8), (100, 7), (100, 16), (960, 5)] {
            let data = gaussian_rows(64, dim, dim as u64 ^ m as u64);
            let store = PqStore::build(&data, dim, m, 5);
            assert_eq!(store.m(), clamp_m(dim, m));
            assert_eq!(store.ds(), dim.div_ceil(store.m()));
            assert_eq!(store.row_bytes(), (store.m() + 1) / 2);
            assert_eq!(store.len(), 64);
            if store.m() & 1 == 1 {
                // Odd m: phantom high nibble of the last byte must be 0.
                for i in 0..store.len() {
                    assert_eq!(store.code(i)[store.row_bytes() - 1] >> 4, 0);
                }
            }
            let lut = store.lut(crate::distance::Metric::L2, &data[..dim]);
            assert_eq!(lut.row_bytes(), store.row_bytes());
            // Self-distance must be among the smallest — sanity that the
            // ADC tables line up with the codes.
            let self_d = store.distance(&lut, 0);
            let far: Vec<f32> = (0..store.len()).map(|i| store.distance(&lut, i)).collect();
            let smaller = far.iter().filter(|&&d| d < self_d).count();
            assert!(smaller <= 8, "self-distance not near-minimal: {smaller} closer");
        }
    }

    #[test]
    fn pq_adc_error_within_quantization_bound() {
        // ADC distance vs the exact f32 table sum: the u8 quantization
        // errs by at most delta/2 per subspace (DESIGN.md bound).
        let dim = 32;
        let m = 8;
        let data = gaussian_rows(128, dim, 9);
        let store = PqStore::build(&data, dim, m, 1);
        for metric in [Metric::L2, Metric::Angular, Metric::Ip] {
            let q = &data[5 * dim..6 * dim];
            let lut = store.lut(metric, q);
            for i in 0..store.len() {
                // Exact f32 ADC: sum the true per-subspace table values.
                let mut exact = match metric {
                    Metric::Angular => 1.0f64,
                    _ => 0.0,
                };
                for j in 0..m {
                    let code = (store.code(i)[j / 2] >> (4 * (j % 2))) & 0x0F;
                    let cb = &store.codebooks()[(j * PQ_K + code as usize) * store.ds()..][..store.ds()];
                    for d in 0..store.ds() {
                        let full = j * store.ds() + d;
                        let qv = if full < dim { q[full] } else { 0.0 };
                        match metric {
                            Metric::L2 => exact += ((qv - cb[d]) * (qv - cb[d])) as f64,
                            _ => exact -= (qv * cb[d]) as f64,
                        }
                    }
                }
                let got = store.distance(&lut, i) as f64;
                // m * delta/2 rounding + a little f32 slack.
                let bound = 1e-3 + m as f64 * 0.5 * 1e-3
                    + (exact.abs() + 1.0) * 1e-5
                    + m as f64 * 0.5 * lut_delta(&lut);
                assert!(
                    (got - exact).abs() <= bound,
                    "metric {metric:?} row {i}: got {got} exact {exact} bound {bound}"
                );
            }
        }
    }

    fn lut_delta(lut: &crate::distance::simd::PqLut) -> f64 {
        // Recover delta from decode: decode(1) - decode(0).
        (lut.decode(1) - lut.decode(0)) as f64
    }

    #[test]
    fn pq_store_is_at_most_one_eighth_of_f32() {
        let n = 2048;
        let dim = 64;
        let data = gaussian_rows(n, dim, 13);
        let store = PqStore::build(&data, dim, 16, 2);
        let f32_bytes = n * dim * 4;
        assert!(
            store.bytes() * 8 <= f32_bytes,
            "pq bytes {} > 1/8 of f32 bytes {}",
            store.bytes(),
            f32_bytes
        );
    }

    #[test]
    fn pq_from_parts_rejects_malformed_shapes() {
        let data = gaussian_rows(32, 8, 1);
        let store = PqStore::build(&data, 8, 4, 1);
        let cb: Vec<f32> = store.codebooks().to_vec();
        let codes: Vec<u8> = store.codes().to_vec();
        assert!(PqStore::from_parts(0, 4, cb.clone().into(), codes.clone().into()).is_err());
        assert!(PqStore::from_parts(8, 0, cb.clone().into(), codes.clone().into()).is_err());
        assert!(PqStore::from_parts(8, 9, cb.clone().into(), codes.clone().into()).is_err());
        // Wrong codebook length.
        assert!(PqStore::from_parts(8, 4, cb[1..].to_vec().into(), codes.clone().into()).is_err());
        // Ragged code bytes.
        assert!(PqStore::from_parts(8, 4, cb.clone().into(), codes[1..].to_vec().into()).is_err());
        // Non-finite codebook entry.
        let mut bad = cb.clone();
        bad[3] = f32::NAN;
        assert!(PqStore::from_parts(8, 4, bad.into(), codes.clone().into()).is_err());
        // And the well-formed parts round-trip.
        let rt = PqStore::from_parts(8, 4, cb.into(), codes.into()).unwrap();
        assert_eq!(rt.codes(), store.codes());
        assert_eq!(rt.len(), store.len());
    }

    #[test]
    fn pq_scatter_row_builds_position_major_blocks() {
        let rb = 3;
        let mut blocks = Vec::new();
        let rows: Vec<Vec<u8>> = (0..40u8).map(|i| vec![i, i ^ 0x55, i ^ 0xAA]).collect();
        for (slot, row) in rows.iter().enumerate() {
            scatter_row(&mut blocks, rb, slot, row);
        }
        assert_eq!(blocks.len(), 2 * block_bytes(rb));
        for (slot, row) in rows.iter().enumerate() {
            let base = (slot / PQ_BLOCK) * block_bytes(rb);
            for p in 0..rb {
                assert_eq!(blocks[base + p * PQ_BLOCK + slot % PQ_BLOCK], row[p]);
            }
        }
    }
}
