//! The v1/v2 sequential-stream snapshot format, kept as a compatibility
//! shim: one little-endian stream (`CRNN` magic + version) carrying the
//! vector set, the layered graph, the quantized codes, the variant
//! configuration and — since v2 — an optional id → tenant/tags metadata
//! section plus the mutation-state tail (tombstone bitset, free-slot
//! list, insert-level RNG state, frozen quantizer scale).
//!
//! The reader here is what keeps pre-container snapshots loading; the
//! writer is retained so the byte-offset corruption fixtures in the tests
//! below stay exact. Readers are hostile-input hardened: every `u64`
//! length field is overflow-checked against the file size before any
//! allocation, the tombstone count may never exceed the point count, the
//! bitset may not mark slots beyond the point count, and every free-list
//! entry must be a marked, unique, in-range slot.

use super::reader::R;
use super::writer::W;
use super::MAGIC;
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::metadata::MetadataStore;
use crate::anns::tombstones::Tombstones;
use crate::anns::VectorSet;
use crate::bail;
use crate::distance::quant::QuantizedStore;
use crate::distance::Metric;
use crate::util::error::{Context, Error, Result};
use crate::variants::{decode_action, encode_action, Module, VariantConfig};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// v2 appended the mutation-state tail (tombstone bitset + free list +
/// insert-level RNG state + frozen quantizer scale). The reader still
/// accepts v1 files (no tail; empty mutation state, re-fit scale).
pub(crate) const VERSION_V2: u32 = 2;

/// Write a v2 sequential-stream snapshot (index only).
pub(crate) fn save_v2(idx: &crate::anns::glass::GlassIndex, path: &Path) -> Result<()> {
    save_v2_impl(idx, None, path)
}

/// Write a v2 sequential-stream snapshot with the metadata section.
pub(crate) fn save_v2_with_metadata(
    idx: &crate::anns::glass::GlassIndex,
    metadata: &MetadataStore,
    path: &Path,
) -> Result<()> {
    save_v2_impl(idx, Some(metadata), path)
}

fn save_v2_impl(
    idx: &crate::anns::glass::GlassIndex,
    metadata: Option<&MetadataStore>,
    path: &Path,
) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut bw = BufWriter::new(f);
    let mut w = W(&mut bw);
    w.0.write_all(MAGIC)?;
    w.u32(VERSION_V2)?;
    // Vector set.
    let g = &idx.graph;
    w.u32(g.vectors.dim as u32)?;
    w.u32(match g.vectors.metric {
        Metric::L2 => 0,
        Metric::Angular => 1,
        Metric::Ip => 2,
    })?;
    w.f32s(&g.vectors.data)?;
    // Graph.
    w.u32(g.m as u32)?;
    w.u32(g.entry)?;
    w.u32(g.max_level as u32)?;
    w.u8s(&g.levels)?;
    w.u32s(&g.layer0)?;
    w.u32s(&g.entry_points)?;
    w.u32(g.upper.len() as u32)?;
    for layer in &g.upper {
        w.u64(layer.len() as u64)?;
        // Deterministic output: sort by node id.
        let mut keys: Vec<u32> = layer.keys().copied().collect();
        keys.sort_unstable();
        for k in keys {
            w.u32(k)?;
            w.u32s(&layer[&k])?;
        }
    }
    // Config (via the stable action encoding).
    for module in Module::ALL {
        let a = encode_action(&idx.config, module);
        w.u64(a.len() as u64)?;
        for v in a {
            w.f64(v)?;
        }
    }
    // v2: metadata section — a presence flag, then (when present) the
    // store's interned columns: row count, name table, per-row tenant name
    // ids, row-delimiting tag offsets, and the flat tag name ids. Plain
    // [`save_v2`] writes flag 0 only, so index-only snapshots cost 8
    // extra bytes and round-trip unchanged.
    match metadata {
        None => w.u64(0)?,
        Some(meta) => {
            crate::ensure!(
                meta.len() <= g.len(),
                "metadata store has {} rows but the index has {} points",
                meta.len(),
                g.len()
            );
            w.u64(1)?;
            w.u64(meta.len() as u64)?;
            let names = meta.names();
            w.u64(names.len() as u64)?;
            for name in names {
                w.u8s(name.as_bytes())?;
            }
            w.u32s(meta.tenants())?;
            let mut offsets = Vec::with_capacity(meta.len() + 1);
            let mut tag_ids: Vec<u32> = Vec::new();
            offsets.push(0u64);
            for row in meta.tags() {
                tag_ids.extend_from_slice(row);
                offsets.push(tag_ids.len() as u64);
            }
            w.u64s(&offsets)?;
            w.u32s(&tag_ids)?;
        }
    }
    // v2: mutation state — declared tombstone count, bitset words, free
    // list, insert-level RNG state (4 fixed u64s). The count is redundant
    // with the words' popcount; writing both lets the reader cross-check
    // a corrupted file. Persisting the RNG state keeps post-reload online
    // inserts on the exact stream the snapshot was on.
    w.u64(idx.deleted.count() as u64)?;
    w.u64s(idx.deleted.words())?;
    w.u32s(&idx.free)?;
    for x in idx.rng_state() {
        w.u64(x)?;
    }
    // The frozen quantizer scale (exact f32 bits): codes are re-derived
    // from it at load, bit-identical to the saved store even when rows
    // were appended online (a load-time re-fit over base+inserted rows
    // would shift the scale and silently change quantized search).
    w.u32(idx.quant.scale.to_bits())?;
    bw.flush()?;
    Ok(())
}

/// Load a v1/v2 sequential-stream snapshot. Codes and degree metadata are
/// rebuilt from the payload; the codes re-derive from the **persisted**
/// frozen scale (v2), never a re-fit, so an index that absorbed online
/// inserts restores bit-identically. v1 files predate the metadata and
/// mutation sections and load with everything-live defaults.
pub(crate) fn load(
    path: &Path,
) -> Result<(crate::anns::glass::GlassIndex, Option<MetadataStore>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let limit = f
        .metadata()
        .with_context(|| format!("stat {path:?}"))?
        .len();
    let mut br = BufReader::new(f);
    let mut r = R { inner: &mut br, limit };
    let mut magic = [0u8; 4];
    r.inner.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a CRINN index file");
    }
    let version = r.u32()?;
    if version != 1 && version != VERSION_V2 {
        bail!("unsupported index version {version}");
    }
    let dim = r.u32()? as usize;
    let metric = match r.u32()? {
        0 => Metric::L2,
        1 => Metric::Angular,
        2 => Metric::Ip,
        m => bail!("bad metric tag {m}"),
    };
    let data = r.f32s()?;
    let vs = VectorSet::new(data, dim, metric);

    let m = r.u32()? as usize;
    let entry = r.u32()?;
    let max_level = r.u32()? as u8;
    let levels = r.u8s()?;
    let layer0 = r.u32s()?;
    let entry_points = r.u32s()?;
    let n_layers = r.u32()? as usize;

    let mut graph = HnswGraph::new(vs, m);
    crate::ensure!(graph.layer0.len() == layer0.len(), "layer0 size mismatch");
    graph.layer0 = layer0.into();
    graph.levels = levels;
    graph.entry = entry;
    graph.max_level = max_level;
    graph.entry_points = entry_points;
    // Rebuild degree metadata from the sentinel layout.
    for i in 0..graph.len() as u32 {
        graph.degree0[i as usize] = graph.neighbors0_scan(i).len() as u16;
    }
    for l in 0..n_layers {
        // Each upper-layer entry is at least 12 bytes (u32 key + u64 len).
        let count = r.len(12)?;
        for _ in 0..count {
            let k = r.u32()?;
            let nbs = r.u32s()?;
            graph.set_neighbors_upper((l + 1) as u8, k, nbs);
        }
    }
    // Config.
    let mut config = VariantConfig::glass_baseline();
    for module in Module::ALL {
        let len = r.len(8)?;
        let mut a = Vec::with_capacity(len);
        for _ in 0..len {
            a.push(r.f64()?);
        }
        config = decode_action(&config, module, &a);
    }
    // v2: metadata section (v1 files predate it, like the mutation tail).
    let n_points = graph.len();
    let metadata = if version >= 2 {
        let has_meta = r.u64()?;
        crate::ensure!(
            has_meta <= 1,
            "corrupt index: metadata flag {has_meta} is not 0 or 1"
        );
        if has_meta == 1 {
            let n_meta = r.u64()?;
            crate::ensure!(
                n_meta <= n_points as u64,
                "corrupt index: metadata rows {n_meta} exceed point count {n_points}"
            );
            // Each name costs at least its 8-byte length prefix.
            let n_names = r.len(8)?;
            let mut names = Vec::with_capacity(n_names);
            for _ in 0..n_names {
                let raw = r.u8s()?;
                names.push(String::from_utf8(raw).map_err(|_| {
                    Error::msg("corrupt index: metadata name is not UTF-8".to_string())
                })?);
            }
            let tenants = r.u32s()?;
            crate::ensure!(
                tenants.len() as u64 == n_meta,
                "corrupt index: metadata tenant column has {} rows, expected {n_meta}",
                tenants.len()
            );
            let offsets = r.u64s()?;
            crate::ensure!(
                offsets.len() as u64 == n_meta + 1,
                "corrupt index: metadata tag offsets has {} entries, expected {}",
                offsets.len(),
                n_meta + 1
            );
            crate::ensure!(
                offsets.first() == Some(&0),
                "corrupt index: metadata tag offsets must start at 0"
            );
            crate::ensure!(
                offsets.windows(2).all(|w| w[0] <= w[1]),
                "corrupt index: metadata tag offsets are not monotone"
            );
            let tag_ids = r.u32s()?;
            crate::ensure!(
                *offsets.last().unwrap() == tag_ids.len() as u64,
                "corrupt index: metadata tag offsets end at {} but {} tag ids follow",
                offsets.last().unwrap(),
                tag_ids.len()
            );
            let tags: Vec<Vec<u32>> = offsets
                .windows(2)
                .map(|w| tag_ids[w[0] as usize..w[1] as usize].to_vec())
                .collect();
            let store = MetadataStore::from_columns(names, tenants, tags)
                .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
            Some(store)
        } else {
            None
        }
    } else {
        None
    };
    // v2: mutation state (v1 files predate it — `from_parts`' defaults,
    // empty tombstones / empty free list / fresh RNG plus a re-fit scale,
    // are exactly the v1 semantics, so old snapshots keep loading).
    // Reject before reconstruction: a tombstone count larger than the
    // point count, a bitset marking phantom slots, or a free list naming
    // live/duplicate/out-of-range slots all indicate a corrupted or
    // hostile file (same discipline as the length-field hardening above —
    // fail with Err, never trust-and-crash later).
    let mutation_state = if version >= 2 {
        let declared_dead = r.u64()?;
        crate::ensure!(
            declared_dead <= n_points as u64,
            "corrupt index: tombstone count {declared_dead} exceeds point count {n_points}"
        );
        let words = r.u64s()?;
        let deleted = Tombstones::from_words(words, n_points)
            .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
        crate::ensure!(
            deleted.count() as u64 == declared_dead,
            "corrupt index: tombstone bitset popcount {} != declared count {declared_dead}",
            deleted.count()
        );
        let free = r.u32s()?;
        crate::anns::tombstones::validate_free_list(&free, &deleted, n_points)
            .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
        // Insert-level RNG state: 4 fixed u64s, any value accepted (the
        // degenerate all-zero orbit falls back to the default seed inside
        // `Rng::from_state`).
        let mut rng_state = [0u64; 4];
        for x in rng_state.iter_mut() {
            *x = r.u64()?;
        }
        // The frozen quantizer scale: codes rebuild from it
        // bit-identically (never re-fit — online-appended rows would
        // shift a refit scale).
        let scale = f32::from_bits(r.u32()?);
        crate::ensure!(
            scale.is_finite() && scale > 0.0,
            "corrupt index: quantizer scale {scale} is not a positive finite value"
        );
        Some((deleted, free, rng_state, scale))
    } else {
        None
    };
    graph
        .validate()
        .map_err(|e| Error::msg(format!("loaded graph invalid: {e}")))?;
    let quant = match &mutation_state {
        Some((_, _, _, scale)) => QuantizedStore::with_scale(&graph.vectors.data, dim, *scale),
        None => QuantizedStore::build(&graph.vectors.data, dim),
    };
    let mut idx = crate::anns::glass::GlassIndex::from_parts(graph, quant, config);
    if let Some((deleted, free, rng_state, _)) = mutation_state {
        idx.restore_mutation_state(deleted, free, rng_state);
    }
    Ok((idx, metadata))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::glass::GlassIndex;
    use crate::anns::persist::{load_glass, load_glass_with_metadata};
    use crate::anns::AnnIndex;
    use crate::dataset::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn glass_v2_roundtrip_identical_results() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 77);
        ds.compute_ground_truth(10);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("roundtrip_v2.idx");
        save_v2(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(loaded.len(), idx.len());
        for qi in 0..ds.n_queries() {
            let a = idx.search(ds.query_vec(qi), 10, 64);
            let b = loaded.search(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b, "query {qi} diverged after reload");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let path = tmp("garbage.idx");
        std::fs::write(&path, b"not an index").unwrap();
        assert!(load_glass(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncated_v2_file() {
        // A valid index cut off at various points must error cleanly (no
        // panic, no abort) — both mid-payload and mid-length-field.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 79);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let path = tmp("truncated_v2.idx");
        save_v2(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for frac in [0.05, 0.3, 0.6, 0.95] {
            let cut = (full.len() as f64 * frac) as usize;
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(load_glass(&path).is_err(), "truncated at {cut}/{} loaded", full.len());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_huge_length_fields() {
        // A hostile header whose u64 length field dwarfs the file must be
        // rejected by the file-size sanity cap before any allocation — the
        // old code fed it straight to `vec![0u8; n * 4]` and OOM-aborted.
        // Also cover the overflow case where `n * 4` wraps u64.
        for huge in [u64::MAX, u64::MAX / 2, 1u64 << 40] {
            let mut f = Vec::new();
            f.extend_from_slice(MAGIC);
            f.extend_from_slice(&VERSION_V2.to_le_bytes());
            f.extend_from_slice(&64u32.to_le_bytes()); // dim
            f.extend_from_slice(&0u32.to_le_bytes()); // metric = L2
            f.extend_from_slice(&huge.to_le_bytes()); // f32s length field
            let path = tmp(&format!("hugelen_{huge:x}.idx"));
            std::fs::write(&path, &f).unwrap();
            let err = load_glass(&path);
            assert!(err.is_err(), "length {huge} accepted");
            let msg = format!("{:#}", err.unwrap_err());
            assert!(msg.contains("corrupt index"), "unexpected error: {msg}");
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn mutation_state_v2_roundtrip() {
        use crate::anns::MutableAnnIndex;
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 80);
        ds.compute_ground_truth(10);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        for id in [3u32, 77, 150, 299] {
            idx.delete(id).unwrap();
        }
        let path = tmp("mutstate_v2.idx");
        save_v2(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(loaded.live_count(), idx.live_count());
        assert_eq!(loaded.deleted_count(), 4);
        for id in [3u32, 77, 150, 299] {
            assert!(loaded.is_deleted(id));
        }
        assert!(!loaded.is_deleted(4));
        // Deletes don't touch the vector payload, so the rebuilt quantizer
        // has the same scale and the reloaded search is bitwise identical
        // — and it must filter the persisted tombstones.
        for qi in 0..ds.n_queries() {
            let a = idx.search_with_dists(ds.query_vec(qi), 10, 64);
            let b = loaded.search_with_dists(ds.query_vec(qi), 10, 64);
            assert_eq!(a, b, "query {qi} diverged after reload");
            assert!(b.iter().all(|&(_, i)| ![3u32, 77, 150, 299].contains(&i)));
        }
        // Free list round-trips: a consolidated snapshot restores with its
        // recyclable slots, and the next insert reuses one.
        idx.consolidate().unwrap();
        save_v2(&idx, &path).unwrap();
        let mut reloaded = load_glass(&path).unwrap();
        assert_eq!(reloaded.deleted_count(), 0);
        assert_eq!(reloaded.live_count(), 296);
        let id = reloaded.insert(ds.query_vec(0)).unwrap();
        assert!([3u32, 77, 150, 299].contains(&id), "expected slot reuse, got {id}");
        assert_eq!(reloaded.len(), 300);
        // Stream determinism: the reloaded index resumed the persisted
        // insert-level RNG, so applying the SAME inserts to the original
        // in-memory index and to the snapshot produces identical graphs
        // (ids, sampled levels, edges) and identical search results.
        let id2 = idx.insert(ds.query_vec(0)).unwrap();
        assert_eq!(id2, id, "reloaded snapshot diverged on slot choice");
        for extra in 1..4 {
            let a = idx.insert(ds.query_vec(extra)).unwrap();
            let b = reloaded.insert(ds.query_vec(extra)).unwrap();
            assert_eq!(a, b);
        }
        assert_eq!(idx.graph.levels, reloaded.graph.levels, "level streams diverged");
        for qi in 0..ds.n_queries() {
            assert_eq!(
                idx.search_with_dists(ds.query_vec(qi), 10, 64),
                reloaded.search_with_dists(ds.query_vec(qi), 10, 64),
                "post-reload insert stream diverged at query {qi}"
            );
        }
        // Snapshot taken AFTER online inserts: the persisted frozen scale
        // restores bit-identical codes (no re-fit over the grown payload),
        // so the reload reproduces the in-memory quantized pipeline
        // exactly.
        save_v2(&idx, &path).unwrap();
        let post = load_glass(&path).unwrap();
        assert_eq!(post.quant.scale, idx.quant.scale, "scale was re-fit on load");
        for qi in 0..ds.n_queries() {
            assert_eq!(
                idx.search_with_dists(ds.query_vec(qi), 10, 64),
                post.search_with_dists(ds.query_vec(qi), 10, 64),
                "insert-grown snapshot diverged at query {qi}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    /// Byte offsets of the v2 mutation-state tail, from EOF:
    /// `[dead:8][wlen:8][words:8*wlen][flen:8][free:4*flen][rng:32][scale:4]`.
    fn patched(full: &[u8], from_end: usize, bytes: &[u8]) -> Vec<u8> {
        let mut f = full.to_vec();
        let at = f.len() - from_end;
        f[at..at + bytes.len()].copy_from_slice(bytes);
        f
    }

    #[test]
    fn rejects_corrupt_mutation_state() {
        use crate::anns::MutableAnnIndex;
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 81);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        idx.delete(5).unwrap();
        idx.consolidate().unwrap(); // free = [5]
        let path = tmp("mutcorrupt_v2.idx");
        save_v2(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // n=300 => 5 bitset words; tail = 8 (dead) + 8 (wlen) + 40 (words)
        // + 8 (flen) + 4 (one free id) + 32 (rng state) + 4 (scale) = 104.
        let tail = 104;
        assert!(load_glass(&path).is_ok(), "pristine file must load");

        // (a) Tombstone count exceeding the point count — the headline
        // hostile-file check (overflow-safe: u64::MAX never allocates).
        for huge in [u64::MAX, 301u64] {
            std::fs::write(&path, patched(&full, tail, &huge.to_le_bytes())).unwrap();
            let err = load_glass(&path).expect_err("hostile tombstone count accepted");
            assert!(
                format!("{err:#}").contains("tombstone count"),
                "unexpected error: {err:#}"
            );
        }
        // (b) Declared count inconsistent with the bitset popcount.
        std::fs::write(&path, patched(&full, tail, &2u64.to_le_bytes())).unwrap();
        let err = load_glass(&path).expect_err("popcount mismatch accepted");
        assert!(format!("{err:#}").contains("popcount"), "unexpected: {err:#}");
        // (c) Bitset marking a phantom slot beyond the point count (bit 63
        // of the last word = slot 319 of a 300-point index). The last word
        // sits 8 (word) + 8 (flen) + 4 (free) + 32 (rng) + 4 (scale) = 56
        // bytes from EOF.
        let mut bad_word = [0u8; 8];
        bad_word[7] = 0x80;
        std::fs::write(&path, patched(&full, 56, &bad_word)).unwrap();
        let err = load_glass(&path).expect_err("phantom tombstone accepted");
        assert!(format!("{err:#}").contains("corrupt index"), "unexpected: {err:#}");
        // (d) Free list naming a live (non-tombstoned) slot (the free id
        // sits 4 + 32 + 4 = 40 bytes from EOF).
        std::fs::write(&path, patched(&full, 40, &7u32.to_le_bytes())).unwrap();
        let err = load_glass(&path).expect_err("live free slot accepted");
        assert!(
            format!("{err:#}").contains("not a tombstoned point"),
            "unexpected: {err:#}"
        );
        // (e) An all-zero RNG state (the degenerate xoshiro orbit) is
        // defused to the default seed, not reproduced: the file loads and
        // inserts still sample useful levels (the state sits 32 + 4 = 36
        // bytes from EOF).
        std::fs::write(&path, patched(&full, 36, &[0u8; 32])).unwrap();
        let mut zeroed = load_glass(&path).unwrap();
        let id = zeroed.insert(&vec![0.0f32; 64]).unwrap();
        assert_eq!(id, 5, "freed slot must still be recycled");
        // (f) A hostile quantizer scale (NaN / zero / negative) is
        // rejected instead of poisoning every quantized distance.
        for bad in [f32::NAN, 0.0, -1.0, f32::INFINITY] {
            std::fs::write(&path, patched(&full, 4, &bad.to_bits().to_le_bytes())).unwrap();
            let err = load_glass(&path).expect_err("hostile scale accepted");
            assert!(
                format!("{err:#}").contains("quantizer scale"),
                "unexpected: {err:#}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loads_v1_snapshot_without_mutation_state() {
        use crate::anns::MutableAnnIndex;
        // A v1 file is byte-for-byte a v2 file minus the mutation-state
        // tail, with the version field patched — snapshots written before
        // the tail existed must keep loading, with everything-live
        // defaults and the legacy re-fit scale (identical to the frozen
        // one here, since no rows were appended).
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 5, 82);
        ds.compute_ground_truth(10);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let path = tmp("v1compat.idx");
        save_v2(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Tail with zero deletes/free slots: 8 (dead) + 8 (wlen) + 40
        // (words) + 8 (flen) + 0 (free) + 32 (rng) + 4 (scale) = 100, plus
        // the 8-byte has-metadata flag in front of it.
        let mut v1 = full[..full.len() - 108].to_vec();
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &v1).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(loaded.live_count(), 300);
        assert_eq!(loaded.deleted_count(), 0);
        for qi in 0..ds.n_queries() {
            assert_eq!(
                loaded.search_with_dists(ds.query_vec(qi), 10, 64),
                idx.search_with_dists(ds.query_vec(qi), 10, 64),
                "v1 load diverged at query {qi}"
            );
        }
        // Unknown future versions still fail loudly.
        let mut v9 = full.clone();
        v9[4..8].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&path, &v9).unwrap();
        let err = load_glass(&path).unwrap_err();
        assert!(format!("{err:#}").contains("unsupported index version"));
        std::fs::remove_file(&path).ok();
    }

    /// The shared fixture for the metadata-section tests: 300 points,
    /// tenant `t{id%3}` and tag `"even"` on even ids, so the name table is
    /// `["t0", "even", "t1", "t2"]` and the flat tag array has 150 ids.
    fn meta_fixture() -> MetadataStore {
        let mut meta = MetadataStore::new();
        for id in 0..300u32 {
            let tenant = format!("t{}", id % 3);
            let tags: &[&str] = if id % 2 == 0 { &["even"] } else { &[] };
            meta.push(Some(&tenant), tags);
        }
        meta
    }

    #[test]
    fn filtered_metadata_v2_roundtrip() {
        use crate::anns::{FilterExpr, MutableAnnIndex};
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 5, 83);
        ds.compute_ground_truth(10);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        idx.delete(5).unwrap(); // metadata + mutation state coexist
        let meta = meta_fixture();
        let path = tmp("metaroundtrip_v2.idx");
        save_v2_with_metadata(&idx, &meta, &path).unwrap();
        let (loaded, loaded_meta) = load_glass_with_metadata(&path).unwrap();
        let loaded_meta = loaded_meta.expect("metadata section must round-trip");
        assert_eq!(loaded_meta.names(), meta.names());
        assert_eq!(loaded_meta.tenants(), meta.tenants());
        assert_eq!(loaded_meta.tags(), meta.tags());
        assert_eq!(loaded.deleted_count(), 1);
        // Compiled filters agree, and filtered search is identical across
        // the reload (same graph, same tombstones, same bitset).
        let expr = FilterExpr::and(vec![FilterExpr::tenant("t1"), FilterExpr::tag("even")]);
        let f0 = meta.compile(&expr, idx.len());
        let f1 = loaded_meta.compile(&expr, loaded.len());
        assert_eq!(f0.words(), f1.words());
        for qi in 0..ds.n_queries() {
            assert_eq!(
                idx.search_filtered_with_dists(ds.query_vec(qi), 10, 64, Some(&f0)),
                loaded.search_filtered_with_dists(ds.query_vec(qi), 10, 64, Some(&f1)),
                "filtered search diverged after reload at query {qi}"
            );
        }
        // The plain loader still accepts the file (drops the metadata).
        let plain = load_glass(&path).unwrap();
        assert_eq!(
            plain.search_with_dists(ds.query_vec(0), 10, 64),
            loaded.search_with_dists(ds.query_vec(0), 10, 64)
        );
        // And an index-only snapshot reports no metadata.
        save_v2(&idx, &path).unwrap();
        let (_, none_meta) = load_glass_with_metadata(&path).unwrap();
        assert!(none_meta.is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_filtered_metadata_section() {
        // Byte-patch the metadata section of a valid snapshot. Layout for
        // the fixture (no deletes, n=300): from EOF, the 100-byte mutation
        // tail, then [tag_ids: 8 + 4*150][offsets: 8 + 8*301]
        // [tenants: 8 + 4*300][names payload: 10+12+10+10]
        // [n_names: 8][n_meta: 8][has_meta: 8].
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 84);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let meta = meta_fixture();
        let path = tmp("metacorrupt_v2.idx");
        save_v2_with_metadata(&idx, &meta, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        let tail = 100;
        let tag_ids_at = tail + 8 + 4 * 150; // count field of the flat tag array
        let offsets_at = tag_ids_at + 8 + 8 * 301; // count field of the offsets
        let tenants_at = offsets_at + 8 + 4 * 300; // count field of the tenant column
        let n_names_at = tenants_at + 42 + 8; // 42 payload bytes + its count
        let n_meta_at = n_names_at + 8;
        let has_meta_at = n_meta_at + 8;
        assert!(load_glass_with_metadata(&path).is_ok(), "pristine file must load");

        // (a) Row count exceeding the point count (and the overflow case).
        for bad in [301u64, u64::MAX] {
            std::fs::write(&path, patched(&full, n_meta_at, &bad.to_le_bytes())).unwrap();
            let err = load_glass_with_metadata(&path).expect_err("hostile row count accepted");
            assert!(format!("{err:#}").contains("corrupt index"), "unexpected: {err:#}");
        }
        // (b) A flag value that is neither 0 nor 1.
        std::fs::write(&path, patched(&full, has_meta_at, &7u64.to_le_bytes())).unwrap();
        let err = load_glass_with_metadata(&path).expect_err("hostile flag accepted");
        assert!(format!("{err:#}").contains("metadata flag"), "unexpected: {err:#}");
        // (c) A tenant name id beyond the name table (first tenant value
        // sits right after the tenant column's count field).
        std::fs::write(
            &path,
            patched(&full, tenants_at - 8, &999u32.to_le_bytes()),
        )
        .unwrap();
        let err = load_glass_with_metadata(&path).expect_err("out-of-range tenant accepted");
        assert!(format!("{err:#}").contains("out of range"), "unexpected: {err:#}");
        // (d) Offsets inconsistent with the flat tag array: shrinking the
        // final offset breaks monotonicity / the end-of-array cross-check.
        std::fs::write(
            &path,
            patched(&full, tag_ids_at + 8, &149u64.to_le_bytes()),
        )
        .unwrap();
        let err = load_glass_with_metadata(&path).expect_err("offset mismatch accepted");
        assert!(format!("{err:#}").contains("corrupt index"), "unexpected: {err:#}");
        // (e) A tag-array count that disagrees with the offsets.
        std::fs::write(&path, patched(&full, tag_ids_at, &149u64.to_le_bytes())).unwrap();
        let err = load_glass_with_metadata(&path).expect_err("short tag array accepted");
        assert!(format!("{err:#}").contains("corrupt index"), "unexpected: {err:#}");
        // (f) Truncation inside the metadata section.
        std::fs::write(&path, &full[..full.len() - offsets_at + 16]).unwrap();
        assert!(load_glass_with_metadata(&path).is_err(), "truncated metadata loaded");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn config_survives_v2_roundtrip() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 78);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("config_v2.idx");
        save_v2(&idx, &path).unwrap();
        let loaded = load_glass(&path).unwrap();
        assert_eq!(
            loaded.config.search.early_termination,
            idx.config.search.early_termination
        );
        assert_eq!(loaded.config.construction.m, idx.config.construction.m);
        assert_eq!(
            loaded.config.refine.precomputed_metadata,
            idx.config.refine.precomputed_metadata
        );
        std::fs::remove_file(&path).ok();
    }
}
