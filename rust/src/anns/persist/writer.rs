//! Little-endian stream-writer primitives shared by every snapshot
//! writer: fixed-width scalars plus `u64`-count-prefixed arrays.

use crate::util::error::Result;
use std::io::Write;

pub(crate) struct W<'a, T: Write>(pub(crate) &'a mut T);

impl<'a, T: Write> W<'a, T> {
    pub(crate) fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    pub(crate) fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    pub(crate) fn u8s(&mut self, v: &[u8]) -> Result<()> {
        self.u64(v.len() as u64)?;
        self.0.write_all(v)?;
        Ok(())
    }
    pub(crate) fn u64s(&mut self, v: &[u64]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}
