//! Little-endian stream-writer primitives shared by every snapshot
//! writer, plus the v3 paged-container save: each logical piece of the
//! index (vectors, codes, adjacency, …) becomes an independently
//! addressable section (see [`super::sections`]), with the big flat
//! arrays written as raw bytes so a reader can view them in place.

use super::sections::{self, SectionBuilder};
use crate::anns::metadata::MetadataStore;
use crate::anns::store::region::as_bytes;
use crate::distance::Metric;
use crate::util::error::Result;
use crate::variants::{encode_action, Module};
use std::io::Write;
use std::path::Path;

pub(crate) struct W<'a, T: Write>(pub(crate) &'a mut T);

impl<'a, T: Write> W<'a, T> {
    pub(crate) fn u32(&mut self, v: u32) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn u64(&mut self, v: u64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn f64(&mut self, v: f64) -> Result<()> {
        self.0.write_all(&v.to_le_bytes())?;
        Ok(())
    }
    pub(crate) fn f32s(&mut self, v: &[f32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    pub(crate) fn u32s(&mut self, v: &[u32]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    pub(crate) fn u8s(&mut self, v: &[u8]) -> Result<()> {
        self.u64(v.len() as u64)?;
        self.0.write_all(v)?;
        Ok(())
    }
    pub(crate) fn u64s(&mut self, v: &[u64]) -> Result<()> {
        self.u64(v.len() as u64)?;
        for x in v {
            self.0.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
}

/// Write a v3 paged snapshot. The raw-array sections ([`sections::SEC_VECTORS`],
/// [`sections::SEC_CODES`], [`sections::SEC_LAYER0`], [`sections::SEC_LEVELS`],
/// [`sections::SEC_DEGREE0`], [`sections::SEC_ENTRY_POINTS`]) are the in-memory
/// arrays verbatim; the structured sections reuse the count-prefixed
/// stream primitives above inside their payload.
pub(crate) fn save_v3(
    idx: &crate::anns::glass::GlassIndex,
    metadata: Option<&MetadataStore>,
    path: &Path,
) -> Result<()> {
    let g = &idx.graph;
    let mut b = SectionBuilder::new();

    // SEC_INDEX: the 40-byte fixed header every other section is
    // interpreted against.
    let mut buf = Vec::new();
    {
        let mut w = W(&mut buf);
        w.u32(g.vectors.dim as u32)?;
        w.u32(match g.vectors.metric {
            Metric::L2 => 0,
            Metric::Angular => 1,
            Metric::Ip => 2,
        })?;
        w.u64(g.len() as u64)?;
        w.u32(g.m as u32)?;
        w.u32(g.entry)?;
        w.u32(g.max_level as u32)?;
        // The frozen quantizer scale (exact f32 bits): the codes section
        // below was encoded under it, and post-load online inserts keep
        // encoding with it — never a re-fit.
        w.u32(idx.quant.scale.to_bits())?;
        w.u64(idx.deleted.count() as u64)?;
    }
    b.add(sections::SEC_INDEX, buf);

    b.add(sections::SEC_VECTORS, as_bytes(g.vectors.data.as_slice()).to_vec());
    b.add(sections::SEC_CODES, as_bytes(idx.quant.codes()).to_vec());
    b.add(sections::SEC_LAYER0, as_bytes(g.layer0.as_slice()).to_vec());
    b.add(sections::SEC_LEVELS, g.levels.clone());
    b.add(sections::SEC_DEGREE0, as_bytes(g.degree0.as_slice()).to_vec());
    b.add(sections::SEC_ENTRY_POINTS, as_bytes(g.entry_points.as_slice()).to_vec());

    // SEC_UPPER: sparse upper layers, sorted by node id per layer for
    // deterministic output.
    let mut buf = Vec::new();
    {
        let mut w = W(&mut buf);
        w.u32(g.upper.len() as u32)?;
        for layer in &g.upper {
            w.u64(layer.len() as u64)?;
            let mut keys: Vec<u32> = layer.keys().copied().collect();
            keys.sort_unstable();
            for k in keys {
                w.u32(k)?;
                w.u32s(&layer[&k])?;
            }
        }
    }
    b.add(sections::SEC_UPPER, buf);

    // SEC_CONFIG: via the stable action encoding (keeps the format
    // stable as knobs evolve).
    let mut buf = Vec::new();
    {
        let mut w = W(&mut buf);
        for module in Module::ALL {
            let a = encode_action(&idx.config, module);
            w.u64(a.len() as u64)?;
            for v in a {
                w.f64(v)?;
            }
        }
    }
    b.add(sections::SEC_CONFIG, buf);

    // SEC_METADATA (optional): the id → tenant/tags columns, same
    // interned shape as the v2 stream section.
    if let Some(meta) = metadata {
        crate::ensure!(
            meta.len() <= g.len(),
            "metadata store has {} rows but the index has {} points",
            meta.len(),
            g.len()
        );
        let mut buf = Vec::new();
        {
            let mut w = W(&mut buf);
            w.u64(meta.len() as u64)?;
            let names = meta.names();
            w.u64(names.len() as u64)?;
            for name in names {
                w.u8s(name.as_bytes())?;
            }
            w.u32s(meta.tenants())?;
            let mut offsets = Vec::with_capacity(meta.len() + 1);
            let mut tag_ids: Vec<u32> = Vec::new();
            offsets.push(0u64);
            for row in meta.tags() {
                tag_ids.extend_from_slice(row);
                offsets.push(tag_ids.len() as u64);
            }
            w.u64s(&offsets)?;
            w.u32s(&tag_ids)?;
        }
        b.add(sections::SEC_METADATA, buf);
    }

    // SEC_MUTATION: tombstone bitset words, free-slot list, insert-level
    // RNG state (the declared tombstone count lives in SEC_INDEX for the
    // popcount cross-check).
    let mut buf = Vec::new();
    {
        let mut w = W(&mut buf);
        w.u64s(idx.deleted.words())?;
        w.u32s(&idx.free)?;
        for x in idx.rng_state() {
            w.u64(x)?;
        }
    }
    b.add(sections::SEC_MUTATION, buf);

    // SEC_PQ_* (optional): layer-0 PQ fast-scan state — header (m), raw
    // f32 codebooks, raw packed 4-bit rows. Appended after every legacy
    // section so directory slots of PQ-less snapshots are unchanged.
    if let Some(pq) = idx.pq_store() {
        let mut buf = Vec::new();
        {
            let mut w = W(&mut buf);
            w.u32(pq.m() as u32)?;
            w.u32(0)?; // reserved
        }
        b.add(sections::SEC_PQ_META, buf);
        b.add(sections::SEC_PQ_CODEBOOKS, as_bytes(pq.codebooks()).to_vec());
        b.add(sections::SEC_PQ_CODES, as_bytes(pq.codes()).to_vec());
    }

    b.write_to(path)
}
