//! Hostile-input hardened stream-reader primitives shared by every
//! snapshot reader: fixed-width scalars plus `u64`-count-prefixed arrays,
//! with every length field overflow-checked against the file size before
//! any allocation sized by it.

use crate::util::error::{Error, Result};
use std::io::Read;

pub(crate) struct R<'a, T: Read> {
    pub(crate) inner: &'a mut T,
    /// Total file size in bytes — the sanity cap for every `u64` length
    /// field. A valid field can never describe more payload than the file
    /// holds, so anything larger is corruption (or a hostile header) and
    /// must return `Err` instead of feeding `vec![0u8; huge]` and
    /// OOM-aborting the process.
    pub(crate) limit: u64,
}

impl<'a, T: Read> R<'a, T> {
    pub(crate) fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    /// Read a `u64` element count and validate it against the file size
    /// (overflow-checked multiply by the per-element byte width) before any
    /// allocation sized by it.
    pub(crate) fn len(&mut self, elem_bytes: u64) -> Result<usize> {
        let n = self.u64()?;
        let bytes = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| Error::msg(format!("corrupt index: length field {n} overflows")))?;
        crate::ensure!(
            bytes <= self.limit,
            "corrupt index: length field {n} ({bytes} bytes) exceeds file size {}",
            self.limit
        );
        Ok(n as usize)
    }
    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    pub(crate) fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    pub(crate) fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.len(1)?;
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
    pub(crate) fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len(8)?;
        let mut raw = vec![0u8; n * 8];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }
}
