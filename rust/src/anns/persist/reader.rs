//! Hostile-input hardened stream-reader primitives shared by every
//! snapshot reader (fixed-width scalars plus `u64`-count-prefixed arrays,
//! with every length field overflow-checked against the file size before
//! any allocation sized by it), and the v3 paged-container load.
//!
//! The v3 loader is "map (or read) the file, validate the directory,
//! point slices at it": the directory and every section checksum are
//! verified up front — on BOTH the heap and mmap paths — then the
//! zero-copy sections (SQ8 codes, layer-0 adjacency) become
//! [`Segment`] views straight into the region while the small or
//! structured sections parse into owned values through the same
//! hardened primitives the v1/v2 shim uses.

use super::sections::{self, Directory};
use crate::anns::hnsw::graph::HnswGraph;
use crate::anns::metadata::MetadataStore;
use crate::anns::store::region::{MappedRegion, Segment};
use crate::anns::tombstones::Tombstones;
use crate::anns::VectorSet;
use crate::bail;
use crate::distance::quant::QuantizedStore;
use crate::distance::Metric;
use crate::util::error::{Error, Result};
use crate::variants::{decode_action, Module, VariantConfig};
use std::io::Read;
use std::path::Path;
use std::sync::Arc;

pub(crate) struct R<'a, T: Read> {
    pub(crate) inner: &'a mut T,
    /// Total file size in bytes — the sanity cap for every `u64` length
    /// field. A valid field can never describe more payload than the file
    /// holds, so anything larger is corruption (or a hostile header) and
    /// must return `Err` instead of feeding `vec![0u8; huge]` and
    /// OOM-aborting the process.
    pub(crate) limit: u64,
}

impl<'a, T: Read> R<'a, T> {
    pub(crate) fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.inner.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }
    pub(crate) fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    pub(crate) fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.inner.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    /// Read a `u64` element count and validate it against the file size
    /// (overflow-checked multiply by the per-element byte width) before any
    /// allocation sized by it.
    pub(crate) fn len(&mut self, elem_bytes: u64) -> Result<usize> {
        let n = self.u64()?;
        let bytes = n
            .checked_mul(elem_bytes)
            .ok_or_else(|| Error::msg(format!("corrupt index: length field {n} overflows")))?;
        crate::ensure!(
            bytes <= self.limit,
            "corrupt index: length field {n} ({bytes} bytes) exceeds file size {}",
            self.limit
        );
        Ok(n as usize)
    }
    pub(crate) fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    pub(crate) fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.len(4)?;
        let mut raw = vec![0u8; n * 4];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
    pub(crate) fn u8s(&mut self) -> Result<Vec<u8>> {
        let n = self.len(1)?;
        let mut v = vec![0u8; n];
        self.inner.read_exact(&mut v)?;
        Ok(v)
    }
    pub(crate) fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.len(8)?;
        let mut raw = vec![0u8; n * 8];
        self.inner.read_exact(&mut raw)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }
}

/// Load a v3 paged snapshot. `mmap = true` serves the zero-copy sections
/// (codes, layer-0 adjacency) straight out of a private read-only file
/// mapping; `mmap = false` reads the file into an aligned heap region
/// and views the same offsets there — the two paths interpret identical
/// bytes through identical code, so their search results are bitwise
/// equal.
pub(crate) fn load_v3(
    path: &Path,
    mmap: bool,
) -> Result<(crate::anns::glass::GlassIndex, Option<MetadataStore>)> {
    let region = Arc::new(if mmap {
        MappedRegion::map_file(path)?
    } else {
        MappedRegion::read_file(path)?
    });
    let dir = Directory::parse(&region)?;
    // Integrity first, on both paths: after this, every section byte the
    // loader (or a served search) touches has a verified checksum.
    dir.verify_checksums(&region)?;

    // SEC_INDEX: the fixed header the other sections are sized against.
    let (hoff, hlen) = dir.require(sections::SEC_INDEX)?;
    crate::ensure!(
        hlen == 40,
        "corrupt index: index header section is {hlen} bytes, expected 40"
    );
    let mut s = &region.as_slice()[hoff..hoff + hlen];
    let mut r = R { inner: &mut s, limit: hlen as u64 };
    let dim = r.u32()? as usize;
    let metric = match r.u32()? {
        0 => Metric::L2,
        1 => Metric::Angular,
        2 => Metric::Ip,
        m => bail!("bad metric tag {m}"),
    };
    let n = r.u64()?;
    let m = r.u32()? as usize;
    let entry = r.u32()?;
    let max_level = r.u32()?;
    let scale = f32::from_bits(r.u32()?);
    let declared_dead = r.u64()?;
    crate::ensure!(dim >= 1, "corrupt index: dimension is 0");
    crate::ensure!(m >= 1, "corrupt index: graph degree m is 0");
    crate::ensure!(
        max_level <= u8::MAX as u32,
        "corrupt index: max level {max_level} exceeds the level cap"
    );
    crate::ensure!(
        scale.is_finite() && scale > 0.0,
        "corrupt index: quantizer scale {scale} is not a positive finite value"
    );

    // Every raw-array section must be exactly the size the header
    // implies — u64 arithmetic so hostile counts can't overflow.
    let sized = |id: u32, elem_bytes: u64, elems: u64, what: &str| -> Result<(usize, usize)> {
        let (off, len) = dir.require(id)?;
        let want = elems
            .checked_mul(elem_bytes)
            .ok_or_else(|| Error::msg(format!("corrupt index: {what} size overflows")))?;
        crate::ensure!(
            len as u64 == want,
            "corrupt index: {what} section is {len} bytes, expected {want}"
        );
        Ok((off, len))
    };
    let per_point = |k: u64| n.checked_mul(k);
    let nd = per_point(dim as u64)
        .ok_or_else(|| Error::msg("corrupt index: point count overflows".to_string()))?;
    let nm0 = per_point(m as u64 * 2)
        .ok_or_else(|| Error::msg("corrupt index: adjacency size overflows".to_string()))?;

    let (voff, _) = sized(sections::SEC_VECTORS, 4, nd, "vectors")?;
    let (coff, _) = sized(sections::SEC_CODES, 1, nd, "codes")?;
    let (loff, _) = sized(sections::SEC_LAYER0, 4, nm0, "layer0 adjacency")?;
    let (lvoff, _) = sized(sections::SEC_LEVELS, 1, n, "levels")?;
    let (doff, _) = sized(sections::SEC_DEGREE0, 2, n, "degree metadata")?;
    let (eoff, elen) = dir.require(sections::SEC_ENTRY_POINTS)?;
    crate::ensure!(
        elen % 4 == 0,
        "corrupt index: entry-point section is {elen} bytes, not a u32 array"
    );

    // n (and n*dim, n*m0) fit usize: the sections above exist in a real
    // file, so each product is bounded by the file size.
    let n = n as usize;
    let data = region.view::<f32>(voff, n * dim)?.to_vec();
    let vs = VectorSet::new(data, dim, metric);
    let levels = region.view::<u8>(lvoff, n)?.to_vec();
    let degree0 = region.view::<u16>(doff, n)?.to_vec();
    let entry_points = region.view::<u32>(eoff, elen / 4)?.to_vec();
    // The zero-copy sections: views into the shared region, owned by the
    // index only through the refcount. Mutation promotes to heap (CoW).
    let layer0: Segment<u32> = Segment::from_region(Arc::clone(&region), loff, n * m * 2)?;
    let codes: Segment<i8> = Segment::from_region(Arc::clone(&region), coff, n * dim)?;

    let mut graph = HnswGraph::from_storage(
        vs,
        m,
        levels,
        layer0,
        degree0,
        entry,
        max_level as u8,
        entry_points,
    )
    .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;

    // SEC_UPPER: sparse upper layers.
    let (uoff, ulen) = dir.require(sections::SEC_UPPER)?;
    let mut s = &region.as_slice()[uoff..uoff + ulen];
    {
        let mut r = R { inner: &mut s, limit: ulen as u64 };
        let n_layers = r.u32()? as usize;
        crate::ensure!(
            n_layers <= u8::MAX as usize,
            "corrupt index: {n_layers} upper layers exceed the level cap"
        );
        for l in 0..n_layers {
            // Each upper-layer entry is at least 12 bytes (u32 key + u64 len).
            let count = r.len(12)?;
            for _ in 0..count {
                let k = r.u32()?;
                crate::ensure!(
                    (k as usize) < n,
                    "corrupt index: upper-layer node {k} out of range"
                );
                let nbs = r.u32s()?;
                graph.set_neighbors_upper((l + 1) as u8, k, nbs);
            }
        }
    }
    crate::ensure!(s.is_empty(), "corrupt index: trailing bytes in upper-layer section");

    // SEC_CONFIG: via the stable action encoding.
    let (cfoff, cflen) = dir.require(sections::SEC_CONFIG)?;
    let mut s = &region.as_slice()[cfoff..cfoff + cflen];
    let mut config = VariantConfig::glass_baseline();
    {
        let mut r = R { inner: &mut s, limit: cflen as u64 };
        for module in Module::ALL {
            let len = r.len(8)?;
            let mut a = Vec::with_capacity(len);
            for _ in 0..len {
                a.push(r.f64()?);
            }
            config = decode_action(&config, module, &a);
        }
    }
    crate::ensure!(s.is_empty(), "corrupt index: trailing bytes in config section");

    // SEC_METADATA (optional): same column validation as the v2 shim.
    let metadata = match dir.get(sections::SEC_METADATA) {
        Some((moff, mlen)) => Some(parse_metadata(&region.as_slice()[moff..moff + mlen], n)?),
        None => None,
    };

    // SEC_MUTATION: tombstones + free list + RNG state, with the same
    // rejection rules as the v2 tail (phantom slots, popcount mismatch,
    // live/duplicate/out-of-range free entries).
    let (moff, mlen) = dir.require(sections::SEC_MUTATION)?;
    let mut s = &region.as_slice()[moff..moff + mlen];
    let (deleted, free, rng_state);
    {
        let mut r = R { inner: &mut s, limit: mlen as u64 };
        let words = r.u64s()?;
        deleted = Tombstones::from_words(words, n)
            .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
        crate::ensure!(
            deleted.count() as u64 == declared_dead,
            "corrupt index: tombstone bitset popcount {} != declared count {declared_dead}",
            deleted.count()
        );
        free = r.u32s()?;
        crate::anns::tombstones::validate_free_list(&free, &deleted, n)
            .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
        let mut state = [0u64; 4];
        for x in state.iter_mut() {
            *x = r.u64()?;
        }
        rng_state = state;
    }
    crate::ensure!(s.is_empty(), "corrupt index: trailing bytes in mutation section");

    graph
        .validate()
        .map_err(|e| Error::msg(format!("loaded graph invalid: {e}")))?;
    let quant = QuantizedStore::from_parts(dim, scale, codes)
        .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
    let mut idx = crate::anns::glass::GlassIndex::from_parts(graph, quant, config);
    idx.restore_mutation_state(deleted, free, rng_state);

    // SEC_PQ_* (optional): layer-0 PQ fast-scan state. A present meta
    // section makes the codebook and packed-code sections mandatory, and
    // both are sized against the header's point count before any view is
    // taken — the codes become zero-copy [`Segment`] views exactly like
    // the SQ8 section above.
    if let Some((poff, plen)) = dir.get(sections::SEC_PQ_META) {
        let mut s = &region.as_slice()[poff..poff + plen];
        let pq_m;
        {
            let mut r = R { inner: &mut s, limit: plen as u64 };
            pq_m = r.u32()? as usize;
            let _reserved = r.u32()?;
        }
        crate::ensure!(s.is_empty(), "corrupt index: trailing bytes in pq meta section");
        crate::ensure!(
            pq_m >= 1 && pq_m <= dim.min(256),
            "corrupt index: pq subquantizer count {pq_m} out of range for dimension {dim}"
        );
        let ds = dim.div_ceil(pq_m);
        let row_bytes = pq_m.div_ceil(2);
        let cb_elems = (pq_m * 16 * ds) as u64;
        let code_elems = (n as u64)
            .checked_mul(row_bytes as u64)
            .ok_or_else(|| Error::msg("corrupt index: pq code size overflows".to_string()))?;
        let (cboff, _) = sized(sections::SEC_PQ_CODEBOOKS, 4, cb_elems, "pq codebooks")?;
        let (pcoff, _) = sized(sections::SEC_PQ_CODES, 1, code_elems, "pq codes")?;
        let codebooks: Segment<f32> =
            Segment::from_region(Arc::clone(&region), cboff, pq_m * 16 * ds)?;
        let pq_codes: Segment<u8> = Segment::from_region(Arc::clone(&region), pcoff, n * row_bytes)?;
        let store = crate::anns::store::pq::PqStore::from_parts(dim, pq_m, codebooks, pq_codes)
            .map_err(|e| Error::msg(format!("corrupt index: pq state: {e}")))?;
        crate::ensure!(
            store.len() == n,
            "corrupt index: pq codes cover {} rows but the index has {n} points",
            store.len()
        );
        idx.attach_pq(store);
    }

    Ok((idx, metadata))
}

/// Parse the optional metadata section into a [`MetadataStore`], with
/// the same hostile-input rules as the v2 stream section: row count
/// capped by the point count, tenant/offset/tag columns cross-checked,
/// name ids range-checked by `from_columns`.
fn parse_metadata(bytes: &[u8], n_points: usize) -> Result<MetadataStore> {
    let mut s = bytes;
    let store;
    {
        let mut r = R { inner: &mut s, limit: bytes.len() as u64 };
        let n_meta = r.u64()?;
        crate::ensure!(
            n_meta <= n_points as u64,
            "corrupt index: metadata rows {n_meta} exceed point count {n_points}"
        );
        // Each name costs at least its 8-byte length prefix.
        let n_names = r.len(8)?;
        let mut names = Vec::with_capacity(n_names);
        for _ in 0..n_names {
            let raw = r.u8s()?;
            names.push(String::from_utf8(raw).map_err(|_| {
                Error::msg("corrupt index: metadata name is not UTF-8".to_string())
            })?);
        }
        let tenants = r.u32s()?;
        crate::ensure!(
            tenants.len() as u64 == n_meta,
            "corrupt index: metadata tenant column has {} rows, expected {n_meta}",
            tenants.len()
        );
        let offsets = r.u64s()?;
        crate::ensure!(
            offsets.len() as u64 == n_meta + 1,
            "corrupt index: metadata tag offsets has {} entries, expected {}",
            offsets.len(),
            n_meta + 1
        );
        crate::ensure!(
            offsets.first() == Some(&0),
            "corrupt index: metadata tag offsets must start at 0"
        );
        crate::ensure!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "corrupt index: metadata tag offsets are not monotone"
        );
        let tag_ids = r.u32s()?;
        crate::ensure!(
            *offsets.last().unwrap() == tag_ids.len() as u64,
            "corrupt index: metadata tag offsets end at {} but {} tag ids follow",
            offsets.last().unwrap(),
            tag_ids.len()
        );
        let tags: Vec<Vec<u32>> = offsets
            .windows(2)
            .map(|w| tag_ids[w[0] as usize..w[1] as usize].to_vec())
            .collect();
        store = MetadataStore::from_columns(names, tenants, tags)
            .map_err(|e| Error::msg(format!("corrupt index: {e}")))?;
    }
    crate::ensure!(s.is_empty(), "corrupt index: trailing bytes in metadata section");
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::glass::GlassIndex;
    use crate::anns::persist::{
        load_glass, load_glass_mmap, load_glass_mmap_with_metadata, load_glass_with_metadata,
        save_glass, save_glass_with_metadata,
    };
    use crate::anns::{AnnIndex, MutableAnnIndex};
    use crate::dataset::synth;
    use crate::variants::VariantConfig;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    fn patched_at(full: &[u8], at: usize, bytes: &[u8]) -> Vec<u8> {
        let mut f = full.to_vec();
        f[at..at + bytes.len()].copy_from_slice(bytes);
        f
    }

    /// Directory slot of the i-th section in `save_v3`'s insertion order:
    /// INDEX, VECTORS, CODES, LAYER0, LEVELS, DEGREE0, ENTRY_POINTS,
    /// UPPER, CONFIG, [METADATA], MUTATION.
    fn entry_at(i: usize) -> usize {
        sections::HEADER_BYTES + i * sections::DIR_ENTRY_BYTES
    }

    #[test]
    fn v3_roundtrip_heap_and_mmap_bitwise_identical() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 90);
        ds.compute_ground_truth(10);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        let path = tmp("roundtrip_v3.idx");
        save_glass(&idx, &path).unwrap();
        let heap = load_glass(&path).unwrap();
        let mapped = load_glass_mmap(&path).unwrap();
        assert_eq!(heap.len(), idx.len());
        assert_eq!(mapped.len(), idx.len());
        // The mmap load serves adjacency as a region view (zero-copy);
        // the heap load views a heap region — neither copied into a Vec.
        assert!(mapped.graph.layer0.is_mapped());
        assert!(heap.graph.layer0.is_mapped());
        assert_eq!(heap.quant.scale, idx.quant.scale);
        assert_eq!(mapped.quant.scale, idx.quant.scale);
        for qi in 0..ds.n_queries() {
            let want = idx.search_with_dists(ds.query_vec(qi), 10, 64);
            assert_eq!(heap.search_with_dists(ds.query_vec(qi), 10, 64), want, "heap q{qi}");
            assert_eq!(mapped.search_with_dists(ds.query_vec(qi), 10, 64), want, "mmap q{qi}");
        }
        // Batch path too (the conformance suite covers this per metric;
        // this is the cheap smoke check).
        let queries: Vec<&[f32]> = (0..5).map(|qi| ds.query_vec(qi)).collect();
        assert_eq!(
            heap.search_batch(&queries, 10, 64),
            mapped.search_batch(&queries, 10, 64)
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_mutation_state_roundtrip_and_insert_determinism() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 91);
        ds.compute_ground_truth(10);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        for id in [3u32, 77, 150, 299] {
            idx.delete(id).unwrap();
        }
        let path = tmp("mutstate_v3.idx");
        save_glass(&idx, &path).unwrap();
        for load in [load_glass, load_glass_mmap] {
            let loaded = load(&path).unwrap();
            assert_eq!(loaded.live_count(), idx.live_count());
            assert_eq!(loaded.deleted_count(), 4);
            for id in [3u32, 77, 150, 299] {
                assert!(loaded.is_deleted(id));
            }
            for qi in 0..ds.n_queries() {
                assert_eq!(
                    loaded.search_with_dists(ds.query_vec(qi), 10, 64),
                    idx.search_with_dists(ds.query_vec(qi), 10, 64),
                    "query {qi} diverged after reload"
                );
            }
        }
        // Free list + RNG stream: a consolidated snapshot recycles slots
        // and replays the same insert stream as the in-memory index —
        // including when the snapshot is mmap-served (inserts promote the
        // mapped sections to heap copy-on-write).
        idx.consolidate().unwrap();
        save_glass(&idx, &path).unwrap();
        let mut reloaded = load_glass_mmap(&path).unwrap();
        assert!(reloaded.graph.layer0.is_mapped());
        assert_eq!(reloaded.deleted_count(), 0);
        let id = reloaded.insert(ds.query_vec(0)).unwrap();
        let id2 = idx.insert(ds.query_vec(0)).unwrap();
        assert_eq!(id2, id, "reloaded snapshot diverged on slot choice");
        assert!(!reloaded.graph.layer0.is_mapped(), "insert must promote to heap");
        for extra in 1..4 {
            assert_eq!(
                idx.insert(ds.query_vec(extra)).unwrap(),
                reloaded.insert(ds.query_vec(extra)).unwrap()
            );
        }
        assert_eq!(idx.graph.levels, reloaded.graph.levels, "level streams diverged");
        for qi in 0..ds.n_queries() {
            assert_eq!(
                idx.search_with_dists(ds.query_vec(qi), 10, 64),
                reloaded.search_with_dists(ds.query_vec(qi), 10, 64),
                "post-reload insert stream diverged at query {qi}"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_metadata_roundtrip_and_unknown_section_ignored() {
        use crate::anns::metadata::MetadataStore;
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 200, 5, 92);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let mut meta = MetadataStore::new();
        for id in 0..200u32 {
            let tenant = format!("t{}", id % 3);
            let tags: &[&str] = if id % 2 == 0 { &["even"] } else { &[] };
            meta.push(Some(&tenant), tags);
        }
        let path = tmp("meta_v3.idx");
        save_glass_with_metadata(&idx, &meta, &path).unwrap();
        for load in [load_glass_with_metadata, load_glass_mmap_with_metadata] {
            let (loaded, loaded_meta) = load(&path).unwrap();
            let loaded_meta = loaded_meta.expect("metadata section must round-trip");
            assert_eq!(loaded_meta.names(), meta.names());
            assert_eq!(loaded_meta.tenants(), meta.tenants());
            assert_eq!(loaded_meta.tags(), meta.tags());
            assert_eq!(
                loaded.search_with_dists(ds.query_vec(0), 10, 64),
                idx.search_with_dists(ds.query_vec(0), 10, 64)
            );
        }
        // Index-only snapshots report no metadata.
        save_glass(&idx, &path).unwrap();
        let (_, none_meta) = load_glass_with_metadata(&path).unwrap();
        assert!(none_meta.is_none());
        // Forward compatibility: a section with an unknown id is ignored,
        // not an error. Rewrite the metadata entry's id (slot 9 of the
        // directory) to an id no current reader knows.
        save_glass_with_metadata(&idx, &meta, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, patched_at(&full, entry_at(9), &0xBEEFu32.to_le_bytes())).unwrap();
        let (ok, no_meta) = load_glass_with_metadata(&path).unwrap();
        assert!(no_meta.is_none(), "unknown section must be skipped");
        assert_eq!(ok.len(), idx.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_rejects_truncated_file() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 93);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let path = tmp("truncated_v3.idx");
        save_glass(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        for cut in [6usize, 14, 100, full.len() / 2, full.len() - 3] {
            std::fs::write(&path, &full[..cut]).unwrap();
            assert!(load_glass(&path).is_err(), "truncated at {cut}/{} loaded", full.len());
            assert!(load_glass_mmap(&path).is_err(), "truncated at {cut} mmap-loaded");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_rejects_hostile_section_directory() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 94);
        let idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        let path = tmp("hostile_v3.idx");
        save_glass(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        assert!(load_glass(&path).is_ok(), "pristine file must load");
        let expect_err = |bytes: Vec<u8>, what: &str, needle: &str| {
            std::fs::write(&path, bytes).unwrap();
            for (label, res) in [
                ("heap", load_glass(&path)),
                ("mmap", load_glass_mmap(&path)),
            ] {
                let err = res.err().unwrap_or_else(|| panic!("{what} accepted ({label})"));
                let msg = format!("{err:#}");
                assert!(msg.contains(needle), "{what} ({label}): unexpected error: {msg}");
            }
        };

        // (a) Duplicate section ids: entry 1 (vectors) renamed to id 1
        // (the index header's id).
        expect_err(
            patched_at(&full, entry_at(1), &sections::SEC_INDEX.to_le_bytes()),
            "duplicate id",
            "duplicate section id",
        );
        // (b) Misaligned payload offset.
        expect_err(
            patched_at(&full, entry_at(1) + 8, &4u64.to_le_bytes()),
            "misaligned offset",
            "not 64-byte aligned",
        );
        // (c) Offset beyond EOF (64-aligned so the alignment check passes).
        let beyond = ((full.len() as u64 / 64) + 2) * 64;
        expect_err(
            patched_at(&full, entry_at(1) + 8, &beyond.to_le_bytes()),
            "out-of-bounds offset",
            "exceeds file size",
        );
        // (d) A length that overflows offset + len past u64.
        expect_err(
            patched_at(&full, entry_at(1) + 16, &u64::MAX.to_le_bytes()),
            "overflowing length",
            "length overflows",
        );
        // (e) Overlapping sections: point the codes entry (slot 2) at the
        // layer0 entry's (slot 3) offset.
        let layer0_off = u64::from_le_bytes(
            full[entry_at(3) + 8..entry_at(3) + 16].try_into().unwrap(),
        );
        expect_err(
            patched_at(&full, entry_at(2) + 8, &layer0_off.to_le_bytes()),
            "overlapping sections",
            "overlap",
        );
        // (f) Checksum mismatch: flip one payload byte (the file's last
        // byte belongs to the mutation section's payload).
        let mut flipped = full.clone();
        *flipped.last_mut().unwrap() ^= 0x01;
        expect_err(flipped, "corrupted payload", "checksum mismatch");
        // (g) A hostile section count whose directory dwarfs the file.
        expect_err(
            patched_at(&full, 8, &u32::MAX.to_le_bytes()),
            "huge section count",
            "exceeds file size",
        );
        // (h) A declared tombstone count inconsistent with the (empty)
        // bitset: flip the SEC_INDEX payload's declared_dead field — and
        // restore the section checksum so only the semantic check can
        // catch it.
        let index_off = u64::from_le_bytes(
            full[entry_at(0) + 8..entry_at(0) + 16].try_into().unwrap(),
        ) as usize;
        let mut deep = patched_at(&full, index_off + 32, &2u64.to_le_bytes());
        let sum = sections::checksum(&deep[index_off..index_off + 40]);
        deep = patched_at(&deep, entry_at(0) + 24, &sum.to_le_bytes());
        expect_err(deep, "popcount mismatch", "popcount");
        std::fs::remove_file(&path).ok();
    }

    /// Read the i-th directory entry's payload (offset, len) from raw
    /// snapshot bytes.
    fn entry_payload(full: &[u8], i: usize) -> (usize, usize) {
        let off =
            u64::from_le_bytes(full[entry_at(i) + 8..entry_at(i) + 16].try_into().unwrap());
        let len =
            u64::from_le_bytes(full[entry_at(i) + 16..entry_at(i) + 24].try_into().unwrap());
        (off as usize, len as usize)
    }

    #[test]
    fn v3_pq_roundtrip_heap_and_mmap_bitwise_identical() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 700, 20, 95);
        ds.compute_ground_truth(10);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        idx.enable_pq(16, 7);
        let path = tmp("pq_roundtrip_v3.idx");
        save_glass(&idx, &path).unwrap();
        let heap = load_glass(&path).unwrap();
        let mapped = load_glass_mmap(&path).unwrap();
        for loaded in [&heap, &mapped] {
            let pq = loaded.pq_store().expect("pq sections must round-trip");
            assert_eq!(pq.m(), 16);
            assert_eq!(pq.len(), idx.len());
        }
        // Both loads serve the packed codes as region views, not copies.
        assert!(heap.pq_store().unwrap().is_mapped());
        assert!(mapped.pq_store().unwrap().is_mapped());
        for qi in 0..ds.n_queries() {
            let want = idx.search_with_dists(ds.query_vec(qi), 10, 64);
            assert_eq!(heap.search_with_dists(ds.query_vec(qi), 10, 64), want, "heap q{qi}");
            assert_eq!(mapped.search_with_dists(ds.query_vec(qi), 10, 64), want, "mmap q{qi}");
        }
        // PQ-less snapshots keep reporting no store.
        let plain = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::crinn_full(),
            7,
        );
        save_glass(&plain, &path).unwrap();
        assert!(load_glass(&path).unwrap().pq_store().is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v3_pq_rejects_hostile_pq_sections() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 300, 5, 96);
        let mut idx = GlassIndex::build(
            crate::anns::VectorSet::from_dataset(&ds),
            VariantConfig::glass_baseline(),
            7,
        );
        idx.enable_pq(8, 7);
        let path = tmp("pq_hostile_v3.idx");
        save_glass(&idx, &path).unwrap();
        let full = std::fs::read(&path).unwrap();
        assert!(load_glass(&path).unwrap().pq_store().is_some(), "pristine file must load");
        // No metadata section, so the insertion order puts the PQ
        // sections at slots 10 (meta), 11 (codebooks), 12 (codes).
        let expect_err = |bytes: Vec<u8>, what: &str, needle: &str| {
            std::fs::write(&path, bytes).unwrap();
            for (label, res) in [
                ("heap", load_glass(&path)),
                ("mmap", load_glass_mmap(&path)),
            ] {
                let err = res.err().unwrap_or_else(|| panic!("{what} accepted ({label})"));
                let msg = format!("{err:#}");
                assert!(msg.contains(needle), "{what} ({label}): unexpected error: {msg}");
            }
        };

        // (a) Subquantizer count zeroed — deep-patch the meta payload and
        // restore its checksum so only the semantic range check can fire.
        let (moff, mlen) = entry_payload(&full, 10);
        assert_eq!(mlen, 8, "pq meta payload is m + reserved");
        let mut deep = patched_at(&full, moff, &0u32.to_le_bytes());
        let sum = sections::checksum(&deep[moff..moff + mlen]);
        deep = patched_at(&deep, entry_at(10) + 24, &sum.to_le_bytes());
        expect_err(deep, "zero pq m", "pq subquantizer count");
        // (b) Subquantizer count above the dimension, same re-sign trick.
        let mut deep = patched_at(&full, moff, &65u32.to_le_bytes());
        let sum = sections::checksum(&deep[moff..moff + mlen]);
        deep = patched_at(&deep, entry_at(10) + 24, &sum.to_le_bytes());
        expect_err(deep, "oversized pq m", "pq subquantizer count");
        // (c) Truncated codebook section: shrink the directory length and
        // re-sign over the shorter payload so the size check, not the
        // checksum, must reject it.
        let (cboff, cblen) = entry_payload(&full, 11);
        let mut deep = patched_at(&full, entry_at(11) + 16, &((cblen - 4) as u64).to_le_bytes());
        let sum = sections::checksum(&deep[cboff..cboff + cblen - 4]);
        deep = patched_at(&deep, entry_at(11) + 24, &sum.to_le_bytes());
        expect_err(deep, "truncated pq codebooks", "pq codebooks");
        // (d) Truncated packed-code section, same trick.
        let (pcoff, pclen) = entry_payload(&full, 12);
        let mut deep = patched_at(&full, entry_at(12) + 16, &((pclen - 1) as u64).to_le_bytes());
        let sum = sections::checksum(&deep[pcoff..pcoff + pclen - 1]);
        deep = patched_at(&deep, entry_at(12) + 24, &sum.to_le_bytes());
        expect_err(deep, "truncated pq codes", "pq codes");
        // (e) A non-finite codebook entry must be rejected by the store's
        // own validation (checksum re-signed so it gets that far).
        let mut deep = patched_at(&full, cboff, &f32::NAN.to_le_bytes());
        let sum = sections::checksum(&deep[cboff..cboff + cblen]);
        deep = patched_at(&deep, entry_at(11) + 24, &sum.to_le_bytes());
        expect_err(deep, "non-finite pq codebook", "pq state");
        std::fs::remove_file(&path).ok();
    }
}
