//! Index persistence: save/load built GLASS/HNSW indexes.
//!
//! A deployment builds once and serves many times — ann-benchmarks and
//! every production store persist their graphs. The module tree:
//!
//! * [`writer`] — little-endian stream-writer primitives;
//! * [`reader`] — hostile-input hardened stream-reader primitives (every
//!   `u64` length field is overflow-checked against the file size before
//!   any allocation);
//! * [`compat`] — the v1/v2 sequential-stream format, kept as a
//!   compatibility shim so snapshots written before the paged container
//!   landed keep loading.
//!
//! The container carries the vector set, the layered graph, the
//! quantized codes, the variant configuration (encoded through the same
//! action space the RL uses, which keeps the format stable as knobs
//! evolve), an optional id → tenant/tags metadata section (for filtered
//! serving), and the mutation state: the tombstone bitset and the
//! free-slot list, so a snapshot taken under live traffic restores with
//! exactly the same live set.

pub(crate) mod compat;
pub(crate) mod reader;
pub(crate) mod writer;

use crate::anns::metadata::MetadataStore;
use crate::util::error::Result;
use std::path::Path;

/// File magic shared by every snapshot version.
pub(crate) const MAGIC: &[u8; 4] = b"CRNN";

/// Save a built GLASS index (graph + codes + config) to `path`.
pub fn save_glass(idx: &crate::anns::glass::GlassIndex, path: &Path) -> Result<()> {
    compat::save_v2(idx, path)
}

/// [`save_glass`] plus the id → tenant/tags store, so a filtered-serving
/// deployment snapshots index and metadata as one artifact.
pub fn save_glass_with_metadata(
    idx: &crate::anns::glass::GlassIndex,
    metadata: &MetadataStore,
    path: &Path,
) -> Result<()> {
    compat::save_v2_with_metadata(idx, metadata, path)
}

/// Load a GLASS index saved with [`save_glass`]. Codes and degree
/// metadata are rebuilt from the payload (cheaper than storing them and
/// immune to quantizer-version drift); the codes re-derive from the
/// **persisted** frozen scale, never a re-fit, so an index that absorbed
/// online inserts restores bit-identically.
pub fn load_glass(path: &Path) -> Result<crate::anns::glass::GlassIndex> {
    Ok(load_glass_with_metadata(path)?.0)
}

/// [`load_glass`] plus the persisted metadata store (`None` for index-only
/// snapshots and v1 files). The metadata columns get the same
/// hostile-input treatment as the mutation state: row count capped by the
/// point count, name ids range-checked, tag offsets monotone and
/// consistent with the flat tag array — reject with `Err`, never
/// trust-and-crash later.
pub fn load_glass_with_metadata(
    path: &Path,
) -> Result<(crate::anns::glass::GlassIndex, Option<MetadataStore>)> {
    compat::load(path)
}
