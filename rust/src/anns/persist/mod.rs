//! Index persistence: save/load built GLASS/HNSW indexes.
//!
//! A deployment builds once and serves many times — ann-benchmarks and
//! every production store persist their graphs. The module tree:
//!
//! * [`sections`] — the v3 paged section container: a checksummed
//!   section directory with 64-byte-aligned payloads, so every logical
//!   piece of the index (vectors, SQ8 codes, graph adjacency, metadata,
//!   mutation state) is independently addressable;
//! * [`writer`] — little-endian stream-writer primitives plus the v3
//!   save;
//! * [`reader`] — hostile-input hardened stream-reader primitives (every
//!   `u64` length field is overflow-checked against the file size before
//!   any allocation) plus the v3 load, heap- or mmap-served;
//! * [`compat`] — the v1/v2 sequential-stream format, kept as a
//!   compatibility shim so snapshots written before the paged container
//!   landed keep loading.
//!
//! The container carries the vector set, the layered graph, the
//! quantized codes, the variant configuration (encoded through the same
//! action space the RL uses, which keeps the format stable as knobs
//! evolve), an optional id → tenant/tags metadata section (for filtered
//! serving), and the mutation state: the tombstone bitset and the
//! free-slot list, so a snapshot taken under live traffic restores with
//! exactly the same live set.
//!
//! Saves write v3. Loads sniff the version and dispatch; the mmap entry
//! points ([`load_glass_mmap`]) serve the big read-only sections (codes,
//! layer-0 adjacency) zero-copy out of the page cache and are bitwise
//! result-identical to the heap load.

pub(crate) mod compat;
pub(crate) mod reader;
pub(crate) mod sections;
pub(crate) mod writer;

// The section checksum doubles as the tuned-config artifact's signature
// (`variants::artifact`) so every on-disk format shares one FNV-1a-64.
pub(crate) use sections::checksum;

use crate::anns::metadata::MetadataStore;
use crate::bail;
use crate::util::error::{Context, Error, Result};
use std::io::Read;
use std::path::Path;

/// File magic shared by every snapshot version.
pub(crate) const MAGIC: &[u8; 4] = b"CRNN";

/// Save a built GLASS index (graph + codes + config) to `path` in the
/// v3 paged container format.
pub fn save_glass(idx: &crate::anns::glass::GlassIndex, path: &Path) -> Result<()> {
    writer::save_v3(idx, None, path)
}

/// [`save_glass`] plus the id → tenant/tags store, so a filtered-serving
/// deployment snapshots index and metadata as one artifact.
pub fn save_glass_with_metadata(
    idx: &crate::anns::glass::GlassIndex,
    metadata: &MetadataStore,
    path: &Path,
) -> Result<()> {
    writer::save_v3(idx, Some(metadata), path)
}

/// Load a GLASS index saved with [`save_glass`] (any version: v3 paged
/// containers load their persisted code rows directly; v1/v2 stream
/// files re-derive codes from the **persisted** frozen scale, never a
/// re-fit, so an index that absorbed online inserts restores
/// bit-identically either way).
pub fn load_glass(path: &Path) -> Result<crate::anns::glass::GlassIndex> {
    Ok(load_glass_with_metadata(path)?.0)
}

/// [`load_glass`] plus the persisted metadata store (`None` for
/// index-only snapshots and v1 files). The metadata columns get the same
/// hostile-input treatment as the mutation state: row count capped by the
/// point count, name ids range-checked, tag offsets monotone and
/// consistent with the flat tag array — reject with `Err`, never
/// trust-and-crash later.
pub fn load_glass_with_metadata(
    path: &Path,
) -> Result<(crate::anns::glass::GlassIndex, Option<MetadataStore>)> {
    match sniff_version(path)? {
        1 | 2 => compat::load(path),
        sections::VERSION_V3 => reader::load_v3(path, false),
        v => bail!("unsupported index version {v}"),
    }
}

/// [`load_glass`], serving the large read-only sections (SQ8 codes,
/// layer-0 adjacency) zero-copy out of a private read-only `mmap(2)` of
/// the snapshot — cold starts skip copying them onto the heap and the
/// pages stay evictable. Search results are bitwise identical to the
/// heap load; the first online insert promotes the touched section to
/// heap (copy-on-write). v1/v2 stream files predate the mappable layout
/// and degrade to the classic heap load.
pub fn load_glass_mmap(path: &Path) -> Result<crate::anns::glass::GlassIndex> {
    Ok(load_glass_mmap_with_metadata(path)?.0)
}

/// [`load_glass_mmap`] plus the persisted metadata store.
pub fn load_glass_mmap_with_metadata(
    path: &Path,
) -> Result<(crate::anns::glass::GlassIndex, Option<MetadataStore>)> {
    match sniff_version(path)? {
        1 | 2 => compat::load(path),
        sections::VERSION_V3 => reader::load_v3(path, true),
        v => bail!("unsupported index version {v}"),
    }
}

/// Read magic + version without touching the rest of the file.
fn sniff_version(path: &Path) -> Result<u32> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head)
        .map_err(|_| Error::msg("not a CRINN index file".to_string()))?;
    crate::ensure!(&head[0..4] == MAGIC, "not a CRINN index file");
    Ok(u32::from_le_bytes([head[4], head[5], head[6], head[7]]))
}
