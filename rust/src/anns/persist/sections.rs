//! The v3 paged section container: a section *directory* instead of one
//! sequential stream.
//!
//! Layout (all little-endian):
//!
//! ```text
//! [0..4)   magic "CRNN"
//! [4..8)   version (3)
//! [8..12)  section count
//! [12..16) reserved (0)
//! 16 + 32*i, one per section:
//!   [+0..+4)   section id
//!   [+4..+8)   reserved (0)
//!   [+8..+16)  payload offset (64-byte aligned, ascending)
//!   [+16..+24) payload length in bytes
//!   [+24..+32) payload checksum (word-at-a-time FNV-1a-64)
//! ...zero padding to each aligned offset, then the payload bytes...
//! ```
//!
//! Every section is independently addressable: a reader seeks (or maps)
//! exactly the payloads it wants, and the 64-byte alignment means any
//! flat-array payload can be viewed in place as `&[T]` for every Pod
//! element type. Unknown section ids are ignored (forward compatibility:
//! an old reader skips sections a newer writer added); duplicate ids,
//! overlapping payloads, misaligned or out-of-bounds offsets, and
//! checksum mismatches are all hard errors — a snapshot either validates
//! completely or refuses to load.

use super::MAGIC;
use crate::anns::store::region::MappedRegion;
use crate::util::error::{Context, Error, Result};
use std::io::Write;
use std::path::Path;

/// v3 introduced the paged section container.
pub(crate) const VERSION_V3: u32 = 3;
/// Payload alignment: one cache line, and a multiple of every Pod
/// element size, so in-place `&[T]` views are always aligned.
pub(crate) const ALIGN: usize = 64;
pub(crate) const HEADER_BYTES: usize = 16;
pub(crate) const DIR_ENTRY_BYTES: usize = 32;

/// Fixed-size index header: dim, metric, point count, graph degree,
/// entry, max level, frozen quantizer scale, declared tombstone count.
pub(crate) const SEC_INDEX: u32 = 1;
/// Raw `[n * dim]` f32 vector rows.
pub(crate) const SEC_VECTORS: u32 = 2;
/// Raw `[n * dim]` i8 SQ8 code rows (served zero-copy).
pub(crate) const SEC_CODES: u32 = 3;
/// Raw `[n * m0]` u32 layer-0 adjacency (served zero-copy).
pub(crate) const SEC_LAYER0: u32 = 4;
/// Raw `[n]` u8 per-node levels.
pub(crate) const SEC_LEVELS: u32 = 5;
/// Raw `[n]` u16 precomputed layer-0 degrees.
pub(crate) const SEC_DEGREE0: u32 = 6;
/// Raw u32 diverse entry-point list.
pub(crate) const SEC_ENTRY_POINTS: u32 = 7;
/// Structured sparse upper layers (count-prefixed, sorted by node id).
pub(crate) const SEC_UPPER: u32 = 8;
/// Variant configuration via the stable action encoding.
pub(crate) const SEC_CONFIG: u32 = 9;
/// Optional id → tenant/tags metadata columns.
pub(crate) const SEC_METADATA: u32 = 10;
/// Mutation state: tombstone bitset words, free list, insert RNG state.
pub(crate) const SEC_MUTATION: u32 = 11;
/// Optional PQ header: subquantizer count `m` (u32) + reserved u32.
/// Present iff the index has a layer-0 PQ store; then the two sections
/// below are required.
pub(crate) const SEC_PQ_META: u32 = 12;
/// Raw `[m * 16 * ds]` f32 PQ codebooks (served zero-copy).
pub(crate) const SEC_PQ_CODEBOOKS: u32 = 13;
/// Raw `[n * (m+1)/2]` u8 packed 4-bit PQ code rows (served zero-copy).
pub(crate) const SEC_PQ_CODES: u32 = 14;

/// Word-at-a-time FNV-1a-64 over the payload bytes: 8 bytes per round
/// (LE-read into the accumulator), remainder bytes one at a time — for
/// inputs shorter than 8 bytes this is exactly byte-wise FNV-1a-64.
/// Not cryptographic; it catches torn writes, truncation and bit rot,
/// which is the threat model for a local snapshot file.
pub(crate) fn checksum(bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        h ^= u64::from_le_bytes(c.try_into().unwrap());
        h = h.wrapping_mul(PRIME);
    }
    for &b in chunks.remainder() {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// Accumulates `(id, payload)` sections and writes the container:
/// header, directory (offsets assigned in insertion order, each aligned
/// up), zero padding, payloads.
pub(crate) struct SectionBuilder {
    sections: Vec<(u32, Vec<u8>)>,
}

impl SectionBuilder {
    pub(crate) fn new() -> SectionBuilder {
        SectionBuilder { sections: Vec::new() }
    }

    pub(crate) fn add(&mut self, id: u32, payload: Vec<u8>) {
        self.sections.push((id, payload));
    }

    pub(crate) fn write_to(&self, path: &Path) -> Result<()> {
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut bw = std::io::BufWriter::new(f);
        let count = self.sections.len();
        bw.write_all(MAGIC)?;
        bw.write_all(&VERSION_V3.to_le_bytes())?;
        bw.write_all(&(count as u32).to_le_bytes())?;
        bw.write_all(&0u32.to_le_bytes())?;
        // Directory: assign ascending aligned offsets in insertion order.
        let mut offsets = Vec::with_capacity(count);
        let mut offset = align_up(HEADER_BYTES + count * DIR_ENTRY_BYTES);
        for (id, payload) in &self.sections {
            bw.write_all(&id.to_le_bytes())?;
            bw.write_all(&0u32.to_le_bytes())?;
            bw.write_all(&(offset as u64).to_le_bytes())?;
            bw.write_all(&(payload.len() as u64).to_le_bytes())?;
            bw.write_all(&checksum(payload).to_le_bytes())?;
            offsets.push(offset);
            offset = align_up(offset + payload.len());
        }
        // Payloads, zero-padded out to each directory offset.
        let mut at = HEADER_BYTES + count * DIR_ENTRY_BYTES;
        for ((_, payload), &off) in self.sections.iter().zip(&offsets) {
            let pad = [0u8; ALIGN];
            bw.write_all(&pad[..off - at])?;
            bw.write_all(payload)?;
            at = off + payload.len();
        }
        bw.flush()?;
        Ok(())
    }
}

/// The parsed, fully validated section directory of a v3 container.
pub(crate) struct Directory {
    /// `(id, offset, len, checksum)` in directory order; ids unique.
    entries: Vec<(u32, usize, usize, u64)>,
}

impl Directory {
    /// Parse and validate the directory (not the payloads): magic,
    /// version, directory bounds, per-entry alignment and bounds,
    /// duplicate ids, pairwise overlap. Payload integrity is the
    /// separate [`Directory::verify_checksums`] pass.
    pub(crate) fn parse(region: &MappedRegion) -> Result<Directory> {
        let bytes = region.as_slice();
        crate::ensure!(
            bytes.len() >= HEADER_BYTES,
            "corrupt index: {} bytes is too small for a section container",
            bytes.len()
        );
        crate::ensure!(&bytes[0..4] == MAGIC, "not a CRINN index file");
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        crate::ensure!(version == VERSION_V3, "unsupported index version {version}");
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let dir_end = count
            .checked_mul(DIR_ENTRY_BYTES)
            .and_then(|x| x.checked_add(HEADER_BYTES))
            .ok_or_else(|| Error::msg("corrupt index: section count overflows".to_string()))?;
        crate::ensure!(
            dir_end <= bytes.len(),
            "corrupt index: directory of {count} sections exceeds file size {}",
            bytes.len()
        );
        let mut entries = Vec::with_capacity(count);
        let mut seen = std::collections::HashSet::new();
        for i in 0..count {
            let e = &bytes[HEADER_BYTES + i * DIR_ENTRY_BYTES..HEADER_BYTES + (i + 1) * DIR_ENTRY_BYTES];
            let id = u32::from_le_bytes(e[0..4].try_into().unwrap());
            let offset = u64::from_le_bytes(e[8..16].try_into().unwrap());
            let len = u64::from_le_bytes(e[16..24].try_into().unwrap());
            let sum = u64::from_le_bytes(e[24..32].try_into().unwrap());
            crate::ensure!(seen.insert(id), "corrupt index: duplicate section id {id}");
            crate::ensure!(
                offset % ALIGN as u64 == 0,
                "corrupt index: section {id} at offset {offset} is not {ALIGN}-byte aligned"
            );
            crate::ensure!(
                offset >= dir_end as u64,
                "corrupt index: section {id} at offset {offset} overlaps the directory"
            );
            let end = offset.checked_add(len).ok_or_else(|| {
                Error::msg(format!("corrupt index: section {id} length overflows"))
            })?;
            crate::ensure!(
                end <= bytes.len() as u64,
                "corrupt index: section {id} [{offset}, {end}) exceeds file size {}",
                bytes.len()
            );
            entries.push((id, offset as usize, len as usize, sum));
        }
        let mut by_offset = entries.clone();
        by_offset.sort_by_key(|&(_, offset, _, _)| offset);
        for w in by_offset.windows(2) {
            let (a, a_off, a_len, _) = w[0];
            let (b, b_off, _, _) = w[1];
            crate::ensure!(
                a_off + a_len <= b_off,
                "corrupt index: sections {a} and {b} overlap"
            );
        }
        Ok(Directory { entries })
    }

    /// Verify every payload checksum against its directory entry. Both
    /// load paths run this — an mmap-served snapshot is checked as
    /// eagerly as a heap-loaded one, so serving never reads bytes whose
    /// integrity was not established at load.
    pub(crate) fn verify_checksums(&self, region: &MappedRegion) -> Result<()> {
        let bytes = region.as_slice();
        for &(id, offset, len, sum) in &self.entries {
            let got = checksum(&bytes[offset..offset + len]);
            crate::ensure!(
                got == sum,
                "corrupt index: section {id} checksum mismatch \
                 (stored {sum:#018x}, computed {got:#018x})"
            );
        }
        Ok(())
    }

    /// Byte range of section `id`, if present. Unknown ids in the file
    /// are simply never asked for — forward compatibility.
    pub(crate) fn get(&self, id: u32) -> Option<(usize, usize)> {
        self.entries
            .iter()
            .find(|&&(eid, _, _, _)| eid == id)
            .map(|&(_, offset, len, _)| (offset, len))
    }

    /// Byte range of a section every v3 snapshot must carry.
    pub(crate) fn require(&self, id: u32) -> Result<(usize, usize)> {
        self.get(id)
            .ok_or_else(|| Error::msg(format!("corrupt index: missing section {id}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("crinn_{}_{}", std::process::id(), name))
    }

    #[test]
    fn checksum_matches_fnv1a_vectors_and_detects_flips() {
        // Short inputs are exactly byte-wise FNV-1a-64 (published vectors).
        assert_eq!(checksum(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(checksum(b"a"), 0xaf63_dc4c_8601_ec8c);
        // Word-at-a-time sensitivity: any single-byte flip changes the sum.
        let base: Vec<u8> = (0..=255u8).cycle().take(1024 + 5).collect();
        let want = checksum(&base);
        for at in [0usize, 7, 8, 512, 1024, 1028] {
            let mut b = base.clone();
            b[at] ^= 0x40;
            assert_ne!(checksum(&b), want, "flip at {at} undetected");
        }
    }

    #[test]
    fn builder_roundtrips_through_directory() {
        let path = tmp("container_roundtrip.bin");
        let mut b = SectionBuilder::new();
        b.add(7, vec![1, 2, 3]);
        b.add(900, Vec::new()); // empty + unknown ids are fine
        b.add(2, (0..200u8).collect());
        b.write_to(&path).unwrap();
        let region = MappedRegion::read_file(&path).unwrap();
        let dir = Directory::parse(&region).unwrap();
        dir.verify_checksums(&region).unwrap();
        let (off, len) = dir.require(7).unwrap();
        assert_eq!(off % ALIGN, 0);
        assert_eq!(&region.as_slice()[off..off + len], &[1, 2, 3]);
        let (_, len) = dir.get(900).unwrap();
        assert_eq!(len, 0);
        let (off2, len2) = dir.require(2).unwrap();
        assert_eq!(region.as_slice()[off2..off2 + len2], (0..200u8).collect::<Vec<_>>());
        assert!(dir.get(4).is_none());
        assert!(format!("{:#}", dir.require(4).unwrap_err()).contains("missing section"));
        std::fs::remove_file(&path).ok();
    }
}
