//! The CRINN action space: every optimization §6 reports the RL discovering,
//! as parametric knobs over the HNSW/GLASS modules.
//!
//! The paper's LLM rewrites module *source*; the observable effect of every
//! rewrite it reports is a configuration of these mechanisms (DESIGN.md §2
//! documents the substitution). Knob defaults = the GLASS baseline; the
//! `crinn_*` constructors give the paper's discovered settings; the GRPO
//! policy explores the full space via [`decode_action`]/[`encode_action`].
//!
//! [`VariantConfig`] below is the GLASS-centric compat view. The unified
//! tuning layer generalizes it: [`space`] covers every buildable family
//! plus serving knobs behind one [`TuningSpace`]/[`TunedConfig`] pair,
//! [`build`] constructs any family from a [`TunedConfig`], and
//! [`artifact`] round-trips the tuned configuration as a versioned,
//! checksummed file (`crinn tune` → `crinn serve --tuned`).

pub mod artifact;
pub mod build;
pub mod space;

pub use artifact::TunedArtifact;
pub use build::build_index;
pub use space::{
    validate_config, IndexFamily, IvfKnobs, KnobBound, KnobKind, ServingKnobs, TunedConfig,
    TuningSpace,
};

/// Graph-construction module knobs (§6.1).
#[derive(Clone, Debug, PartialEq)]
pub struct ConstructionKnobs {
    /// Max connections per node on upper layers (layer 0 gets `2*m`).
    pub m: usize,
    /// Baseline construction beam width.
    pub ef_construction: usize,
    /// §6.1 "Adaptive Search with Dynamic EF Scaling".
    pub adaptive_ef: bool,
    /// ef multiplier slope (paper's snippet uses 14.5).
    pub ef_scale: f64,
    /// recall target driving the adaptive scaling.
    pub target_recall: f64,
    /// critical threshold above which scaling kicks in.
    pub recall_threshold: f64,
    /// §6.1 "Multi-Entry Point Search Architecture" (1..=9).
    pub num_entry_points: usize,
    /// Minimum pairwise distance quantile for entry diversity.
    pub entry_diversity: f64,
    /// §6.1 "Zero-Overhead Multi-Level Prefetching": neighbors prefetched
    /// ahead during construction-time searches (paper: 5 fixed → 24–48).
    pub prefetch_depth: usize,
    /// Cache level hint (1=L3 … 3=L1; paper's snippets use 1 and 3).
    pub prefetch_locality: i32,
}

impl Default for ConstructionKnobs {
    /// GLASS baseline: fixed ef, single entry point, fixed window of 5.
    fn default() -> Self {
        ConstructionKnobs {
            m: 16,
            ef_construction: 200,
            adaptive_ef: false,
            ef_scale: 0.0,
            target_recall: 0.9,
            recall_threshold: 0.88,
            num_entry_points: 1,
            entry_diversity: 0.5,
            prefetch_depth: 5,
            prefetch_locality: 1,
        }
    }
}

impl ConstructionKnobs {
    /// The configuration §6.1 reports CRINN discovering.
    pub fn crinn_discovered() -> Self {
        ConstructionKnobs {
            m: 24,
            ef_construction: 180,
            adaptive_ef: true,
            ef_scale: 14.5,
            target_recall: 0.95,
            recall_threshold: 0.9,
            num_entry_points: 5,
            entry_diversity: 0.6,
            prefetch_depth: 32,
            prefetch_locality: 3,
        }
    }

    /// Effective construction ef under the adaptive rule (§6.1 snippet:
    /// `ef * (1 + recall_excess * scale)` above the critical threshold).
    pub fn effective_ef(&self) -> usize {
        if self.adaptive_ef && self.target_recall > self.recall_threshold {
            let excess = self.target_recall - self.recall_threshold;
            (self.ef_construction as f64 * (1.0 + excess * self.ef_scale)) as usize
        } else {
            self.ef_construction
        }
    }
}

/// Search module knobs (§6.2).
#[derive(Clone, Debug, PartialEq)]
pub struct SearchKnobs {
    /// §6.2 "Multi-Tier Entry Point Selection": 1..=3 tiers.
    pub entry_tiers: usize,
    /// ef budget above which tier 2 entries join.
    pub tier_budget_1: usize,
    /// ef budget above which tier 3 entries join.
    pub tier_budget_2: usize,
    /// §6.2 "Batch Processing with Adaptive Prefetching".
    pub edge_batch: bool,
    /// Neighbors gathered per batch before distance evaluation.
    pub batch_size: usize,
    /// §6.2 "Intelligent Early Termination with Convergence Detection".
    pub early_termination: bool,
    /// Consecutive non-improving expansions tolerated (scaled by ef).
    pub patience: usize,
    /// Prefetch lookahead while scanning adjacency.
    pub prefetch_depth: usize,
    pub prefetch_locality: i32,
}

impl Default for SearchKnobs {
    /// GLASS baseline: single entry, sequential edges, exhaust the pool.
    fn default() -> Self {
        SearchKnobs {
            entry_tiers: 1,
            tier_budget_1: 64,
            tier_budget_2: 192,
            edge_batch: false,
            batch_size: 16,
            early_termination: false,
            patience: 3,
            prefetch_depth: 4,
            prefetch_locality: 1,
        }
    }
}

impl SearchKnobs {
    /// The configuration §6.2 reports CRINN discovering.
    pub fn crinn_discovered() -> Self {
        SearchKnobs {
            entry_tiers: 3,
            tier_budget_1: 48,
            tier_budget_2: 160,
            edge_batch: true,
            batch_size: 32,
            early_termination: true,
            patience: 4,
            prefetch_depth: 16,
            prefetch_locality: 3,
        }
    }
}

/// Refinement module knobs (§6.3) — the quantized-primary + exact-rerank
/// stage of GLASS.
#[derive(Clone, Debug, PartialEq)]
pub struct RefineKnobs {
    /// Quantized primary search + full-precision rerank enabled.
    pub quantized_primary: bool,
    /// §6.3 "Adaptive Memory Prefetching" during rerank gathers.
    pub adaptive_prefetch: bool,
    /// Lookahead edges prefetched (paper's `edges[i + lookahead]`).
    pub lookahead: usize,
    /// §6.3 "Pre-computed Edge Metadata": stored degree counts instead of
    /// sentinel scans.
    pub precomputed_metadata: bool,
    /// Rerank pool = `max(k, ef * rerank_frac)` candidates.
    pub rerank_frac: f64,
}

impl Default for RefineKnobs {
    fn default() -> Self {
        RefineKnobs {
            quantized_primary: true,
            adaptive_prefetch: false,
            lookahead: 1,
            precomputed_metadata: false,
            rerank_frac: 1.0,
        }
    }
}

impl RefineKnobs {
    /// The configuration §6.3 reports CRINN discovering.
    pub fn crinn_discovered() -> Self {
        RefineKnobs {
            quantized_primary: true,
            adaptive_prefetch: true,
            lookahead: 4,
            precomputed_metadata: true,
            rerank_frac: 0.55,
        }
    }

    pub fn rerank_count(&self, k: usize, ef: usize) -> usize {
        ((ef as f64 * self.rerank_frac) as usize).max(k)
    }
}

/// Full variant: one point in CRINN's optimization space.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VariantConfig {
    pub construction: ConstructionKnobs,
    pub search: SearchKnobs,
    pub refine: RefineKnobs,
}

impl VariantConfig {
    /// GLASS baseline (RL starting point, §3.5).
    pub fn glass_baseline() -> Self {
        VariantConfig::default()
    }

    /// All three modules at the paper's discovered settings.
    pub fn crinn_full() -> Self {
        VariantConfig {
            construction: ConstructionKnobs::crinn_discovered(),
            search: SearchKnobs::crinn_discovered(),
            refine: RefineKnobs::crinn_discovered(),
        }
    }

    /// Progressive stages for Table 4: baseline, +construction, +search,
    /// +refinement (cumulative, in the paper's optimization order §3.5).
    pub fn progressive_stages() -> Vec<(&'static str, VariantConfig)> {
        let base = VariantConfig::glass_baseline();
        let mut s1 = base.clone();
        s1.construction = ConstructionKnobs::crinn_discovered();
        let mut s2 = s1.clone();
        s2.search = SearchKnobs::crinn_discovered();
        let mut s3 = s2.clone();
        s3.refine = RefineKnobs::crinn_discovered();
        vec![
            ("glass-baseline", base),
            ("+graph-construction", s1),
            ("+search", s2),
            ("+refinement", s3),
        ]
    }
}

/// Which module a GRPO round is optimizing (§3.5 sequential order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Module {
    Construction,
    Search,
    Refinement,
}

impl Module {
    pub const ALL: [Module; 3] = [Module::Construction, Module::Search, Module::Refinement];

    pub fn name(&self) -> &'static str {
        match self {
            Module::Construction => "graph_construction",
            Module::Search => "search",
            Module::Refinement => "refinement",
        }
    }

    pub fn index(&self) -> usize {
        match self {
            Module::Construction => 0,
            Module::Search => 1,
            Module::Refinement => 2,
        }
    }
}

// ---------------------------------------------------------------------------
// Action encoding: the policy's A=8 dims per module, each in [-1, 1].
// ---------------------------------------------------------------------------

#[inline]
fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * (t.clamp(-1.0, 1.0) + 1.0) / 2.0
}

#[inline]
fn unlerp(a: f64, b: f64, v: f64) -> f64 {
    (((v - a) / (b - a)) * 2.0 - 1.0).clamp(-1.0, 1.0)
}

/// Number of action dims per module — must equal `model.N_KNOBS` (checked
/// against the artifact manifest at trainer startup).
pub const N_KNOBS: usize = 8;

/// Decode a policy action vector into the given module's knobs, leaving the
/// other modules of `base` untouched (sequential optimization).
pub fn decode_action(base: &VariantConfig, module: Module, a: &[f64]) -> VariantConfig {
    assert!(a.len() >= N_KNOBS);
    let mut cfg = base.clone();
    match module {
        Module::Construction => {
            let c = &mut cfg.construction;
            c.m = lerp(8.0, 48.0, a[0]).round() as usize;
            c.ef_construction = lerp(80.0, 500.0, a[1]).round() as usize;
            c.adaptive_ef = a[2] > 0.0;
            c.ef_scale = lerp(0.0, 20.0, a[3]);
            c.num_entry_points = lerp(1.0, 9.0, a[4]).round() as usize;
            c.entry_diversity = lerp(0.0, 1.0, a[5]);
            c.prefetch_depth = lerp(0.0, 48.0, a[6]).round() as usize;
            c.prefetch_locality = lerp(1.0, 3.0, a[7]).round() as i32;
        }
        Module::Search => {
            let s = &mut cfg.search;
            s.entry_tiers = lerp(1.0, 3.0, a[0]).round() as usize;
            s.tier_budget_1 = lerp(16.0, 128.0, a[1]).round() as usize;
            s.tier_budget_2 = lerp(128.0, 384.0, a[2]).round() as usize;
            s.edge_batch = a[3] > 0.0;
            s.batch_size = lerp(4.0, 64.0, a[4]).round() as usize;
            s.early_termination = a[5] > 0.0;
            s.patience = lerp(1.0, 8.0, a[6]).round() as usize;
            s.prefetch_depth = lerp(0.0, 32.0, a[7]).round() as usize;
        }
        Module::Refinement => {
            let r = &mut cfg.refine;
            r.quantized_primary = a[0] > -0.5; // mostly on; off is a valid point
            r.adaptive_prefetch = a[1] > 0.0;
            r.lookahead = lerp(1.0, 8.0, a[2]).round() as usize;
            r.precomputed_metadata = a[3] > 0.0;
            r.rerank_frac = lerp(0.2, 2.0, a[4]);
            // dims 5..8 reserved (kept for artifact-shape stability)
        }
    }
    cfg
}

/// Encode a module's knobs back to the action space (for exemplar features
/// in the contrastive prompt — Eq. 1's database entries).
pub fn encode_action(cfg: &VariantConfig, module: Module) -> Vec<f64> {
    let mut a = vec![0.0; N_KNOBS];
    match module {
        Module::Construction => {
            let c = &cfg.construction;
            a[0] = unlerp(8.0, 48.0, c.m as f64);
            a[1] = unlerp(80.0, 500.0, c.ef_construction as f64);
            a[2] = if c.adaptive_ef { 0.8 } else { -0.8 };
            a[3] = unlerp(0.0, 20.0, c.ef_scale);
            a[4] = unlerp(1.0, 9.0, c.num_entry_points as f64);
            a[5] = unlerp(0.0, 1.0, c.entry_diversity);
            a[6] = unlerp(0.0, 48.0, c.prefetch_depth as f64);
            a[7] = unlerp(1.0, 3.0, c.prefetch_locality as f64);
        }
        Module::Search => {
            let s = &cfg.search;
            a[0] = unlerp(1.0, 3.0, s.entry_tiers as f64);
            a[1] = unlerp(16.0, 128.0, s.tier_budget_1 as f64);
            a[2] = unlerp(128.0, 384.0, s.tier_budget_2 as f64);
            a[3] = if s.edge_batch { 0.8 } else { -0.8 };
            a[4] = unlerp(4.0, 64.0, s.batch_size as f64);
            a[5] = if s.early_termination { 0.8 } else { -0.8 };
            a[6] = unlerp(1.0, 8.0, s.patience as f64);
            a[7] = unlerp(0.0, 32.0, s.prefetch_depth as f64);
        }
        Module::Refinement => {
            let r = &cfg.refine;
            a[0] = if r.quantized_primary { 0.8 } else { -0.8 };
            a[1] = if r.adaptive_prefetch { 0.8 } else { -0.8 };
            a[2] = unlerp(1.0, 8.0, r.lookahead as f64);
            a[3] = if r.precomputed_metadata { 0.8 } else { -0.8 };
            a[4] = unlerp(0.2, 2.0, r.rerank_frac);
        }
    }
    a
}

/// Render a config compactly (prompt construction, logs).
pub fn describe(cfg: &VariantConfig, module: Module) -> String {
    match module {
        Module::Construction => {
            let c = &cfg.construction;
            format!(
                "M={} efC={} adaptive_ef={} scale={:.1} entries={} diversity={:.2} prefetch={}@L{}",
                c.m, c.ef_construction, c.adaptive_ef, c.ef_scale, c.num_entry_points,
                c.entry_diversity, c.prefetch_depth, c.prefetch_locality
            )
        }
        Module::Search => {
            let s = &cfg.search;
            format!(
                "tiers={} budgets=({},{}) batch={}x{} early_term={} patience={} prefetch={}",
                s.entry_tiers, s.tier_budget_1, s.tier_budget_2, s.edge_batch, s.batch_size,
                s.early_termination, s.patience, s.prefetch_depth
            )
        }
        Module::Refinement => {
            let r = &cfg.refine;
            format!(
                "sq8={} adaptive_prefetch={} lookahead={} metadata={} rerank_frac={:.2}",
                r.quantized_primary, r.adaptive_prefetch, r.lookahead,
                r.precomputed_metadata, r.rerank_frac
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_glass_baseline() {
        let v = VariantConfig::glass_baseline();
        assert!(!v.construction.adaptive_ef);
        assert_eq!(v.construction.num_entry_points, 1);
        assert!(!v.search.edge_batch);
        assert!(!v.search.early_termination);
        assert!(v.refine.quantized_primary);
    }

    #[test]
    fn adaptive_ef_raises_effective_ef() {
        let mut c = ConstructionKnobs::default();
        assert_eq!(c.effective_ef(), c.ef_construction);
        c.adaptive_ef = true;
        c.ef_scale = 14.5;
        c.target_recall = 0.95;
        c.recall_threshold = 0.9;
        assert!(c.effective_ef() > c.ef_construction);
    }

    #[test]
    fn decode_respects_bounds_at_extremes() {
        let base = VariantConfig::glass_baseline();
        for module in Module::ALL {
            let lo = decode_action(&base, module, &[-1.0; N_KNOBS]);
            let hi = decode_action(&base, module, &[1.0; N_KNOBS]);
            match module {
                Module::Construction => {
                    assert_eq!(lo.construction.m, 8);
                    assert_eq!(hi.construction.m, 48);
                    assert_eq!(lo.construction.num_entry_points, 1);
                    assert_eq!(hi.construction.num_entry_points, 9);
                }
                Module::Search => {
                    assert_eq!(lo.search.entry_tiers, 1);
                    assert_eq!(hi.search.entry_tiers, 3);
                    assert!(!lo.search.edge_batch && hi.search.edge_batch);
                }
                Module::Refinement => {
                    assert!((lo.refine.rerank_frac - 0.2).abs() < 1e-9);
                    assert!((hi.refine.rerank_frac - 2.0).abs() < 1e-9);
                }
            }
        }
    }

    #[test]
    fn decode_only_touches_target_module() {
        let base = VariantConfig::glass_baseline();
        let out = decode_action(&base, Module::Search, &[0.5; N_KNOBS]);
        assert_eq!(out.construction, base.construction);
        assert_eq!(out.refine, base.refine);
        assert_ne!(out.search, base.search);
    }

    #[test]
    fn encode_decode_roundtrip_close() {
        let cfg = VariantConfig::crinn_full();
        for module in Module::ALL {
            let a = encode_action(&cfg, module);
            let back = decode_action(&cfg, module, &a);
            match module {
                Module::Construction => {
                    assert_eq!(back.construction.m, cfg.construction.m);
                    assert_eq!(
                        back.construction.num_entry_points,
                        cfg.construction.num_entry_points
                    );
                    assert_eq!(back.construction.adaptive_ef, cfg.construction.adaptive_ef);
                }
                Module::Search => {
                    assert_eq!(back.search.entry_tiers, cfg.search.entry_tiers);
                    assert_eq!(back.search.early_termination, cfg.search.early_termination);
                    assert_eq!(back.search.batch_size, cfg.search.batch_size);
                }
                Module::Refinement => {
                    assert_eq!(back.refine.lookahead, cfg.refine.lookahead);
                    assert!((back.refine.rerank_frac - cfg.refine.rerank_frac).abs() < 0.02);
                }
            }
        }
    }

    #[test]
    fn progressive_stages_monotone_config() {
        let stages = VariantConfig::progressive_stages();
        assert_eq!(stages.len(), 4);
        assert_eq!(stages[0].1, VariantConfig::glass_baseline());
        assert_eq!(stages[3].1, VariantConfig::crinn_full());
        // Stage 2 has construction optimized but search still baseline.
        assert_eq!(
            stages[1].1.construction,
            ConstructionKnobs::crinn_discovered()
        );
        assert_eq!(stages[1].1.search, SearchKnobs::default());
    }

    #[test]
    fn describe_mentions_key_fields() {
        let cfg = VariantConfig::crinn_full();
        assert!(describe(&cfg, Module::Construction).contains("adaptive_ef=true"));
        assert!(describe(&cfg, Module::Search).contains("early_term=true"));
        assert!(describe(&cfg, Module::Refinement).contains("sq8=true"));
    }
}
