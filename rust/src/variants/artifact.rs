//! The tuned-config artifact: the versioned, checksummed file `crinn
//! tune` writes and `crinn serve --tuned` loads at startup.
//!
//! Same container discipline as the v3 snapshot sections
//! (`anns::persist::sections`): magic, version, length, FNV-1a-64
//! checksum over the payload, and range-validated fields on load — a
//! hostile or truncated file errors loudly and never panics. The payload
//! is fixed-layout little-endian with no timestamps, so the same tuning
//! outcome always serializes to the same bytes (the seeded-determinism
//! guarantee `tests/tune.rs` asserts).
//!
//! Layout:
//!
//! ```text
//! [0..4)   magic  "CRTC"
//! [4..8)   version (u32 LE) = 2 (v2 added the IVF pq_m/pq_rerank knobs)
//! [8..12)  payload length (u32 LE)
//! [12..20) FNV-1a-64 checksum of the payload (u64 LE)
//! [20..)   payload: config knobs + provenance (fields in source order)
//! ```

use crate::util::error::{Context, Result};
use crate::variants::space::{validate_config, IndexFamily, TunedConfig};
use std::path::Path;

pub const MAGIC: &[u8; 4] = b"CRTC";
pub const VERSION: u32 = 2;
/// Bytes before the checksummed payload.
pub const HEADER_BYTES: usize = 4 + 4 + 4 + 8;

/// The FNV-1a-64 the artifact is signed with (the persist tier's
/// checksum). Public so tests can re-sign byte-patched payloads and prove
/// range validation rejects what the checksum alone would admit.
pub fn payload_checksum(bytes: &[u8]) -> u64 {
    crate::anns::persist::checksum(bytes)
}

/// A tuned configuration plus the provenance of its measurement.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedArtifact {
    pub config: TunedConfig,
    /// Dataset the tuner measured on.
    pub dataset: String,
    /// Search method (`"lagrange"`, `"grpo"`).
    pub method: String,
    /// Tuner RNG seed.
    pub seed: u64,
    /// Oracle evaluations spent.
    pub evals: u32,
    /// The recall@k constraint the tuner enforced.
    pub recall_floor: f64,
    /// recall@k at `config.serving.ef` on the held-out query split —
    /// deterministic (recall is timing-free), so artifact bytes are too.
    pub measured_recall: f64,
}

impl TunedArtifact {
    /// Stable identity of this artifact (the payload checksum) — exported
    /// as the server's tuned-config hash gauge so a metrics snapshot
    /// names the configuration that produced it.
    pub fn hash(&self) -> u64 {
        payload_checksum(&self.payload())
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let payload = self.payload();
        let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload_checksum(&payload).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<TunedArtifact> {
        crate::ensure!(
            bytes.len() >= HEADER_BYTES,
            "tuned-config artifact truncated ({} bytes < {HEADER_BYTES}-byte header)",
            bytes.len()
        );
        crate::ensure!(&bytes[0..4] == MAGIC, "not a CRINN tuned-config artifact");
        let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
        crate::ensure!(
            version == VERSION,
            "unsupported tuned-config version {version} (this build reads {VERSION})"
        );
        let plen = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        crate::ensure!(
            bytes.len() == HEADER_BYTES + plen,
            "tuned-config payload length mismatch: header says {plen}, file carries {}",
            bytes.len() - HEADER_BYTES
        );
        let stored = u64::from_le_bytes(bytes[12..20].try_into().unwrap());
        let payload = &bytes[HEADER_BYTES..];
        crate::ensure!(
            payload_checksum(payload) == stored,
            "tuned-config checksum mismatch (corrupt artifact)"
        );
        let art = parse_payload(payload).map_err(|e| e.context("tuned-config payload"))?;
        validate_config(&art.config)
            .map_err(|e| e.context("tuned-config artifact failed range validation"))?;
        for (name, v) in [
            ("recall_floor", art.recall_floor),
            ("measured_recall", art.measured_recall),
        ] {
            crate::ensure!(
                v.is_finite() && (0.0..=1.0).contains(&v),
                "tuned-config {name} = {v} outside [0, 1]"
            );
        }
        Ok(art)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing tuned-config artifact {path:?}"))
    }

    pub fn load(path: &Path) -> Result<TunedArtifact> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading tuned-config artifact {path:?}"))?;
        TunedArtifact::from_bytes(&bytes)
            .map_err(|e| e.context(format!("loading tuned-config artifact {path:?}")))
    }

    fn payload(&self) -> Vec<u8> {
        let mut w = Writer(Vec::with_capacity(256));
        let c = &self.config;
        w.u32(c.family.tag());
        w.str(&c.label);
        let k = &c.variant.construction;
        w.u32(k.m as u32);
        w.u32(k.ef_construction as u32);
        w.boolean(k.adaptive_ef);
        w.f64(k.ef_scale);
        w.f64(k.target_recall);
        w.f64(k.recall_threshold);
        w.u32(k.num_entry_points as u32);
        w.f64(k.entry_diversity);
        w.u32(k.prefetch_depth as u32);
        w.u32(k.prefetch_locality.clamp(0, 255) as u32);
        let s = &c.variant.search;
        w.u32(s.entry_tiers as u32);
        w.u32(s.tier_budget_1 as u32);
        w.u32(s.tier_budget_2 as u32);
        w.boolean(s.edge_batch);
        w.u32(s.batch_size as u32);
        w.boolean(s.early_termination);
        w.u32(s.patience as u32);
        w.u32(s.prefetch_depth as u32);
        w.u32(s.prefetch_locality.clamp(0, 255) as u32);
        let r = &c.variant.refine;
        w.boolean(r.quantized_primary);
        w.boolean(r.adaptive_prefetch);
        w.u32(r.lookahead as u32);
        w.boolean(r.precomputed_metadata);
        w.f64(r.rerank_frac);
        let i = &c.ivf;
        w.u32(i.nlist as u32);
        w.u32(i.kmeans_iters as u32);
        w.u32(i.rerank_mult as u32);
        w.boolean(i.quantized_scan);
        w.u32(i.pq_m as u32);
        w.u32(i.pq_rerank as u32);
        let v = &c.serving;
        w.u32(v.k as u32);
        w.u32(v.ef as u32);
        w.u32(v.batch as u32);
        w.u32(v.threads as u32);
        w.str(&self.dataset);
        w.str(&self.method);
        w.u64(self.seed);
        w.u32(self.evals);
        w.f64(self.recall_floor);
        w.f64(self.measured_recall);
        w.0
    }
}

fn parse_payload(payload: &[u8]) -> Result<TunedArtifact> {
    let mut r = Reader { bytes: payload, at: 0 };
    let tag = r.u32()?;
    let family = IndexFamily::from_tag(tag)
        .ok_or_else(|| crate::Error::msg(format!("unknown index family tag {tag}")))?;
    let label = r.str()?;
    let mut config = TunedConfig::for_family(family);
    config.label = label;
    let k = &mut config.variant.construction;
    k.m = r.u32()? as usize;
    k.ef_construction = r.u32()? as usize;
    k.adaptive_ef = r.boolean()?;
    k.ef_scale = r.f64()?;
    k.target_recall = r.f64()?;
    k.recall_threshold = r.f64()?;
    k.num_entry_points = r.u32()? as usize;
    k.entry_diversity = r.f64()?;
    k.prefetch_depth = r.u32()? as usize;
    k.prefetch_locality = r.u32()? as i32;
    let s = &mut config.variant.search;
    s.entry_tiers = r.u32()? as usize;
    s.tier_budget_1 = r.u32()? as usize;
    s.tier_budget_2 = r.u32()? as usize;
    s.edge_batch = r.boolean()?;
    s.batch_size = r.u32()? as usize;
    s.early_termination = r.boolean()?;
    s.patience = r.u32()? as usize;
    s.prefetch_depth = r.u32()? as usize;
    s.prefetch_locality = r.u32()? as i32;
    let rf = &mut config.variant.refine;
    rf.quantized_primary = r.boolean()?;
    rf.adaptive_prefetch = r.boolean()?;
    rf.lookahead = r.u32()? as usize;
    rf.precomputed_metadata = r.boolean()?;
    rf.rerank_frac = r.f64()?;
    let i = &mut config.ivf;
    i.nlist = r.u32()? as usize;
    i.kmeans_iters = r.u32()? as usize;
    i.rerank_mult = r.u32()? as usize;
    i.quantized_scan = r.boolean()?;
    i.pq_m = r.u32()? as usize;
    i.pq_rerank = r.u32()? as usize;
    let v = &mut config.serving;
    v.k = r.u32()? as usize;
    v.ef = r.u32()? as usize;
    v.batch = r.u32()? as usize;
    v.threads = r.u32()? as usize;
    let dataset = r.str()?;
    let method = r.str()?;
    let seed = r.u64()?;
    let evals = r.u32()?;
    let recall_floor = r.f64()?;
    let measured_recall = r.f64()?;
    crate::ensure!(
        r.at == payload.len(),
        "trailing bytes after tuned-config payload ({} of {})",
        r.at,
        payload.len()
    );
    Ok(TunedArtifact {
        config,
        dataset,
        method,
        seed,
        evals,
        recall_floor,
        measured_recall,
    })
}

struct Writer(Vec<u8>);

impl Writer {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn boolean(&mut self, v: bool) {
        self.0.push(v as u8);
    }
    fn str(&mut self, s: &str) {
        let b = s.as_bytes();
        debug_assert!(b.len() <= u16::MAX as usize);
        self.0.extend_from_slice(&(b.len() as u16).to_le_bytes());
        self.0.extend_from_slice(b);
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    at: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            self.at + n <= self.bytes.len(),
            "tuned-config payload truncated at byte {} (need {n} more)",
            self.at
        );
        let out = &self.bytes[self.at..self.at + n];
        self.at += n;
        Ok(out)
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn boolean(&mut self) -> Result<bool> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            b => crate::bail!("bool byte {b} in tuned-config payload (want 0/1)"),
        }
    }
    fn str(&mut self) -> Result<String> {
        let len = u16::from_le_bytes(self.take(2)?.try_into().unwrap()) as usize;
        crate::ensure!(len <= 256, "tuned-config string length {len} exceeds 256");
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec())
            .map_err(|_| crate::Error::msg("tuned-config string is not UTF-8"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TunedArtifact {
        TunedArtifact {
            config: TunedConfig::from_algo_name("crinn").unwrap(),
            dataset: "demo-64".into(),
            method: "lagrange".into(),
            seed: 17,
            evals: 32,
            recall_floor: 0.9,
            measured_recall: 0.94,
        }
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        let a = sample();
        let bytes = a.to_bytes();
        let back = TunedArtifact::from_bytes(&bytes).unwrap();
        assert_eq!(back, a);
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.hash(), a.hash());
    }

    #[test]
    fn serialization_is_deterministic() {
        assert_eq!(sample().to_bytes(), sample().to_bytes());
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let mut b = sample().to_bytes();
        b[0] = b'X';
        assert!(format!("{:#}", TunedArtifact::from_bytes(&b).unwrap_err())
            .contains("not a CRINN"));
        let mut b = sample().to_bytes();
        b[4] = 9; // version lives outside the checksummed payload
        assert!(format!("{:#}", TunedArtifact::from_bytes(&b).unwrap_err())
            .contains("version"));
    }

    #[test]
    fn rejects_corruption_and_truncation() {
        let bytes = sample().to_bytes();
        let mut flipped = bytes.clone();
        *flipped.last_mut().unwrap() ^= 0xff;
        assert!(format!("{:#}", TunedArtifact::from_bytes(&flipped).unwrap_err())
            .contains("checksum"));
        for cut in 0..bytes.len() {
            assert!(TunedArtifact::from_bytes(&bytes[..cut]).is_err(), "cut {cut}");
        }
        let mut longer = bytes.clone();
        longer.push(0);
        assert!(TunedArtifact::from_bytes(&longer).is_err());
    }

    #[test]
    fn rejects_out_of_range_after_resign() {
        // Byte-patch construction.m to an absurd value and re-sign the
        // checksum: the range gate (not the checksum) must reject it.
        let a = sample();
        let mut bytes = a.to_bytes();
        let m_off = HEADER_BYTES + 4 + 2 + a.config.label.len();
        bytes[m_off..m_off + 4].copy_from_slice(&1_000_000u32.to_le_bytes());
        let sum = payload_checksum(&bytes[HEADER_BYTES..]);
        bytes[12..20].copy_from_slice(&sum.to_le_bytes());
        let err = format!("{:#}", TunedArtifact::from_bytes(&bytes).unwrap_err());
        assert!(err.contains("range"), "{err}");
    }
}
