//! The unified tuning space: every buildable index family, its knobs, and
//! the serving knobs (batch size, `CRINN_THREADS` worker count) as one
//! typed, bounded configuration with a deterministic flat-`f64` encoding.
//!
//! [`VariantConfig`] remains the GLASS-centric compat view the GRPO
//! trainer and DESIGN.md §2 cite; [`TunedConfig`] embeds it and adds the
//! family tag, IVF knobs and serving knobs so one tuner can drive HNSW,
//! GLASS and IVF through the same [`TuningSpace::encode`]/
//! [`TuningSpace::decode`] pair. For the `VariantConfig` portion the flat
//! vector is exactly the action layout [`decode_action`]/[`encode_action`]
//! already use (one [`super::N_KNOBS`]-dim block per module), so policy
//! actions and tuner actions are the same coordinates.
//!
//! Decoded float knobs are snapped to a 256-step grid over their bound
//! range, which makes `decode ∘ encode` idempotent at the config level:
//! `decode(encode(decode(a))) == decode(a)` bit-for-bit (asserted by
//! `tests/tune.rs`). Without the snap, `lerp`/`unlerp` round-trips drift
//! by an ulp and artifact bytes would not be reproducible.

use crate::util::error::Result;
use crate::variants::{decode_action, encode_action, Module, VariantConfig, N_KNOBS};

/// A buildable index family (the CLI `--algo` axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum IndexFamily {
    BruteForce,
    Hnsw,
    Glass,
    Ivf,
    Vamana,
    NnDescent,
}

impl IndexFamily {
    pub const ALL: [IndexFamily; 6] = [
        IndexFamily::BruteForce,
        IndexFamily::Hnsw,
        IndexFamily::Glass,
        IndexFamily::Ivf,
        IndexFamily::Vamana,
        IndexFamily::NnDescent,
    ];

    /// Families with a tuning space (the rest build only at their preset).
    pub const TUNABLE: [IndexFamily; 3] = [IndexFamily::Hnsw, IndexFamily::Glass, IndexFamily::Ivf];

    /// Canonical name (the CLI algo string of the family's plain preset).
    pub fn name(self) -> &'static str {
        match self {
            IndexFamily::BruteForce => "bruteforce",
            IndexFamily::Hnsw => "hnsw",
            IndexFamily::Glass => "glass",
            IndexFamily::Ivf => "vearch-ivf",
            IndexFamily::Vamana => "parlayann",
            IndexFamily::NnDescent => "nndescent",
        }
    }

    /// Stable artifact tag (never reorder — serialized in tuned-config
    /// artifacts).
    pub fn tag(self) -> u32 {
        match self {
            IndexFamily::BruteForce => 0,
            IndexFamily::Hnsw => 1,
            IndexFamily::Glass => 2,
            IndexFamily::Ivf => 3,
            IndexFamily::Vamana => 4,
            IndexFamily::NnDescent => 5,
        }
    }

    pub fn from_tag(tag: u32) -> Option<IndexFamily> {
        IndexFamily::ALL.into_iter().find(|f| f.tag() == tag)
    }

    pub fn is_tunable(self) -> bool {
        IndexFamily::TUNABLE.contains(&self)
    }
}

/// IVF knobs (mirrors `anns::ivf::IvfParams`; kept here so the tuning
/// layer has no build-time dependency direction on the index modules).
#[derive(Clone, Debug, PartialEq)]
pub struct IvfKnobs {
    /// Number of partitions (0 = `sqrt(n)` heuristic).
    pub nlist: usize,
    /// Lloyd iterations.
    pub kmeans_iters: usize,
    /// Rerank multiplier over k during the exact pass.
    pub rerank_mult: usize,
    /// SQ8 posting-list scan + exact rerank vs. exact IVFFlat.
    pub quantized_scan: bool,
    /// 4-bit PQ subquantizer count (0 = PQ off). When > 0, posting lists
    /// scan packed PQ codes with the fast-scan ADC kernel and PQ
    /// supersedes the SQ8 scan.
    pub pq_m: usize,
    /// Rerank-pool multiplier over k for the PQ candidate pass.
    pub pq_rerank: usize,
}

impl Default for IvfKnobs {
    fn default() -> Self {
        IvfKnobs {
            nlist: 0,
            kmeans_iters: 8,
            rerank_mult: 4,
            quantized_scan: true,
            pq_m: 0,
            pq_rerank: 8,
        }
    }
}

/// Serving knobs: the operating point the server defaults to.
#[derive(Clone, Debug, PartialEq)]
pub struct ServingKnobs {
    /// Neighbors per query (the recall constraint is recall@k at this k).
    pub k: usize,
    /// Default search beam width (`nprobe` scale for IVF). Not a search
    /// dimension: the tuner derives it from the winning curve — smallest
    /// grid ef meeting the recall floor.
    pub ef: usize,
    /// Dynamic-batcher `max_batch`; also the oracle's measurement batch
    /// when serving knobs are scored (≤ 1 = per-query protocol).
    pub batch: usize,
    /// Worker threads (0 = `CRINN_THREADS`/auto).
    pub threads: usize,
}

impl Default for ServingKnobs {
    fn default() -> Self {
        ServingKnobs {
            k: crate::DEFAULT_K,
            ef: 64,
            batch: 64,
            threads: 0,
        }
    }
}

/// One point in the unified space: family + per-family knobs + serving
/// knobs. [`VariantConfig`] is embedded as-is — the GLASS/HNSW compat
/// view — so `crinn train`/`prompt` keep resolving unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedConfig {
    pub family: IndexFamily,
    /// CLI/display label. `"crinn"` and `"pynndescent"` select presets of
    /// their family in [`super::build_index`]; otherwise cosmetic.
    pub label: String,
    pub variant: VariantConfig,
    pub ivf: IvfKnobs,
    pub serving: ServingKnobs,
}

impl Default for TunedConfig {
    fn default() -> Self {
        TunedConfig::from_variant(VariantConfig::glass_baseline())
    }
}

impl TunedConfig {
    /// Compat constructor: wrap a GLASS-space [`VariantConfig`] (the GRPO
    /// trainer's currency) with default family/serving context.
    pub fn from_variant(variant: VariantConfig) -> Self {
        TunedConfig {
            family: IndexFamily::Glass,
            label: "glass".to_string(),
            variant,
            ivf: IvfKnobs::default(),
            serving: ServingKnobs::default(),
        }
    }

    /// The family's default preset (GLASS baseline knobs for the graph
    /// families, `IvfKnobs::default` for IVF).
    pub fn for_family(family: IndexFamily) -> Self {
        TunedConfig {
            family,
            label: family.name().to_string(),
            variant: VariantConfig::glass_baseline(),
            ivf: IvfKnobs::default(),
            serving: ServingKnobs::default(),
        }
    }

    /// Map a CLI `--algo` string to its configuration — the single place
    /// the nine algo names resolve (`cmd_sweep`, `cmd_serve` and
    /// `crinn tune` all go through here).
    pub fn from_algo_name(algo: &str) -> Option<Self> {
        let mut cfg = match algo {
            "bruteforce" => TunedConfig::for_family(IndexFamily::BruteForce),
            "hnsw" => TunedConfig::for_family(IndexFamily::Hnsw),
            "glass" => TunedConfig::for_family(IndexFamily::Glass),
            "crinn" => {
                let mut c = TunedConfig::for_family(IndexFamily::Glass);
                c.variant = VariantConfig::crinn_full();
                c
            }
            "parlayann" => TunedConfig::for_family(IndexFamily::Vamana),
            "nndescent" | "pynndescent" => TunedConfig::for_family(IndexFamily::NnDescent),
            "vearch-ivf" => TunedConfig::for_family(IndexFamily::Ivf),
            "ivfpq" => {
                let mut c = TunedConfig::for_family(IndexFamily::Ivf);
                c.ivf.pq_m = 16;
                c.ivf.pq_rerank = 8;
                c
            }
            _ => return None,
        };
        cfg.label = algo.to_string();
        Some(cfg)
    }

    /// The `anns::ivf` parameter struct this configuration builds with.
    pub fn ivf_params(&self) -> crate::anns::ivf::IvfParams {
        crate::anns::ivf::IvfParams {
            nlist: self.ivf.nlist,
            kmeans_iters: self.ivf.kmeans_iters,
            rerank_mult: self.ivf.rerank_mult,
            quantized_scan: self.ivf.quantized_scan,
            pq_m: self.ivf.pq_m,
            pq_rerank: self.ivf.pq_rerank,
        }
    }

    /// Compact one-line render (tuner logs, CLI summaries).
    pub fn describe(&self) -> String {
        let s = &self.serving;
        let serving = format!("k={} ef={} batch={} threads={}", s.k, s.ef, s.batch, s.threads);
        match self.family {
            IndexFamily::Ivf => {
                let i = &self.ivf;
                format!(
                    "{}: nlist={} kmeans_iters={} rerank_mult={} sq8={} pq_m={} pq_rerank={} | {serving}",
                    self.label,
                    i.nlist,
                    i.kmeans_iters,
                    i.rerank_mult,
                    i.quantized_scan,
                    i.pq_m,
                    i.pq_rerank
                )
            }
            _ => {
                let c = &self.variant.construction;
                format!(
                    "{}: M={} efC={} entries={} | {} | {serving}",
                    self.label,
                    c.m,
                    c.ef_construction,
                    c.num_entry_points,
                    crate::variants::describe(&self.variant, Module::Search)
                )
            }
        }
    }
}

/// Value kind of one tuning dimension.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KnobKind {
    Int,
    Float,
    Bool,
}

/// One typed, bounded dimension of a [`TuningSpace`].
#[derive(Clone, Copy, Debug)]
pub struct KnobBound {
    pub name: &'static str,
    pub kind: KnobKind,
    pub lo: f64,
    pub hi: f64,
}

const fn kb(name: &'static str, kind: KnobKind, lo: f64, hi: f64) -> KnobBound {
    KnobBound { name, kind, lo, hi }
}

// The bounds below mirror the lerp ranges hardcoded in
// `decode_action`/`encode_action` — the action layout is shared, so the
// numbers must stay in lockstep (asserted by `bounds_match_action_space`).
const CONSTRUCTION_BOUNDS: [KnobBound; N_KNOBS] = [
    kb("construction.m", KnobKind::Int, 8.0, 48.0),
    kb("construction.ef_construction", KnobKind::Int, 80.0, 500.0),
    kb("construction.adaptive_ef", KnobKind::Bool, 0.0, 1.0),
    kb("construction.ef_scale", KnobKind::Float, 0.0, 20.0),
    kb("construction.num_entry_points", KnobKind::Int, 1.0, 9.0),
    kb("construction.entry_diversity", KnobKind::Float, 0.0, 1.0),
    kb("construction.prefetch_depth", KnobKind::Int, 0.0, 48.0),
    kb("construction.prefetch_locality", KnobKind::Int, 1.0, 3.0),
];

const SEARCH_BOUNDS: [KnobBound; N_KNOBS] = [
    kb("search.entry_tiers", KnobKind::Int, 1.0, 3.0),
    kb("search.tier_budget_1", KnobKind::Int, 16.0, 128.0),
    kb("search.tier_budget_2", KnobKind::Int, 128.0, 384.0),
    kb("search.edge_batch", KnobKind::Bool, 0.0, 1.0),
    kb("search.batch_size", KnobKind::Int, 4.0, 64.0),
    kb("search.early_termination", KnobKind::Bool, 0.0, 1.0),
    kb("search.patience", KnobKind::Int, 1.0, 8.0),
    kb("search.prefetch_depth", KnobKind::Int, 0.0, 32.0),
];

const REFINE_BOUNDS: [KnobBound; N_KNOBS] = [
    kb("refine.quantized_primary", KnobKind::Bool, 0.0, 1.0),
    kb("refine.adaptive_prefetch", KnobKind::Bool, 0.0, 1.0),
    kb("refine.lookahead", KnobKind::Int, 1.0, 8.0),
    kb("refine.precomputed_metadata", KnobKind::Bool, 0.0, 1.0),
    kb("refine.rerank_frac", KnobKind::Float, 0.2, 2.0),
    // dims 5..8 reserved (artifact-shape stability, like decode_action)
    kb("refine.reserved5", KnobKind::Float, -1.0, 1.0),
    kb("refine.reserved6", KnobKind::Float, -1.0, 1.0),
    kb("refine.reserved7", KnobKind::Float, -1.0, 1.0),
];

const IVF_BOUNDS: [KnobBound; 6] = [
    kb("ivf.nlist", KnobKind::Int, 8.0, 2048.0),
    kb("ivf.kmeans_iters", KnobKind::Int, 2.0, 20.0),
    kb("ivf.rerank_mult", KnobKind::Int, 1.0, 16.0),
    kb("ivf.quantized_scan", KnobKind::Bool, 0.0, 1.0),
    // 0 is in-range (PQ off), so no zero-sentinel carve-out is needed.
    kb("ivf.pq_m", KnobKind::Int, 0.0, 64.0),
    kb("ivf.pq_rerank", KnobKind::Int, 1.0, 32.0),
];

const SERVING_BOUNDS: [KnobBound; 2] = [
    kb("serving.batch", KnobKind::Int, 1.0, 128.0),
    kb("serving.threads", KnobKind::Int, 1.0, 8.0),
];

/// Knobs where 0 is a valid sentinel outside the tuning range (`nlist`'s
/// sqrt heuristic, `threads`' CRINN_THREADS/auto). Decode never emits 0;
/// validation accepts it.
const ZERO_SENTINEL_OK: [&str; 2] = ["ivf.nlist", "serving.threads"];

#[inline]
fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * (t.clamp(-1.0, 1.0) + 1.0) / 2.0
}

#[inline]
fn unlerp(a: f64, b: f64, v: f64) -> f64 {
    (((v - a) / (b - a)) * 2.0 - 1.0).clamp(-1.0, 1.0)
}

/// Snap a float knob onto a 256-step grid over `[lo, hi]` — the
/// quantization that makes decode idempotent (module docs).
fn snap(v: f64, lo: f64, hi: f64) -> f64 {
    const STEPS: f64 = 256.0;
    let t = (((v - lo) / (hi - lo)) * STEPS).round().clamp(0.0, STEPS);
    lo + (hi - lo) * (t / STEPS)
}

/// The typed, bounded search space of one tunable family.
#[derive(Clone, Debug)]
pub struct TuningSpace {
    family: IndexFamily,
    bounds: Vec<KnobBound>,
}

impl TuningSpace {
    /// The space for a tunable family; errors for families that only
    /// build at their preset (brute force, Vamana, NN-Descent).
    pub fn for_family(family: IndexFamily) -> Result<TuningSpace> {
        crate::ensure!(
            family.is_tunable(),
            "index family {} has no tuning space (preset-only build)",
            family.name()
        );
        let mut bounds: Vec<KnobBound> = Vec::new();
        match family {
            IndexFamily::Glass => {
                bounds.extend(CONSTRUCTION_BOUNDS);
                bounds.extend(SEARCH_BOUNDS);
                bounds.extend(REFINE_BOUNDS);
            }
            IndexFamily::Hnsw => {
                bounds.extend(CONSTRUCTION_BOUNDS);
                bounds.extend(SEARCH_BOUNDS);
            }
            IndexFamily::Ivf => bounds.extend(IVF_BOUNDS),
            _ => unreachable!("is_tunable checked above"),
        }
        bounds.extend(SERVING_BOUNDS);
        Ok(TuningSpace { family, bounds })
    }

    pub fn family(&self) -> IndexFamily {
        self.family
    }

    /// Number of flat action dimensions.
    pub fn dims(&self) -> usize {
        self.bounds.len()
    }

    /// The typed bound of every dimension, in encode/decode order.
    pub fn bounds(&self) -> &[KnobBound] {
        &self.bounds
    }

    /// Encode a configuration to the flat action vector (each dim in
    /// `[-1, 1]`; the `VariantConfig` blocks use [`encode_action`]'s
    /// exact layout).
    pub fn encode(&self, cfg: &TunedConfig) -> Vec<f64> {
        let mut a = Vec::with_capacity(self.dims());
        match self.family {
            IndexFamily::Glass => {
                a.extend(encode_action(&cfg.variant, Module::Construction));
                a.extend(encode_action(&cfg.variant, Module::Search));
                a.extend(encode_action(&cfg.variant, Module::Refinement));
            }
            IndexFamily::Hnsw => {
                a.extend(encode_action(&cfg.variant, Module::Construction));
                a.extend(encode_action(&cfg.variant, Module::Search));
            }
            IndexFamily::Ivf => {
                let i = &cfg.ivf;
                a.push(unlerp(IVF_BOUNDS[0].lo, IVF_BOUNDS[0].hi, i.nlist as f64));
                a.push(unlerp(IVF_BOUNDS[1].lo, IVF_BOUNDS[1].hi, i.kmeans_iters as f64));
                a.push(unlerp(IVF_BOUNDS[2].lo, IVF_BOUNDS[2].hi, i.rerank_mult as f64));
                a.push(if i.quantized_scan { 0.8 } else { -0.8 });
                a.push(unlerp(IVF_BOUNDS[4].lo, IVF_BOUNDS[4].hi, i.pq_m as f64));
                a.push(unlerp(IVF_BOUNDS[5].lo, IVF_BOUNDS[5].hi, i.pq_rerank as f64));
            }
            _ => unreachable!("constructed only for tunable families"),
        }
        let s = &cfg.serving;
        a.push(unlerp(SERVING_BOUNDS[0].lo, SERVING_BOUNDS[0].hi, s.batch as f64));
        a.push(unlerp(
            SERVING_BOUNDS[1].lo,
            SERVING_BOUNDS[1].hi,
            s.threads.max(1) as f64,
        ));
        a
    }

    /// Decode a flat action vector (values clamped to `[-1, 1]`) into a
    /// full configuration; float knobs are grid-snapped (module docs).
    pub fn decode(&self, a: &[f64]) -> TunedConfig {
        assert!(a.len() >= self.dims(), "action has {} of {} dims", a.len(), self.dims());
        let mut cfg = TunedConfig::for_family(self.family);
        let serving_at = self.dims() - SERVING_BOUNDS.len();
        match self.family {
            IndexFamily::Glass => {
                let v = decode_action(&cfg.variant, Module::Construction, &a[..N_KNOBS]);
                let v = decode_action(&v, Module::Search, &a[N_KNOBS..2 * N_KNOBS]);
                let v = decode_action(&v, Module::Refinement, &a[2 * N_KNOBS..3 * N_KNOBS]);
                cfg.variant = v;
                snap_variant_floats(&mut cfg.variant);
            }
            IndexFamily::Hnsw => {
                let v = decode_action(&cfg.variant, Module::Construction, &a[..N_KNOBS]);
                let v = decode_action(&v, Module::Search, &a[N_KNOBS..2 * N_KNOBS]);
                cfg.variant = v;
                snap_variant_floats(&mut cfg.variant);
            }
            IndexFamily::Ivf => {
                let i = &mut cfg.ivf;
                i.nlist = lerp(IVF_BOUNDS[0].lo, IVF_BOUNDS[0].hi, a[0]).round() as usize;
                i.kmeans_iters = lerp(IVF_BOUNDS[1].lo, IVF_BOUNDS[1].hi, a[1]).round() as usize;
                i.rerank_mult = lerp(IVF_BOUNDS[2].lo, IVF_BOUNDS[2].hi, a[2]).round() as usize;
                i.quantized_scan = a[3] > 0.0;
                i.pq_m = lerp(IVF_BOUNDS[4].lo, IVF_BOUNDS[4].hi, a[4]).round() as usize;
                i.pq_rerank = lerp(IVF_BOUNDS[5].lo, IVF_BOUNDS[5].hi, a[5]).round() as usize;
            }
            _ => unreachable!("constructed only for tunable families"),
        }
        let s = &mut cfg.serving;
        s.batch = lerp(SERVING_BOUNDS[0].lo, SERVING_BOUNDS[0].hi, a[serving_at]).round() as usize;
        s.threads =
            lerp(SERVING_BOUNDS[1].lo, SERVING_BOUNDS[1].hi, a[serving_at + 1]).round() as usize;
        cfg
    }

    /// Range-validate a configuration against this space's typed bounds
    /// (plus the family-independent checks of [`validate_config`]'s
    /// caller). Hostile values error; nothing panics.
    pub fn validate(&self, cfg: &TunedConfig) -> Result<()> {
        crate::ensure!(
            cfg.family == self.family,
            "config family {} does not match space family {}",
            cfg.family.name(),
            self.family.name()
        );
        for b in &self.bounds {
            let Some(v) = knob_value(cfg, b.name) else {
                continue; // bools and reserved dims have no invalid values
            };
            crate::ensure!(
                v.is_finite(),
                "knob {} is not finite ({v})",
                b.name
            );
            if v == 0.0 && ZERO_SENTINEL_OK.contains(&b.name) {
                continue;
            }
            crate::ensure!(
                v >= b.lo && v <= b.hi,
                "knob {} = {v} out of range [{}, {}]",
                b.name,
                b.lo,
                b.hi
            );
        }
        Ok(())
    }
}

fn snap_variant_floats(v: &mut VariantConfig) {
    v.construction.ef_scale = snap(v.construction.ef_scale, 0.0, 20.0);
    v.construction.entry_diversity = snap(v.construction.entry_diversity, 0.0, 1.0);
    v.refine.rerank_frac = snap(v.refine.rerank_frac, 0.2, 2.0);
}

/// Numeric value of a named knob (None for bools/reserved dims).
fn knob_value(cfg: &TunedConfig, name: &str) -> Option<f64> {
    let c = &cfg.variant.construction;
    let s = &cfg.variant.search;
    let r = &cfg.variant.refine;
    Some(match name {
        "construction.m" => c.m as f64,
        "construction.ef_construction" => c.ef_construction as f64,
        "construction.ef_scale" => c.ef_scale,
        "construction.num_entry_points" => c.num_entry_points as f64,
        "construction.entry_diversity" => c.entry_diversity,
        "construction.prefetch_depth" => c.prefetch_depth as f64,
        "construction.prefetch_locality" => c.prefetch_locality as f64,
        "search.entry_tiers" => s.entry_tiers as f64,
        "search.tier_budget_1" => s.tier_budget_1 as f64,
        "search.tier_budget_2" => s.tier_budget_2 as f64,
        "search.batch_size" => s.batch_size as f64,
        "search.patience" => s.patience as f64,
        "search.prefetch_depth" => s.prefetch_depth as f64,
        "refine.lookahead" => r.lookahead as f64,
        "refine.rerank_frac" => r.rerank_frac,
        "ivf.nlist" => cfg.ivf.nlist as f64,
        "ivf.kmeans_iters" => cfg.ivf.kmeans_iters as f64,
        "ivf.rerank_mult" => cfg.ivf.rerank_mult as f64,
        "ivf.pq_m" => cfg.ivf.pq_m as f64,
        "ivf.pq_rerank" => cfg.ivf.pq_rerank as f64,
        "serving.batch" => cfg.serving.batch as f64,
        "serving.threads" => cfg.serving.threads as f64,
        _ => return None,
    })
}

/// Validate any [`TunedConfig`] — tunable families additionally pass
/// through their space's typed bounds. This is the artifact loader's
/// range gate: hostile files fail loudly here, never panic.
pub fn validate_config(cfg: &TunedConfig) -> Result<()> {
    let s = &cfg.serving;
    crate::ensure!(!cfg.label.is_empty() && cfg.label.len() <= 64, "bad label length");
    crate::ensure!(s.k >= 1 && s.k <= 1024, "serving.k {} out of range [1, 1024]", s.k);
    crate::ensure!(s.ef >= 1 && s.ef <= 100_000, "serving.ef {} out of range", s.ef);
    crate::ensure!(
        s.batch >= 1 && s.batch <= 4096,
        "serving.batch {} out of range [1, 4096]",
        s.batch
    );
    crate::ensure!(s.threads <= 1024, "serving.threads {} out of range", s.threads);
    let c = &cfg.variant.construction;
    for (name, v) in [
        ("target_recall", c.target_recall),
        ("recall_threshold", c.recall_threshold),
        ("ef_scale", c.ef_scale),
        ("entry_diversity", c.entry_diversity),
        ("rerank_frac", cfg.variant.refine.rerank_frac),
    ] {
        crate::ensure!(v.is_finite(), "knob {name} is not finite");
    }
    if cfg.family.is_tunable() {
        TuningSpace::for_family(cfg.family)?.validate(cfg)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_tags_roundtrip() {
        for f in IndexFamily::ALL {
            assert_eq!(IndexFamily::from_tag(f.tag()), Some(f));
        }
        assert_eq!(IndexFamily::from_tag(99), None);
    }

    #[test]
    fn algo_names_cover_the_cli() {
        for algo in [
            "bruteforce",
            "hnsw",
            "glass",
            "crinn",
            "parlayann",
            "nndescent",
            "pynndescent",
            "vearch-ivf",
            "ivfpq",
        ] {
            let cfg = TunedConfig::from_algo_name(algo).unwrap();
            assert_eq!(cfg.label, algo);
            validate_config(&cfg).unwrap();
        }
        assert!(TunedConfig::from_algo_name("faiss").is_none());
        assert_eq!(
            TunedConfig::from_algo_name("crinn").unwrap().variant,
            VariantConfig::crinn_full()
        );
        let ivfpq = TunedConfig::from_algo_name("ivfpq").unwrap();
        assert_eq!(ivfpq.family, IndexFamily::Ivf);
        assert_eq!((ivfpq.ivf.pq_m, ivfpq.ivf.pq_rerank), (16, 8));
        assert_eq!(TunedConfig::from_algo_name("vearch-ivf").unwrap().ivf.pq_m, 0);
    }

    #[test]
    fn bounds_match_action_space() {
        // The GLASS space is exactly the policy's 3 × N_KNOBS action
        // layout plus the two serving dims.
        let glass = TuningSpace::for_family(IndexFamily::Glass).unwrap();
        assert_eq!(glass.dims(), 3 * N_KNOBS + 2);
        let hnsw = TuningSpace::for_family(IndexFamily::Hnsw).unwrap();
        assert_eq!(hnsw.dims(), 2 * N_KNOBS + 2);
        let ivf = TuningSpace::for_family(IndexFamily::Ivf).unwrap();
        assert_eq!(ivf.dims(), 8);
        // encode_action and the bound table agree on the m range.
        let mut cfg = TunedConfig::for_family(IndexFamily::Glass);
        cfg.variant = decode_action(&cfg.variant, Module::Construction, &[-1.0; N_KNOBS]);
        assert_eq!(cfg.variant.construction.m as f64, CONSTRUCTION_BOUNDS[0].lo);
    }

    #[test]
    fn non_tunable_families_error() {
        for f in [IndexFamily::BruteForce, IndexFamily::Vamana, IndexFamily::NnDescent] {
            assert!(TuningSpace::for_family(f).is_err(), "{f:?}");
        }
    }

    #[test]
    fn presets_validate() {
        for f in IndexFamily::ALL {
            validate_config(&TunedConfig::for_family(f)).unwrap();
        }
        validate_config(&TunedConfig::from_variant(VariantConfig::crinn_full())).unwrap();
    }

    #[test]
    fn decode_is_idempotent_under_encode() {
        for f in IndexFamily::TUNABLE {
            let space = TuningSpace::for_family(f).unwrap();
            let mut rng = crate::util::rng::Rng::new(11 + f.tag() as u64);
            for _ in 0..20 {
                let a: Vec<f64> = (0..space.dims()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
                let c1 = space.decode(&a);
                space.validate(&c1).unwrap();
                let e1 = space.encode(&c1);
                let c2 = space.decode(&e1);
                assert_eq!(c1, c2, "{f:?}");
                assert_eq!(e1, space.encode(&c2), "{f:?}");
            }
        }
    }

    #[test]
    fn validate_rejects_out_of_range() {
        let space = TuningSpace::for_family(IndexFamily::Glass).unwrap();
        let mut cfg = TunedConfig::for_family(IndexFamily::Glass);
        cfg.variant.construction.m = 4000;
        let err = space.validate(&cfg).unwrap_err();
        assert!(format!("{err:#}").contains("construction.m"), "{err:#}");
        let mut cfg = TunedConfig::for_family(IndexFamily::Ivf);
        cfg.ivf.nlist = 0; // sqrt sentinel stays valid
        validate_config(&cfg).unwrap();
        cfg.ivf.nlist = 1 << 20;
        assert!(validate_config(&cfg).is_err());
    }
}
