//! One construction path for every index family: `crinn sweep`,
//! `crinn serve`, and the tuner's reward oracle all build through
//! [`build_index`], so adding a family means touching exactly one match.

use crate::anns::{AnnIndex, VectorSet};
use crate::variants::space::{IndexFamily, TunedConfig};
use std::sync::Arc;

/// Build the index a [`TunedConfig`] describes. Deterministic per
/// `(config, vectors, seed)` — the discipline every reward measurement
/// and every artifact replay relies on. Arm-for-arm equivalent to the
/// former per-subcommand `match` in `main.rs`.
pub fn build_index(cfg: &TunedConfig, vs: VectorSet, seed: u64) -> Arc<dyn AnnIndex> {
    match cfg.family {
        IndexFamily::BruteForce => Arc::new(crate::anns::bruteforce::BruteForceIndex::build(vs)),
        IndexFamily::Hnsw => Arc::new(crate::anns::hnsw::HnswIndex::build(
            vs,
            &cfg.variant.construction,
            cfg.variant.search.clone(),
            seed,
        )),
        IndexFamily::Glass => Arc::new(
            crate::anns::glass::GlassIndex::build(vs, cfg.variant.clone(), seed)
                .with_label(&cfg.label),
        ),
        IndexFamily::Vamana => Arc::new(crate::anns::vamana::VamanaIndex::build(
            vs,
            crate::anns::vamana::VamanaParams::default(),
            seed,
        )),
        IndexFamily::NnDescent => {
            let params = if cfg.label == "pynndescent" {
                crate::anns::nndescent::NnDescentParams::pynndescent()
            } else {
                crate::anns::nndescent::NnDescentParams::default()
            };
            Arc::new(crate::anns::nndescent::NnDescentIndex::build(vs, params, seed))
        }
        IndexFamily::Ivf => Arc::new(crate::anns::ivf::IvfIndex::build(vs, cfg.ivf_params(), seed)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn tiny_vs() -> (crate::dataset::Dataset, VectorSet) {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 10, 73);
        ds.compute_ground_truth(10);
        let vs = VectorSet::from_dataset(&ds);
        (ds, vs)
    }

    #[test]
    fn builds_every_family_and_searches() {
        let (ds, _) = tiny_vs();
        for algo in [
            "bruteforce",
            "hnsw",
            "glass",
            "crinn",
            "parlayann",
            "nndescent",
            "pynndescent",
            "vearch-ivf",
            "ivfpq",
        ] {
            let cfg = TunedConfig::from_algo_name(algo).unwrap();
            let idx = build_index(&cfg, VectorSet::from_dataset(&ds), 42);
            assert_eq!(idx.len(), 400, "{algo}");
            let found = idx.search(ds.query_vec(0), 10, 64);
            assert_eq!(found.len(), 10, "{algo}");
        }
    }

    #[test]
    fn glass_build_matches_direct_construction_bitwise() {
        // The dedupe must not change what `crinn sweep --algo crinn`
        // builds: same config + seed → identical search results.
        let (ds, vs) = tiny_vs();
        let direct = crate::anns::glass::GlassIndex::build(
            vs,
            crate::variants::VariantConfig::crinn_full(),
            42,
        )
        .with_label("crinn");
        let cfg = TunedConfig::from_algo_name("crinn").unwrap();
        let via_helper = build_index(&cfg, VectorSet::from_dataset(&ds), 42);
        assert_eq!(via_helper.name(), "crinn");
        for qi in 0..ds.n_queries() {
            assert_eq!(
                via_helper.search_with_dists(ds.query_vec(qi), 10, 48),
                direct.search_with_dists(ds.query_vec(qi), 10, 48),
                "query {qi}"
            );
        }
    }
}
