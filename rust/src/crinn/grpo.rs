//! §3.4 GRPO optimizer state.
//!
//! Holds the policy parameters, Adam moments and the frozen reference
//! policy; each [`GrpoOptimizer::step`] is one execution of the fused
//! `grpo_step` artifact (Eq. 3: clipped importance-weighted surrogate with
//! group-normalized advantages and a KL penalty toward the reference).

use crate::runtime::Engine;
use crate::util::error::Result;

/// GRPO hyperparameters (paper notation: ε clip, β KL weight).
#[derive(Clone, Debug)]
pub struct GrpoHyper {
    pub lr: f32,
    pub clip_eps: f32,
    pub kl_beta: f32,
}

impl Default for GrpoHyper {
    fn default() -> Self {
        GrpoHyper {
            lr: 3e-3,
            clip_eps: 0.2,
            kl_beta: 0.02,
        }
    }
}

/// Policy + optimizer state living on the Rust side; math runs via PJRT.
pub struct GrpoOptimizer<'e> {
    engine: &'e Engine,
    pub hyper: GrpoHyper,
    pub params: Vec<Vec<f32>>,
    pub ref_params: Vec<Vec<f32>>,
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    /// Adam step counter.
    pub t: usize,
    /// Loss history (diagnostics / EXPERIMENTS.md).
    pub losses: Vec<f32>,
}

impl<'e> GrpoOptimizer<'e> {
    /// Initialize from the manifest's init params (the π_ref snapshot).
    pub fn new(engine: &'e Engine, hyper: GrpoHyper) -> Self {
        let params = engine.manifest.init_params.clone();
        let zeros: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        GrpoOptimizer {
            engine,
            hyper,
            ref_params: params.clone(),
            adam_m: zeros.clone(),
            adam_v: zeros,
            params,
            t: 0,
            losses: Vec::new(),
        }
    }

    /// Policy forward pass for a feature batch `[G, F]`.
    pub fn forward(&self, feats: &[f32]) -> Result<(Vec<f32>, Vec<f32>)> {
        self.engine.policy_forward(&self.params, feats)
    }

    /// One GRPO update. `advantages` are already Eq.-2 normalized.
    pub fn step(
        &mut self,
        feats: &[f32],
        actions: &[f32],
        advantages: &[f32],
        old_logp: &[f32],
    ) -> Result<f32> {
        self.t += 1;
        let (p, m, v, loss) = self.engine.grpo_step(
            &self.params,
            &self.adam_m,
            &self.adam_v,
            &self.ref_params,
            feats,
            actions,
            advantages,
            old_logp,
            self.hyper.lr,
            self.hyper.clip_eps,
            self.hyper.kl_beta,
            self.t as f32,
        )?;
        self.params = p;
        self.adam_m = m;
        self.adam_v = v;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Refresh the KL reference to the current policy (between modules,
    /// mirroring per-round reference resets in GRPO practice).
    pub fn refresh_reference(&mut self) {
        self.ref_params = self.params.clone();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn engine() -> Option<Engine> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Engine::new(&dir) {
            Ok(e) => Some(e),
            Err(e) if format!("{e:#}").contains("offline stub") => {
                eprintln!("skipping: PJRT backend is the offline stub");
                None
            }
            Err(e) => panic!("engine failed with artifacts present: {e:#}"),
        }
    }

    /// End-to-end sanity: rewarding actions near +0.5 on every knob must
    /// pull the policy mean toward +0.5. On-policy GRPO with G=8 is noisy,
    /// so we assert on the best error reached during training rather than
    /// the endpoint.
    #[test]
    fn policy_learns_synthetic_objective() {
        let Some(e) = engine() else { return };
        let m = e.manifest.clone();
        let mut opt = GrpoOptimizer::new(&e, GrpoHyper { lr: 0.01, ..Default::default() });
        let mut rng = Rng::new(13);
        let feats = vec![0.0f32; m.group * m.feat_dim];

        let mean_err = |opt: &GrpoOptimizer| -> f32 {
            let (mean, _) = opt.forward(&feats).unwrap();
            mean.iter().map(|x| (x - 0.5).abs()).sum::<f32>() / mean.len() as f32
        };
        let before = mean_err(&opt);
        let mut best = before;
        for _ in 0..30 {
            let (mean, logstd) = opt.forward(&feats).unwrap();
            let grp = crate::crinn::policy::sample_actions(
                &mean, &logstd, m.group, m.n_knobs, &mut rng,
            );
            // Reward = negative distance of action from +0.5.
            let rewards: Vec<f64> = (0..m.group)
                .map(|g| {
                    let s: f32 = (0..m.n_knobs)
                        .map(|a| (grp.actions[g * m.n_knobs + a] - 0.5).abs())
                        .sum();
                    -(s as f64)
                })
                .collect();
            let adv = crate::crinn::policy::normalize_advantages(&rewards);
            opt.step(&feats, &grp.actions, &adv, &grp.logp).unwrap();
            best = best.min(mean_err(&opt));
        }
        assert!(
            best < before * 0.7,
            "policy failed to learn: {before} -> best {best}"
        );
        assert!(opt.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn reference_refresh_copies_params() {
        let Some(e) = engine() else { return };
        let mut opt = GrpoOptimizer::new(&e, GrpoHyper::default());
        opt.params[0][0] += 1.0;
        assert_ne!(opt.params[0][0], opt.ref_params[0][0]);
        opt.refresh_reference();
        assert_eq!(opt.params[0][0], opt.ref_params[0][0]);
    }
}
