//! §3.3 Speed reward: area under the QPS curve over recall ∈ [0.85, 0.95].
//!
//! The paper's reasoning, implemented literally: sweep `ef`, collect
//! (QPS, recall) points, keep the recall window where "most algorithms have
//! sufficient data points and performance differences are most meaningful",
//! integrate QPS over recall (trapezoid, with linear interpolation onto the
//! window boundaries), and hand the scalar to GRPO. Scores are normalized
//! by the baseline's AUC so rewards are dataset-scale-free, then smoothed
//! (log1p, following the stabilization in [18]) before Eq. 2.

use crate::anns::glass::GlassIndex;
use crate::anns::VectorSet;
use crate::dataset::Dataset;
use crate::eval::sweep::{measure_point, CurvePoint};
use crate::variants::{Module, VariantConfig};

/// Reward window + sweep settings.
#[derive(Clone, Debug)]
pub struct RewardSpec {
    pub recall_lo: f64,
    pub recall_hi: f64,
    pub k: usize,
    pub ef_grid: Vec<usize>,
    /// Build seed (fixed: determinism requirement).
    pub seed: u64,
}

impl RewardSpec {
    /// The paper's §3.3 recall window `[0.85, 0.95]` — the single source
    /// for every component that reasons about "the window" (trainer,
    /// tuner, docs, CLI defaults).
    pub const DEFAULT_WINDOW: (f64, f64) = (0.85, 0.95);

    /// [`RewardSpec::DEFAULT_WINDOW`] as `(recall_lo, recall_hi)`.
    pub fn default_window() -> (f64, f64) {
        Self::DEFAULT_WINDOW
    }
}

impl Default for RewardSpec {
    fn default() -> Self {
        let (recall_lo, recall_hi) = RewardSpec::DEFAULT_WINDOW;
        RewardSpec {
            recall_lo,
            recall_hi,
            k: 10,
            ef_grid: vec![12, 16, 24, 32, 48, 64, 96, 128],
            seed: 7,
        }
    }
}

/// Area under the QPS-over-recall curve restricted to `[lo, hi]`.
///
/// Points are sorted by recall; boundary values are linearly interpolated
/// so two curves are integrated over the *same* interval. Returns 0 when
/// the curve never enters the window (the paper's "score of 0" failure
/// mode maps here too).
pub fn window_auc(points: &[CurvePoint], lo: f64, hi: f64) -> f64 {
    let mut pts: Vec<(f64, f64)> = points.iter().map(|p| (p.recall, p.qps)).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    pts.dedup_by(|a, b| {
        if a.0 == b.0 {
            // Keep the faster point at equal recall.
            b.1 = b.1.max(a.1);
            true
        } else {
            false
        }
    });
    if pts.is_empty() {
        return 0.0;
    }
    // Interpolated QPS at a recall value (None outside the span).
    let interp = |r: f64| -> Option<f64> {
        if r < pts[0].0 || r > pts[pts.len() - 1].0 {
            return None;
        }
        for w in pts.windows(2) {
            let (r0, q0) = w[0];
            let (r1, q1) = w[1];
            if r >= r0 && r <= r1 {
                if r1 == r0 {
                    return Some(q0.max(q1));
                }
                let t = (r - r0) / (r1 - r0);
                return Some(q0 + t * (q1 - q0));
            }
        }
        Some(pts[pts.len() - 1].1)
    };
    // Clip the window to the measured span.
    let span_lo = lo.max(pts[0].0);
    let span_hi = hi.min(pts[pts.len() - 1].0);
    if span_hi <= span_lo {
        // Curve entirely above the window still deserves credit at its
        // floor (it dominates the window); entirely below gets 0.
        if pts[0].0 > hi {
            return (hi - lo) * pts[0].1;
        }
        return 0.0;
    }
    // Integration knots: window bounds + interior measured points.
    let mut knots = vec![span_lo];
    knots.extend(
        pts.iter()
            .map(|p| p.0)
            .filter(|&r| r > span_lo && r < span_hi),
    );
    knots.push(span_hi);
    let mut auc = 0.0;
    for w in knots.windows(2) {
        let (r0, r1) = (w[0], w[1]);
        let (Some(q0), Some(q1)) = (interp(r0), interp(r1)) else {
            continue;
        };
        auc += (r1 - r0) * (q0 + q1) / 2.0;
    }
    auc
}

/// Sweep a GLASS candidate configuration and return its window AUC.
///
/// `prebuilt`: when optimizing search/refinement (§3.5), the graph from the
/// frozen construction knobs is reused and only runtime knobs change —
/// matching the paper's per-module evaluation granularity.
pub fn evaluate_config(
    ds: &Dataset,
    config: &VariantConfig,
    module: Module,
    prebuilt: Option<&mut GlassIndex>,
    spec: &RewardSpec,
) -> (f64, Vec<CurvePoint>) {
    let points = match (module, prebuilt) {
        (Module::Construction, _) | (_, None) => {
            let idx = GlassIndex::build(VectorSet::from_dataset(ds), config.clone(), spec.seed);
            sweep_points(&idx, ds, spec)
        }
        (_, Some(idx)) => {
            idx.set_runtime_knobs(config);
            sweep_points(idx, ds, spec)
        }
    };
    (window_auc(&points, spec.recall_lo, spec.recall_hi), points)
}

fn sweep_points(idx: &GlassIndex, ds: &Dataset, spec: &RewardSpec) -> Vec<CurvePoint> {
    spec.ef_grid
        .iter()
        .map(|&ef| measure_point(idx, ds, spec.k, ef))
        .collect()
}

/// Reward smoothing (§3.4 "rewards undergo smoothing following [18]"):
/// log1p of the baseline-normalized score — compresses the occasional
/// pathological-fast outlier that would otherwise dominate Eq. 2's std.
pub fn smooth(score_over_baseline: f64) -> f64 {
    score_over_baseline.max(0.0).ln_1p()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(recall: f64, qps: f64) -> CurvePoint {
        CurvePoint {
            ef: 0,
            recall,
            qps,
            mean_latency_s: 0.0,
            p99_latency_s: 0.0,
        }
    }

    #[test]
    fn auc_of_flat_curve() {
        // QPS constant 1000 across the window -> AUC = 0.1 * 1000.
        let c = vec![pt(0.5, 1000.0), pt(0.99, 1000.0)];
        let a = window_auc(&c, 0.85, 0.95);
        assert!((a - 100.0).abs() < 1e-6, "a={a}");
    }

    #[test]
    fn auc_orders_faster_curves_higher() {
        let slow = vec![pt(0.8, 2000.0), pt(0.9, 1000.0), pt(0.97, 300.0)];
        let fast = vec![pt(0.8, 4000.0), pt(0.9, 2000.0), pt(0.97, 600.0)];
        assert!(
            window_auc(&fast, 0.85, 0.95) > window_auc(&slow, 0.85, 0.95) * 1.5
        );
    }

    #[test]
    fn auc_zero_when_below_window() {
        let c = vec![pt(0.2, 9000.0), pt(0.5, 5000.0)];
        assert_eq!(window_auc(&c, 0.85, 0.95), 0.0);
    }

    #[test]
    fn auc_credits_curves_entirely_above_window() {
        // High-quality algorithms "cannot achieve low recall" (§3.3).
        let c = vec![pt(0.97, 3000.0), pt(0.999, 1000.0)];
        let a = window_auc(&c, 0.85, 0.95);
        assert!((a - 0.1 * 3000.0).abs() < 1e-6);
    }

    #[test]
    fn auc_partial_window_overlap() {
        let c = vec![pt(0.9, 1000.0), pt(0.99, 500.0)];
        let a = window_auc(&c, 0.85, 0.95);
        // Integrates only [0.9, 0.95].
        assert!(a > 0.0 && a < 0.1 * 1000.0);
    }

    #[test]
    fn smoothing_monotone_and_compressive() {
        assert!(smooth(2.0) > smooth(1.0));
        let gain_low = smooth(1.2) - smooth(1.0);
        let gain_high = smooth(5.2) - smooth(5.0);
        assert!(gain_low > gain_high);
        assert_eq!(smooth(-3.0), 0.0);
    }

    #[test]
    fn evaluate_config_runs_end_to_end() {
        let sp = crate::dataset::synth::spec("demo-64").unwrap();
        let mut ds = crate::dataset::synth::generate_counts(sp, 800, 30, 71);
        ds.compute_ground_truth(10);
        let spec = RewardSpec {
            ef_grid: vec![16, 32, 64, 128],
            ..Default::default()
        };
        let (auc, points) = evaluate_config(
            &ds,
            &VariantConfig::glass_baseline(),
            Module::Construction,
            None,
            &spec,
        );
        assert_eq!(points.len(), 4);
        assert!(auc >= 0.0);
        // The sweep should reach the window on this easy dataset.
        assert!(points.iter().any(|p| p.recall > 0.85), "{points:?}");
    }
}
