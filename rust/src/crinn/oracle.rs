//! The reward-oracle seam: one interface between "a candidate
//! configuration" and "its §3.3 speed reward".
//!
//! Both optimizers — the GRPO trainer and the Lagrangian-relaxation
//! baseline in [`crate::crinn::tune`] — consume a [`RewardOracle`], so
//! they compare on *exactly* the same measurement protocol. Two
//! implementations:
//!
//! * [`SweepOracle`] — the real thing: builds the index a
//!   [`TunedConfig`] describes (reusing a cached GLASS graph when only
//!   runtime knobs changed, the §3.5 granularity), sweeps the
//!   deterministic `ef` grid, integrates the recall-windowed QPS AUC;
//! * [`SyntheticOracle`] — a closed-form pseudo-benchmark (pure `f64`
//!   arithmetic, no clocks, no threads) used by determinism tests and
//!   `--oracle synthetic` smoke runs: two identical tune runs produce
//!   bit-identical artifacts because nothing in the loop measures time.

use crate::anns::glass::GlassIndex;
use crate::anns::VectorSet;
use crate::crinn::reward::{window_auc, RewardSpec};
use crate::dataset::Dataset;
use crate::eval::sweep::{measure_point, measure_point_tuned, CurvePoint};
use crate::variants::{build_index, IndexFamily, TunedConfig};

/// What one oracle evaluation returns: the recall-windowed QPS AUC plus
/// the full measured curve (the tuner derives the serving `ef` from it).
#[derive(Clone, Debug)]
pub struct OracleReport {
    /// Window AUC per the oracle's [`RewardSpec`].
    pub auc: f64,
    /// One point per grid `ef`, in grid order.
    pub points: Vec<CurvePoint>,
}

impl OracleReport {
    /// Highest recall the curve reaches (0 for an empty curve).
    pub fn best_recall(&self) -> f64 {
        self.points.iter().map(|p| p.recall).fold(0.0, f64::max)
    }

    /// Smallest grid `ef` whose measured recall meets `floor` — the
    /// operating point a tuned artifact pins for serving. `None` when the
    /// whole curve is under the floor.
    pub fn operating_ef(&self, floor: f64) -> Option<usize> {
        self.points
            .iter()
            .filter(|p| p.recall >= floor)
            .map(|p| p.ef)
            .min()
    }
}

/// Maps a candidate [`TunedConfig`] to its speed reward. Implementations
/// must be deterministic in everything except wall-clock timing — same
/// config, same recall curve, bit for bit.
pub trait RewardOracle {
    /// The sweep settings (window, `k`, `ef` grid, build seed).
    fn spec(&self) -> &RewardSpec;
    /// Short name for logs and artifact provenance.
    fn name(&self) -> &str;
    /// Build/evaluate `cfg` and return its reward report.
    fn evaluate(&mut self, cfg: &TunedConfig) -> OracleReport;
}

/// The real oracle: index builds + timed sweeps on a held dataset.
pub struct SweepOracle {
    ds: Dataset,
    spec: RewardSpec,
    /// `false` (trainer compat): per-query protocol under the ambient
    /// `CRINN_BATCH`/`CRINN_THREADS` — byte-compatible with what
    /// `crinn train` always measured. `true` (tune pipeline): measure
    /// with the **candidate's** serving knobs (batch size, threads), so
    /// those dimensions get a reward gradient.
    measure_serving: bool,
    /// Evaluations performed (for provenance + test assertions).
    pub evals: usize,
    /// §3.5 prebuilt-graph reuse: the last GLASS build, keyed by its
    /// construction knobs. Candidates that only move runtime knobs swap
    /// them in via `set_runtime_knobs` instead of rebuilding.
    cache: Option<(crate::variants::ConstructionKnobs, GlassIndex)>,
}

impl SweepOracle {
    /// `ds` must carry ground truth (asserted).
    pub fn new(ds: Dataset, spec: RewardSpec) -> Self {
        assert!(!ds.gt.is_empty(), "oracle dataset needs ground truth");
        SweepOracle {
            ds,
            spec,
            measure_serving: false,
            evals: 0,
            cache: None,
        }
    }

    /// Switch to the tune-pipeline protocol: score each candidate under
    /// its own serving knobs (batch, threads) instead of the ambient env.
    pub fn with_serving_measurement(mut self) -> Self {
        self.measure_serving = true;
        self
    }

    /// The dataset this oracle measures on.
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    fn sweep(&self, index: &dyn crate::anns::AnnIndex, cfg: &TunedConfig) -> Vec<CurvePoint> {
        self.spec
            .ef_grid
            .iter()
            .map(|&ef| {
                if self.measure_serving {
                    let threads = match cfg.serving.threads {
                        0 => None, // auto: ambient CRINN_THREADS
                        t => Some(t),
                    };
                    measure_point_tuned(
                        index,
                        &self.ds,
                        self.spec.k,
                        ef,
                        Some(cfg.serving.batch.max(1)),
                        threads,
                    )
                } else {
                    measure_point(index, &self.ds, self.spec.k, ef)
                }
            })
            .collect()
    }
}

impl RewardOracle for SweepOracle {
    fn spec(&self) -> &RewardSpec {
        &self.spec
    }

    fn name(&self) -> &str {
        "sweep"
    }

    fn evaluate(&mut self, cfg: &TunedConfig) -> OracleReport {
        self.evals += 1;
        let points = if cfg.family == IndexFamily::Glass {
            // Taken out of `self` so the cached index can be borrowed
            // mutably while `sweep` borrows the rest of the oracle.
            let mut cache = self.cache.take();
            let hit = matches!(&cache, Some((knobs, _)) if *knobs == cfg.variant.construction);
            if !hit {
                let idx = GlassIndex::build(
                    VectorSet::from_dataset(&self.ds),
                    cfg.variant.clone(),
                    self.spec.seed,
                );
                cache = Some((cfg.variant.construction.clone(), idx));
            }
            let (_, idx) = cache.as_mut().expect("cache just filled");
            idx.set_runtime_knobs(&cfg.variant);
            let points = self.sweep(&*idx, cfg);
            self.cache = cache;
            points
        } else {
            // Non-GLASS families have no runtime-knob swap; rebuild. Their
            // tuning spaces are small enough that this stays cheap.
            let idx = build_index(cfg, VectorSet::from_dataset(&self.ds), self.spec.seed);
            self.sweep(idx.as_ref(), cfg)
        };
        OracleReport {
            auc: window_auc(&points, self.spec.recall_lo, self.spec.recall_hi),
            points,
        }
    }
}

/// A clock-free pseudo-benchmark: recall and QPS are closed-form
/// functions of the knobs, shaped like a real curve (recall saturates in
/// `ef`, QPS decays in `ef`, quality knobs trade speed for recall). Used
/// where bit-for-bit reproducibility matters more than realism.
pub struct SyntheticOracle {
    spec: RewardSpec,
    /// Evaluations performed.
    pub evals: usize,
}

impl SyntheticOracle {
    pub fn new(spec: RewardSpec) -> Self {
        SyntheticOracle { spec, evals: 0 }
    }
}

impl RewardOracle for SyntheticOracle {
    fn spec(&self) -> &RewardSpec {
        &self.spec
    }

    fn name(&self) -> &str {
        "synthetic"
    }

    fn evaluate(&mut self, cfg: &TunedConfig) -> OracleReport {
        self.evals += 1;
        // Graph quality: how fast recall saturates in `ef`. Work: per-query
        // cost multiplier. Both depend on family-appropriate knobs so the
        // search has a real (if artificial) landscape to climb.
        let (quality, work) = match cfg.family {
            IndexFamily::Ivf => {
                let nlist = cfg.ivf.nlist.max(8) as f64;
                ((nlist.ln() / 8.0).min(1.2), 1.0 + nlist / 1024.0)
            }
            _ => {
                let m = cfg.variant.construction.m as f64;
                let entries = cfg.variant.construction.num_entry_points as f64;
                ((m / 32.0 + entries / 18.0).min(1.5), 1.0 + m / 64.0)
            }
        };
        let mut speed = 1.0;
        if cfg.variant.refine.quantized_primary {
            speed *= 1.3;
        }
        if cfg.variant.search.edge_batch {
            speed *= 1.1;
        }
        if cfg.family == IndexFamily::Ivf && cfg.ivf.quantized_scan {
            speed *= 1.25;
        }
        speed *= 1.0 + (cfg.serving.batch.max(1) as f64).ln() / 10.0;
        speed *= (cfg.serving.threads.max(1) as f64).sqrt();
        let points: Vec<CurvePoint> = self
            .spec
            .ef_grid
            .iter()
            .map(|&ef| {
                let e = ef as f64;
                let recall = 1.0 - (-e * quality / 32.0).exp();
                let qps = 1e5 * speed / (work * (e + 16.0));
                CurvePoint {
                    ef,
                    recall,
                    qps,
                    mean_latency_s: 1.0 / qps,
                    p99_latency_s: 1.0 / qps,
                }
            })
            .collect();
        OracleReport {
            auc: window_auc(&points, self.spec.recall_lo, self.spec.recall_hi),
            points,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    fn tiny_ds() -> Dataset {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 500, 20, 77);
        ds.compute_ground_truth(10);
        ds
    }

    fn small_spec() -> RewardSpec {
        RewardSpec {
            ef_grid: vec![16, 32, 64, 128],
            ..Default::default()
        }
    }

    #[test]
    fn report_helpers() {
        let mk = |ef, recall| CurvePoint {
            ef,
            recall,
            qps: 100.0,
            mean_latency_s: 0.01,
            p99_latency_s: 0.01,
        };
        let rep = OracleReport {
            auc: 1.0,
            points: vec![mk(16, 0.6), mk(32, 0.88), mk(64, 0.97)],
        };
        assert_eq!(rep.best_recall(), 0.97);
        assert_eq!(rep.operating_ef(0.85), Some(32));
        assert_eq!(rep.operating_ef(0.9), Some(64));
        assert_eq!(rep.operating_ef(0.999), None);
    }

    #[test]
    fn synthetic_oracle_is_bitwise_deterministic_and_knob_sensitive() {
        let mut o = SyntheticOracle::new(small_spec());
        let base = TunedConfig::default();
        let a = o.evaluate(&base);
        let b = o.evaluate(&base);
        assert_eq!(a.auc.to_bits(), b.auc.to_bits());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.recall.to_bits(), pb.recall.to_bits());
            assert_eq!(pa.qps.to_bits(), pb.qps.to_bits());
        }
        assert_eq!(o.evals, 2);
        // More entry points → recall saturates faster.
        let mut rich = base.clone();
        rich.variant.construction.num_entry_points = 9;
        assert!(o.evaluate(&rich).best_recall() > a.best_recall());
        // Bigger batch → faster curve.
        let mut batched = base.clone();
        batched.serving.batch = 128;
        assert!(o.evaluate(&batched).points[0].qps > a.points[0].qps);
    }

    #[test]
    fn sweep_oracle_reuses_glass_graph_across_runtime_knob_changes() {
        let mut o = SweepOracle::new(tiny_ds(), small_spec());
        let base = TunedConfig::default();
        let r1 = o.evaluate(&base);
        assert_eq!(r1.points.len(), 4);
        assert!(r1.best_recall() > 0.5, "{:?}", r1.points);
        // Runtime-only change: cache must survive (same construction knobs).
        let mut runtime = base.clone();
        runtime.variant.search.entry_tiers = 2;
        o.evaluate(&runtime);
        let cached = o.cache.as_ref().expect("cache populated");
        assert_eq!(cached.0, base.variant.construction);
        // Construction change: cache key must follow.
        let mut rebuilt = base.clone();
        rebuilt.variant.construction.m = 12;
        o.evaluate(&rebuilt);
        assert_eq!(
            o.cache.as_ref().unwrap().0.m,
            12,
            "construction change must rebuild the cached graph"
        );
        assert_eq!(o.evals, 3);
    }

    #[test]
    fn sweep_oracle_handles_non_glass_families() {
        let mut o = SweepOracle::new(tiny_ds(), small_spec()).with_serving_measurement();
        for algo in ["hnsw", "vearch-ivf"] {
            let cfg = TunedConfig::from_algo_name(algo).unwrap();
            let rep = o.evaluate(&cfg);
            assert_eq!(rep.points.len(), 4, "{algo}");
            assert!(rep.best_recall() > 0.5, "{algo}: {:?}", rep.points);
        }
    }
}
