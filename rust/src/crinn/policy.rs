//! Policy feature encoding + Gaussian sampling.
//!
//! The contrastive prompt's information content — which module is being
//! optimized, the exemplar implementations and their speed scores, and how
//! far training has progressed — is encoded as the policy network's input
//! features (the substitution for tokenized prompt text; DESIGN.md §2).
//! Layout (must match `python/compile/model.py::FEAT_DIM`):
//!
//! ```text
//! [ module one-hot (3) |
//!   exemplar 0: knobs (8) + normalized score (1) | ... x N_EXEMPLARS |
//!   progress (1) ]
//! ```
//!
//! Actions are draws from the diagonal Gaussian `(mean, logstd)` returned
//! by the AOT `policy_fwd` artifact, clamped to the knob box `[-1, 1]`.

use crate::crinn::database::Exemplar;
use crate::runtime::Manifest;
use crate::util::rng::Rng;
use crate::variants::{encode_action, Module, N_KNOBS};

/// Encode one prompt's features (identical across the G group rows —
/// GRPO's G completions share the prompt q).
pub fn encode_features(
    manifest: &Manifest,
    module: Module,
    exemplars: &[&Exemplar],
    progress: f64,
) -> Vec<f32> {
    let f = manifest.feat_dim;
    let mut row = vec![0f32; f];
    row[module.index()] = 1.0;
    let mut off = manifest.n_modules;
    for slot in 0..manifest.n_exemplars {
        if let Some(e) = exemplars.get(slot) {
            let knobs = encode_action(&e.config, module);
            for (j, &v) in knobs.iter().take(N_KNOBS).enumerate() {
                row[off + j] = v as f32;
            }
            // Score feature: log-scale around the baseline (score 1.0 -> 0).
            row[off + N_KNOBS] = (e.score.max(1e-3).ln()) as f32;
        }
        off += N_KNOBS + 1;
    }
    row[f - 1] = progress.clamp(0.0, 1.0) as f32;
    // Tile to [G, F].
    let mut out = Vec::with_capacity(manifest.group * f);
    for _ in 0..manifest.group {
        out.extend_from_slice(&row);
    }
    out
}

/// A sampled group of actions with their log-probs under the sampling
/// policy (needed as `old_logp` in Eq. 3).
pub struct ActionGroup {
    /// `[G, A]` actions, clamped to [-1, 1].
    pub actions: Vec<f32>,
    /// `[G]` log-probs (of the *pre-clamp* draws — standard practice).
    pub logp: Vec<f32>,
}

/// Sample G actions from the Gaussian `(mean, logstd)` (both `[G, A]`).
pub fn sample_actions(
    mean: &[f32],
    logstd: &[f32],
    group: usize,
    n_knobs: usize,
    rng: &mut Rng,
) -> ActionGroup {
    assert_eq!(mean.len(), group * n_knobs);
    let mut actions = vec![0f32; group * n_knobs];
    let mut logp = vec![0f32; group];
    let ln2pi = (2.0 * std::f32::consts::PI).ln();
    for g in 0..group {
        let mut lp = 0f32;
        for a in 0..n_knobs {
            let i = g * n_knobs + a;
            let std = logstd[i].exp();
            let z = rng.next_gaussian_f32();
            let x = mean[i] + std * z;
            lp += -0.5 * (z * z + 2.0 * logstd[i] + ln2pi);
            actions[i] = x.clamp(-1.0, 1.0);
        }
        logp[g] = lp;
    }
    ActionGroup { actions, logp }
}

/// Eq. 2: group-normalized advantages, with reward smoothing applied by
/// the caller. Degenerate groups (zero std) get all-zero advantages.
pub fn normalize_advantages(rewards: &[f64]) -> Vec<f32> {
    let n = rewards.len() as f64;
    let mean = rewards.iter().sum::<f64>() / n;
    let var = rewards.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n;
    let std = var.sqrt();
    if std < 1e-9 {
        return vec![0.0; rewards.len()];
    }
    rewards
        .iter()
        .map(|r| (((r - mean) / std) as f32).clamp(-5.0, 5.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::variants::VariantConfig;

    fn manifest() -> Option<Manifest> {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(&dir).unwrap())
    }

    #[test]
    fn features_layout() {
        let Some(m) = manifest() else { return };
        let e = Exemplar {
            config: VariantConfig::crinn_full(),
            module: Module::Search,
            score: 1.5,
            iteration: 0,
        };
        let feats = encode_features(&m, Module::Search, &[&e], 0.25);
        assert_eq!(feats.len(), m.group * m.feat_dim);
        // Module one-hot.
        assert_eq!(feats[0], 0.0);
        assert_eq!(feats[1], 1.0);
        assert_eq!(feats[2], 0.0);
        // Score feature is ln(1.5) in the first exemplar slot.
        let score_idx = m.n_modules + N_KNOBS;
        assert!((feats[score_idx] - 1.5f32.ln()).abs() < 1e-6);
        // Progress in the last slot; rows tiled identically.
        assert_eq!(feats[m.feat_dim - 1], 0.25);
        assert_eq!(feats[..m.feat_dim], feats[m.feat_dim..2 * m.feat_dim]);
    }

    #[test]
    fn empty_exemplars_zero_slots() {
        let Some(m) = manifest() else { return };
        let feats = encode_features(&m, Module::Construction, &[], 0.0);
        // Everything except the module one-hot is zero.
        let nonzero = feats[..m.feat_dim].iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, 1);
    }

    #[test]
    fn sampling_statistics() {
        let g = 8;
        let a = 8;
        let mean = vec![0.25f32; g * a];
        let logstd = vec![-1.0f32; g * a];
        let mut rng = Rng::new(11);
        let mut acc = 0.0;
        let trials = 200;
        for _ in 0..trials {
            let grp = sample_actions(&mean, &logstd, g, a, &mut rng);
            acc += grp.actions.iter().map(|&x| x as f64).sum::<f64>() / (g * a) as f64;
            assert!(grp.actions.iter().all(|x| x.abs() <= 1.0));
            assert!(grp.logp.iter().all(|l| l.is_finite()));
        }
        let emp_mean = acc / trials as f64;
        assert!((emp_mean - 0.25).abs() < 0.05, "empirical mean {emp_mean}");
    }

    #[test]
    fn advantages_zero_mean_unit_std() {
        let adv = normalize_advantages(&[1.0, 2.0, 3.0, 4.0]);
        let mean: f32 = adv.iter().sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!(adv[3] > adv[0]);
        let degenerate = normalize_advantages(&[2.0, 2.0, 2.0]);
        assert!(degenerate.iter().all(|&x| x == 0.0));
    }
}
