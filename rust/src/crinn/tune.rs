//! `crinn tune`: self-optimization without the RL policy.
//!
//! A Lagrangian-relaxation derivative-free search (after the constrained
//! auto-configuration literature): maximize the §3.3 recall-windowed QPS
//! AUC subject to "measured recall@k ≥ floor", relaxing the constraint
//! into the objective with a multiplier λ that grows (dual ascent)
//! whenever a candidate lands infeasible. The search runs in the same
//! `[-1, 1]` action coordinates the GRPO policy emits — both optimizers
//! move through [`TuningSpace`] and score through the same
//! [`RewardOracle`], so `--method lagrange` vs `--method grpo` is an
//! apples-to-apples comparison.
//!
//! The pipeline (see `cmd_tune` in `main.rs`): split queries into
//! train/held-out halves, search on the train half, then [`finalize`] on
//! the held-out half — pick the smallest grid `ef` meeting the recall
//! floor, re-measure there, and emit a checksummed
//! [`TunedArtifact`](crate::variants::TunedArtifact) only if the
//! held-out recall clears the floor.

use crate::crinn::oracle::RewardOracle;
use crate::dataset::Dataset;
use crate::eval::sweep::CurvePoint;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::variants::{TunedArtifact, TunedConfig, TuningSpace};

/// Search settings.
#[derive(Clone, Debug)]
pub struct TuneOptions {
    /// Total oracle evaluations (including the baseline at eval 0).
    pub evals: usize,
    /// Seeds the candidate sampler (and is recorded in the artifact).
    pub seed: u64,
    /// Constraint: measured recall@k must reach this on held-out queries.
    pub recall_floor: f64,
    pub verbose: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions {
            evals: 32,
            seed: 17,
            recall_floor: 0.9,
            verbose: true,
        }
    }
}

/// One search-step record, for logs and EXPERIMENTS.md curves.
#[derive(Clone, Debug)]
pub struct TuneRecord {
    pub eval: usize,
    pub auc: f64,
    pub recall: f64,
    pub feasible: bool,
    /// Relaxed objective at evaluation time (λ moves during the run).
    pub score: f64,
}

/// Search outcome (pre-finalize: serving `ef` not yet pinned).
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: TunedConfig,
    /// Train-split window AUC of `best`.
    pub best_auc: f64,
    /// Best recall `best`'s train-split curve reaches.
    pub best_recall: f64,
    /// `best`'s full train-split curve.
    pub best_points: Vec<CurvePoint>,
    /// Oracle evaluations actually spent.
    pub evals: usize,
    pub history: Vec<TuneRecord>,
}

struct Incumbent {
    action: Vec<f64>,
    cfg: TunedConfig,
    auc: f64,
    recall: f64,
    points: Vec<CurvePoint>,
    feasible: bool,
    score: f64,
}

/// Run the Lagrangian-relaxation search: half the budget on uniform
/// random exploration, the rest on coordinate descent around the
/// incumbent with a shrinking step. Deterministic per
/// `(space, oracle, opts.seed)` — everything random flows from one
/// [`Rng`].
pub fn tune_lagrange(
    space: &TuningSpace,
    oracle: &mut dyn RewardOracle,
    opts: &TuneOptions,
) -> Result<TuneResult> {
    let mut rng = Rng::new(opts.seed);
    let dims = space.dims();
    let floor = opts.recall_floor;

    // Eval 0: the family preset, grid-snapped through encode∘decode so the
    // incumbent starts on the same lattice the search moves on. Its AUC
    // normalizes every later score (scale-free, like the trainer).
    let a0 = space.encode(&TunedConfig::for_family(space.family()));
    let c0 = space.decode(&a0);
    let rep0 = oracle.evaluate(&c0);
    let baseline = if rep0.auc > 0.0 { rep0.auc } else { 1.0 };

    let mut lambda = 1.0f64;
    let relaxed = |auc: f64, recall: f64, lambda: f64| -> f64 {
        let gap = (floor - recall).max(0.0);
        auc / baseline - lambda * gap * 10.0
    };

    let r0 = rep0.best_recall();
    let f0 = r0 >= floor;
    let s0 = relaxed(rep0.auc, r0, lambda);
    let mut best = Incumbent {
        action: a0,
        cfg: c0,
        auc: rep0.auc,
        recall: r0,
        points: rep0.points,
        feasible: f0,
        score: s0,
    };
    let mut history = vec![TuneRecord {
        eval: 0,
        auc: rep0.auc,
        recall: r0,
        feasible: f0,
        score: s0,
    }];

    let budget = opts.evals.max(1);
    let explore = budget / 2;
    let mut step = 0.5f64;
    let mut dim_cursor = 0usize;
    let mut evals_done = 1usize;

    while evals_done < budget {
        let action: Vec<f64> = if evals_done <= explore {
            (0..dims).map(|_| rng.range_f64(-1.0, 1.0)).collect()
        } else {
            // Coordinate descent: perturb one dimension of the incumbent,
            // random sign, step shrinking ×0.7 after each full dim sweep.
            let mut a = best.action.clone();
            let d = dim_cursor % dims;
            dim_cursor += 1;
            if dim_cursor % dims == 0 {
                step *= 0.7;
            }
            let dir = if rng.next_f64() < 0.5 { -1.0 } else { 1.0 };
            a[d] = (a[d] + dir * step).clamp(-1.0, 1.0);
            a
        };
        let cfg = space.decode(&action);
        let rep = oracle.evaluate(&cfg);
        let recall = rep.best_recall();
        let feasible = recall >= floor;
        if !feasible {
            // Dual ascent: infeasible iterates make the constraint dearer.
            lambda = (lambda * 1.5).min(64.0);
        }
        let score = relaxed(rep.auc, recall, lambda);
        history.push(TuneRecord {
            eval: evals_done,
            auc: rep.auc,
            recall,
            feasible,
            score,
        });
        // Feasible beats infeasible; among feasible, raw AUC decides;
        // among infeasible, the relaxed score decides.
        let better = match (feasible, best.feasible) {
            (true, true) => rep.auc > best.auc,
            (true, false) => true,
            (false, true) => false,
            (false, false) => score > best.score,
        };
        if better {
            best = Incumbent {
                action,
                cfg,
                auc: rep.auc,
                recall,
                points: rep.points,
                feasible,
                score,
            };
        }
        if opts.verbose {
            let rec = history.last().expect("just pushed");
            eprintln!(
                "[tune] eval {:>3}  auc/base {:.3}  recall {:.3}{}  incumbent {:.3}",
                rec.eval,
                rec.auc / baseline,
                rec.recall,
                if rec.feasible { "" } else { " (infeasible)" },
                best.auc / baseline,
            );
        }
        evals_done += 1;
    }

    Ok(TuneResult {
        best: best.cfg,
        best_auc: best.auc,
        best_recall: best.recall,
        best_points: best.points,
        evals: evals_done,
        history,
    })
}

/// Split a dataset's queries into interleaved train/held-out halves
/// (even indexes train, odd held out). Base vectors are shared — the
/// index under test is identical; only the measurement queries differ.
pub fn split_queries(ds: &Dataset) -> (Dataset, Dataset) {
    assert!(ds.n_queries() >= 2, "need at least 2 queries to split");
    assert!(!ds.gt.is_empty(), "split needs ground truth");
    let pick = |parity: usize, suffix: &str| -> Dataset {
        let mut queries = Vec::new();
        let mut gt = Vec::new();
        for q in (parity..ds.n_queries()).step_by(2) {
            queries.extend_from_slice(ds.query_vec(q));
            gt.push(ds.gt[q].clone());
        }
        Dataset {
            name: format!("{}/{suffix}", ds.name),
            dim: ds.dim,
            metric: ds.metric,
            base: ds.base.clone(),
            queries,
            gt,
            gt_k: ds.gt_k,
        }
    };
    (pick(0, "train"), pick(1, "holdout"))
}

/// Pin the serving operating point on held-out data and build the
/// artifact. Picks the smallest grid `ef` whose held-out recall meets
/// the floor, stores that measurement, and refuses (with a loud error,
/// not a panic) when the winning configuration cannot clear the floor on
/// queries it never tuned against.
pub fn finalize(
    result: &TuneResult,
    holdout: &mut dyn RewardOracle,
    opts: &TuneOptions,
    method: &str,
    dataset_name: &str,
) -> Result<TunedArtifact> {
    let mut cfg = result.best.clone();
    cfg.serving.k = holdout.spec().k;
    let rep = holdout.evaluate(&cfg);
    let Some(ef) = rep.operating_ef(opts.recall_floor) else {
        crate::bail!(
            "tuned configuration reaches recall {:.3} on held-out queries, below the {:.2} floor \
             ({}); lower --floor or raise --evals",
            rep.best_recall(),
            opts.recall_floor,
            result.best.describe(),
        );
    };
    cfg.serving.ef = ef;
    let measured = rep
        .points
        .iter()
        .find(|p| p.ef == ef)
        .map(|p| p.recall)
        .unwrap_or(0.0);
    crate::ensure!(
        measured >= opts.recall_floor,
        "held-out recall {measured:.3} at ef {ef} fell under the {:.2} floor",
        opts.recall_floor
    );
    Ok(TunedArtifact {
        config: cfg,
        dataset: dataset_name.to_string(),
        method: method.to_string(),
        seed: opts.seed,
        evals: result.evals as u32,
        recall_floor: opts.recall_floor,
        measured_recall: measured,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crinn::oracle::SyntheticOracle;
    use crate::crinn::reward::RewardSpec;
    use crate::dataset::synth;
    use crate::variants::IndexFamily;

    fn spec() -> RewardSpec {
        RewardSpec {
            ef_grid: vec![16, 32, 64, 128],
            ..Default::default()
        }
    }

    fn opts(evals: usize, floor: f64) -> TuneOptions {
        TuneOptions {
            evals,
            seed: 23,
            recall_floor: floor,
            verbose: false,
        }
    }

    #[test]
    fn lagrange_improves_on_the_synthetic_baseline() {
        let space = TuningSpace::for_family(IndexFamily::Glass).unwrap();
        let mut oracle = SyntheticOracle::new(spec());
        let res = tune_lagrange(&space, &mut oracle, &opts(24, 0.5)).unwrap();
        assert_eq!(res.evals, 24);
        assert_eq!(res.history.len(), 24);
        assert_eq!(oracle.evals, 24);
        let baseline_auc = res.history[0].auc;
        assert!(
            res.best_auc >= baseline_auc,
            "search must keep at least the baseline: {} vs {baseline_auc}",
            res.best_auc
        );
        assert!(res.best_recall >= 0.5);
        // The search actually moved: later evals saw different configs.
        assert!(
            res.history[1..].iter().any(|r| r.auc != baseline_auc),
            "exploration never left the baseline"
        );
    }

    #[test]
    fn lagrange_is_deterministic_per_seed() {
        let space = TuningSpace::for_family(IndexFamily::Ivf).unwrap();
        let run = || {
            let mut oracle = SyntheticOracle::new(spec());
            tune_lagrange(&space, &mut oracle, &opts(16, 0.5)).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_auc.to_bits(), b.best_auc.to_bits());
        for (ra, rb) in a.history.iter().zip(&b.history) {
            assert_eq!(ra.auc.to_bits(), rb.auc.to_bits());
            assert_eq!(ra.score.to_bits(), rb.score.to_bits());
        }
    }

    #[test]
    fn split_queries_partitions_evenly_and_shares_base() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 21, 91);
        ds.compute_ground_truth(10);
        let (train, hold) = split_queries(&ds);
        assert_eq!(train.n_queries(), 11);
        assert_eq!(hold.n_queries(), 10);
        assert_eq!(train.base, ds.base);
        assert_eq!(hold.base, ds.base);
        assert_eq!(train.query_vec(0), ds.query_vec(0));
        assert_eq!(hold.query_vec(0), ds.query_vec(1));
        assert_eq!(train.gt[1], ds.gt[2]);
        assert_eq!(hold.gt[1], ds.gt[3]);
        assert!(train.name.ends_with("/train"));
        assert!(hold.name.ends_with("/holdout"));
    }

    #[test]
    fn finalize_pins_ef_and_enforces_the_floor() {
        let space = TuningSpace::for_family(IndexFamily::Glass).unwrap();
        let mut oracle = SyntheticOracle::new(spec());
        let o = opts(12, 0.5);
        let res = tune_lagrange(&space, &mut oracle, &o).unwrap();
        let mut holdout = SyntheticOracle::new(spec());
        let art = finalize(&res, &mut holdout, &o, "lagrange", "demo-64").unwrap();
        assert!(art.measured_recall >= o.recall_floor);
        assert!(spec().ef_grid.contains(&art.config.serving.ef));
        assert_eq!(art.config.serving.k, 10);
        assert_eq!(art.method, "lagrange");
        assert_eq!(art.evals, 12);
        // An unreachable floor must fail loudly, not panic.
        let impossible = TuneOptions {
            recall_floor: 1.5,
            ..o
        };
        let err = finalize(&res, &mut holdout, &impossible, "lagrange", "demo-64")
            .unwrap_err();
        assert!(format!("{err:#}").contains("floor"), "{err:#}");
    }
}
