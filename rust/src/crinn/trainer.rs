//! The CRINN training loop (§3.1, §3.5): sequential module-by-module
//! contrastive RL over the GLASS starting point.
//!
//! Per module round (construction → search → refinement):
//! 1. sample contrastive exemplars from the performance-indexed database
//!    (Eq. 1), render the Table-1 prompt (logged), encode features;
//! 2. policy forward (AOT artifact) → sample G candidate configurations;
//! 3. **execute** each candidate on the training dataset — real index
//!    builds/searches — and score with the recall-window AUC (§3.3),
//!    normalized by the GLASS baseline's AUC;
//! 4. smooth rewards, normalize within the group (Eq. 2), GRPO-update the
//!    policy via the fused artifact (Eq. 3);
//! 5. insert successful candidates into the database; adopt the best
//!    configuration found before moving to the next module.

use crate::crinn::database::{CodeDatabase, Exemplar};
use crate::crinn::grpo::{GrpoHyper, GrpoOptimizer};
use crate::crinn::oracle::{RewardOracle, SweepOracle};
use crate::crinn::policy;
use crate::crinn::reward::{self, RewardSpec};
use crate::dataset::Dataset;
use crate::runtime::Engine;
use crate::util::error::Result;
use crate::util::rng::Rng;
use crate::variants::{decode_action, Module, TunedConfig, VariantConfig};

/// Trainer options.
#[derive(Clone, Debug)]
pub struct TrainerOptions {
    /// GRPO iterations per module.
    pub iters_per_module: usize,
    /// Exemplars per prompt (Table 1 shows 2; default 4 like [18]).
    pub n_exemplars: usize,
    /// Eq. 1 temperature.
    pub tau: f64,
    pub hyper: GrpoHyper,
    pub reward: RewardSpec,
    pub seed: u64,
    /// Write rendered prompts to this directory (`--dump-prompts`).
    pub dump_prompts: Option<std::path::PathBuf>,
    pub verbose: bool,
}

impl Default for TrainerOptions {
    fn default() -> Self {
        TrainerOptions {
            iters_per_module: 8,
            n_exemplars: 4,
            tau: 1.0,
            hyper: GrpoHyper::default(),
            reward: RewardSpec::default(),
            seed: 17,
            dump_prompts: None,
            verbose: true,
        }
    }
}

/// One training-step record (per candidate), for EXPERIMENTS.md curves.
#[derive(Clone, Debug)]
pub struct StepRecord {
    pub module: Module,
    pub iteration: usize,
    pub candidate: usize,
    pub score: f64,
    pub loss: f32,
}

/// Training outcome.
pub struct TrainResult {
    /// Best configuration after all three module rounds.
    pub best_config: VariantConfig,
    /// Baseline (GLASS) window AUC on the training set.
    pub baseline_auc: f64,
    /// Best score (baseline-normalized) per module, in §3.5 order.
    pub module_best: Vec<(Module, f64)>,
    pub history: Vec<StepRecord>,
}

/// The CRINN trainer.
pub struct CrinnTrainer<'e> {
    engine: &'e Engine,
    /// The reward seam (§3.3): every candidate is scored here. The GRPO
    /// trainer and the `crinn tune` baseline share this interface, so
    /// their rewards are measured by exactly the same protocol.
    oracle: Box<dyn RewardOracle>,
    /// Evaluation-target name for log lines (dataset name, or the
    /// oracle's name for injected oracles).
    target: String,
    opts: TrainerOptions,
    pub db: CodeDatabase,
}

impl<'e> CrinnTrainer<'e> {
    /// `ds` must carry ground truth (the oracle asserts). Wraps a
    /// [`SweepOracle`] in trainer-compat mode: per-query protocol under
    /// the ambient environment, the §3.5 prebuilt-graph reuse keyed on
    /// construction knobs — identical measurements to the pre-oracle
    /// trainer.
    pub fn new(engine: &'e Engine, ds: Dataset, opts: TrainerOptions) -> Self {
        let target = ds.name.clone();
        let oracle = Box::new(SweepOracle::new(ds, opts.reward.clone()));
        let mut t = Self::with_oracle(engine, oracle, opts);
        t.target = target;
        t
    }

    /// Train against an injected oracle (deterministic smoke runs use
    /// [`crate::crinn::SyntheticOracle`]).
    pub fn with_oracle(
        engine: &'e Engine,
        oracle: Box<dyn RewardOracle>,
        opts: TrainerOptions,
    ) -> Self {
        assert_eq!(
            engine.manifest.n_knobs,
            crate::variants::N_KNOBS,
            "artifact/action-space mismatch — re-run `make artifacts`"
        );
        let target = oracle.name().to_string();
        CrinnTrainer {
            engine,
            oracle,
            target,
            opts,
            db: CodeDatabase::new(),
        }
    }

    /// Run the full sequential optimization. Deterministic per seed.
    pub fn train(&mut self) -> Result<TrainResult> {
        let mut rng = Rng::new(self.opts.seed);
        let mut opt = GrpoOptimizer::new(self.engine, self.opts.hyper.clone());
        let m = self.engine.manifest.clone();

        // Baseline: the GLASS starting point (§3.5), score := 1.0.
        let baseline_auc = self
            .oracle
            .evaluate(&TunedConfig::from_variant(VariantConfig::glass_baseline()))
            .auc;
        crate::ensure!(
            baseline_auc > 0.0,
            "baseline never reaches the reward window on {}; enlarge ef grid",
            self.target
        );
        if self.opts.verbose {
            eprintln!(
                "[crinn] baseline AUC on {}: {baseline_auc:.1} (score 1.0)",
                self.target
            );
        }
        for module in Module::ALL {
            self.db.insert(Exemplar {
                config: VariantConfig::glass_baseline(),
                module,
                score: 1.0,
                iteration: 0,
            });
        }

        let mut best_config = VariantConfig::glass_baseline();
        let mut history = Vec::new();
        let mut module_best = Vec::new();
        let total_iters = self.opts.iters_per_module * Module::ALL.len();
        let mut global_iter = 0usize;

        for module in Module::ALL {
            // §3.5 granularity lives in the oracle now: search/refinement
            // candidates keep the best construction knobs, so the oracle's
            // construction-keyed graph cache reuses one build per module.
            let mut best_module_score = self
                .db
                .best(module)
                .map(|e| e.score)
                .unwrap_or(1.0);

            for iter in 0..self.opts.iters_per_module {
                global_iter += 1;
                let progress = global_iter as f64 / total_iters as f64;
                // --- contrastive prompt (Eq. 1 sampling + Table 1 render).
                let exemplars =
                    self.db
                        .sample(module, self.opts.n_exemplars, self.opts.tau, &mut rng);
                let prompt = crate::crinn::prompt::render(module, &exemplars);
                if let Some(dir) = &self.opts.dump_prompts {
                    std::fs::create_dir_all(dir).ok();
                    std::fs::write(
                        dir.join(format!("{}_iter{iter}.md", module.name())),
                        &prompt,
                    )
                    .ok();
                }
                let feats =
                    policy::encode_features(&m, module, &exemplars, progress);

                // --- G completions from the current policy.
                let (mean, logstd) = opt.forward(&feats)?;
                let grp =
                    policy::sample_actions(&mean, &logstd, m.group, m.n_knobs, &mut rng);

                // --- execute & score each candidate (the speed reward).
                let mut rewards = Vec::with_capacity(m.group);
                for g in 0..m.group {
                    let action: Vec<f64> = (0..m.n_knobs)
                        .map(|a| grp.actions[g * m.n_knobs + a] as f64)
                        .collect();
                    let cfg = decode_action(&best_config, module, &action);
                    let auc = self
                        .oracle
                        .evaluate(&TunedConfig::from_variant(cfg.clone()))
                        .auc;
                    let score = auc / baseline_auc;
                    rewards.push(reward::smooth(score));
                    self.db.insert(Exemplar {
                        config: cfg.clone(),
                        module,
                        score,
                        iteration: global_iter,
                    });
                    if score > best_module_score {
                        best_module_score = score;
                        best_config = cfg;
                    }
                    history.push(StepRecord {
                        module,
                        iteration: iter,
                        candidate: g,
                        score,
                        loss: f32::NAN,
                    });
                }

                // --- Eq. 2 + Eq. 3.
                let adv = policy::normalize_advantages(&rewards);
                let loss = opt.step(&feats, &grp.actions, &adv, &grp.logp)?;
                for rec in history.iter_mut().rev().take(m.group) {
                    rec.loss = loss;
                }
                if self.opts.verbose {
                    let best_in_group = rewards.iter().cloned().fold(f64::MIN, f64::max);
                    eprintln!(
                        "[crinn] {:<18} iter {:>2}  best-in-group {:.3}  module-best {:.3}  loss {:+.4}",
                        module.name(),
                        iter,
                        best_in_group.exp() - 1.0, // undo log1p for display
                        best_module_score,
                        loss
                    );
                }
            }
            module_best.push((module, best_module_score));
            opt.refresh_reference();
        }

        Ok(TrainResult {
            best_config,
            baseline_auc,
            module_best,
            history,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    /// Full (tiny) training run: exercises prompt/DB/policy/GRPO/reward
    /// end-to-end through the real PJRT artifacts. Kept small — the e2e
    /// example and `crinn train` run the real thing.
    #[test]
    fn tiny_training_run_improves_or_holds() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let engine = match Engine::new(&dir) {
            Ok(e) => e,
            Err(e) if format!("{e:#}").contains("offline stub") => {
                eprintln!("skipping: PJRT backend is the offline stub");
                return;
            }
            Err(e) => panic!("engine failed with artifacts present: {e:#}"),
        };
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 900, 40, 81);
        ds.compute_ground_truth(10);
        let opts = TrainerOptions {
            iters_per_module: 1,
            reward: RewardSpec {
                ef_grid: vec![16, 32, 64, 96],
                ..Default::default()
            },
            verbose: false,
            ..Default::default()
        };
        let mut trainer = CrinnTrainer::new(&engine, ds, opts);
        let res = trainer.train().unwrap();
        assert!(res.baseline_auc > 0.0);
        assert_eq!(res.module_best.len(), 3);
        // Every module's best is at least the baseline (we keep the best).
        for (m, s) in &res.module_best {
            assert!(*s >= 1.0 - 1e-9, "{m:?} best {s}");
        }
        assert!(!res.history.is_empty());
        assert!(trainer.db.len() > 3);
    }
}
