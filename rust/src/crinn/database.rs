//! §3.2 performance-indexed exemplar database + Eq. 1 contrastive sampling.
//!
//! "We maintain a performance-indexed database of all successful code
//! samples" and draw exemplars with the temperature-scaled softmax
//!
//! `P(B_i) = exp((s_i - μ)/τ) / Σ_j exp((s_j - μ)/τ)`           (Eq. 1)
//!
//! τ governs exploration↔exploitation: low τ shows the LLM/policy only the
//! best implementations, high τ keeps diverse (including slow) exemplars in
//! the prompt for contrast.

use crate::util::rng::Rng;
use crate::variants::{Module, VariantConfig};

/// One stored implementation with its measured speed score.
#[derive(Clone, Debug)]
pub struct Exemplar {
    pub config: VariantConfig,
    pub module: Module,
    /// Baseline-normalized speed score (1.0 = GLASS starting point).
    pub score: f64,
    /// Training iteration that produced it.
    pub iteration: usize,
}

/// Performance-indexed database, per paper kept append-only over the run.
#[derive(Default)]
pub struct CodeDatabase {
    entries: Vec<Exemplar>,
}

impl CodeDatabase {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Insert a successful sample (score > 0; failures score 0 per Table 1
    /// and are not stored as exemplars).
    pub fn insert(&mut self, e: Exemplar) {
        if e.score > 0.0 {
            self.entries.push(e);
        }
    }

    /// All entries for a module (most recent last).
    pub fn for_module(&self, module: Module) -> Vec<&Exemplar> {
        self.entries
            .iter()
            .filter(|e| e.module == module)
            .collect()
    }

    /// Best entry for a module.
    pub fn best(&self, module: Module) -> Option<&Exemplar> {
        self.for_module(module)
            .into_iter()
            .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
    }

    /// Eq. 1: sample `k` distinct exemplars for `module` with temperature
    /// `tau`. Returns fewer when the database is small.
    pub fn sample(&self, module: Module, k: usize, tau: f64, rng: &mut Rng) -> Vec<&Exemplar> {
        let pool = self.for_module(module);
        if pool.len() <= k {
            return pool;
        }
        let mu = pool.iter().map(|e| e.score).sum::<f64>() / pool.len() as f64;
        let tau = tau.max(1e-6);
        let mut weights: Vec<f64> = pool
            .iter()
            .map(|e| ((e.score - mu) / tau).min(50.0).exp())
            .collect();
        let mut picked: Vec<usize> = Vec::with_capacity(k);
        for _ in 0..k {
            let total: f64 = weights.iter().sum();
            if total <= 0.0 {
                break;
            }
            let mut t = rng.next_f64() * total;
            let mut idx = weights.len() - 1;
            for (i, &w) in weights.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    idx = i;
                    break;
                }
            }
            picked.push(idx);
            weights[idx] = 0.0; // without replacement
        }
        picked.into_iter().map(|i| pool[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(score: f64, module: Module) -> Exemplar {
        Exemplar {
            config: VariantConfig::glass_baseline(),
            module,
            score,
            iteration: 0,
        }
    }

    #[test]
    fn insert_filters_failures() {
        let mut db = CodeDatabase::new();
        db.insert(ex(0.0, Module::Search));
        db.insert(ex(-1.0, Module::Search));
        db.insert(ex(1.2, Module::Search));
        assert_eq!(db.len(), 1);
    }

    #[test]
    fn best_per_module() {
        let mut db = CodeDatabase::new();
        db.insert(ex(1.0, Module::Search));
        db.insert(ex(2.0, Module::Search));
        db.insert(ex(9.0, Module::Refinement));
        assert_eq!(db.best(Module::Search).unwrap().score, 2.0);
        assert!(db.best(Module::Construction).is_none());
    }

    #[test]
    fn low_temperature_prefers_high_scores() {
        let mut db = CodeDatabase::new();
        for i in 0..50 {
            db.insert(ex(1.0 + i as f64 * 0.02, Module::Construction));
        }
        let mut rng = Rng::new(3);
        let mut mean_low = 0.0;
        let mut mean_high = 0.0;
        for _ in 0..50 {
            mean_low += db
                .sample(Module::Construction, 4, 0.02, &mut rng)
                .iter()
                .map(|e| e.score)
                .sum::<f64>()
                / 4.0;
            mean_high += db
                .sample(Module::Construction, 4, 10.0, &mut rng)
                .iter()
                .map(|e| e.score)
                .sum::<f64>()
                / 4.0;
        }
        assert!(
            mean_low > mean_high,
            "low-tau mean {mean_low} should exceed high-tau mean {mean_high}"
        );
    }

    #[test]
    fn sample_without_replacement() {
        let mut db = CodeDatabase::new();
        for i in 0..10 {
            db.insert(Exemplar {
                iteration: i,
                ..ex(1.0 + i as f64, Module::Search)
            });
        }
        let mut rng = Rng::new(5);
        let s = db.sample(Module::Search, 5, 1.0, &mut rng);
        let iters: std::collections::HashSet<usize> = s.iter().map(|e| e.iteration).collect();
        assert_eq!(iters.len(), 5);
    }

    #[test]
    fn small_pool_returned_whole() {
        let mut db = CodeDatabase::new();
        db.insert(ex(1.0, Module::Search));
        let mut rng = Rng::new(1);
        assert_eq!(db.sample(Module::Search, 4, 1.0, &mut rng).len(), 1);
    }
}
