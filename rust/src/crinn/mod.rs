//! CRINN: contrastive reinforcement learning over ANNS modules (§3).
//!
//! The training loop exactly mirrors the paper's:
//!
//! 1. **Sequential module optimization** (§3.1/§3.5): graph construction →
//!    search → refinement, each optimized while the others stay fixed.
//! 2. **Contrastive prompts** (§3.2 / Table 1): each step samples exemplar
//!    implementations + speed scores from a performance-indexed database
//!    with the temperature-softmax of Eq. 1 ([`database`]), renders the
//!    Table-1 prompt verbatim ([`prompt`]) and encodes the same content as
//!    the policy features ([`policy`]).
//! 3. **Speed reward** (§3.3): candidates are *actually executed* — an ef
//!    sweep on the training dataset, filtered to recall ∈ [0.85, 0.95],
//!    area under the QPS curve ([`reward`]).
//! 4. **GRPO** (§3.4, Eq. 2–3): G completions per prompt, group-normalized
//!    advantages with smoothing, clipped surrogate + KL against the
//!    reference policy — the update itself runs as the AOT `grpo_step`
//!    artifact through [`crate::runtime::Engine`] ([`grpo`], [`trainer`]).
//!
//! The substitution of the paper's code-writing LLM by a policy over the
//! structured variant space is documented in DESIGN.md §2.

pub mod database;
pub mod grpo;
pub mod policy;
pub mod prompt;
pub mod reward;
pub mod trainer;

pub use database::{CodeDatabase, Exemplar};
pub use reward::RewardSpec;
pub use trainer::{CrinnTrainer, TrainerOptions};
