//! CRINN: contrastive reinforcement learning over ANNS modules (§3).
//!
//! The training loop exactly mirrors the paper's:
//!
//! 1. **Sequential module optimization** (§3.1/§3.5): graph construction →
//!    search → refinement, each optimized while the others stay fixed.
//! 2. **Contrastive prompts** (§3.2 / Table 1): each step samples exemplar
//!    implementations + speed scores from a performance-indexed database
//!    with the temperature-softmax of Eq. 1 ([`database`]), renders the
//!    Table-1 prompt verbatim ([`prompt`]) and encodes the same content as
//!    the policy features ([`policy`]).
//! 3. **Speed reward** (§3.3): candidates are *actually executed* — an ef
//!    sweep on the training dataset, filtered to the
//!    [`RewardSpec::DEFAULT_WINDOW`] recall window, area under the QPS
//!    curve ([`reward`]), served to both optimizers through the
//!    [`oracle`] seam.
//! 4. **GRPO** (§3.4, Eq. 2–3): G completions per prompt, group-normalized
//!    advantages with smoothing, clipped surrogate + KL against the
//!    reference policy — the update itself runs as the AOT `grpo_step`
//!    artifact through [`crate::runtime::Engine`] ([`grpo`], [`trainer`]).
//!
//! Alongside the RL loop, [`tune`] implements `crinn tune`: a
//! Lagrangian-relaxation derivative-free baseline over the same
//! [`crate::variants::TuningSpace`] and the same [`oracle`], emitting a
//! checksummed tuned-config artifact that `crinn serve --tuned` loads.
//!
//! The substitution of the paper's code-writing LLM by a policy over the
//! structured variant space is documented in DESIGN.md §2.

pub mod database;
pub mod grpo;
pub mod oracle;
pub mod policy;
pub mod prompt;
pub mod reward;
pub mod trainer;
pub mod tune;

pub use database::{CodeDatabase, Exemplar};
pub use oracle::{OracleReport, RewardOracle, SweepOracle, SyntheticOracle};
pub use reward::RewardSpec;
pub use trainer::{CrinnTrainer, TrainerOptions};
pub use tune::{finalize, split_queries, tune_lagrange, TuneOptions, TuneResult};
