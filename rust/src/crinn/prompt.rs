//! §3.2 / Table 1: the contrastive prompt, rendered verbatim.
//!
//! Our policy consumes the prompt as encoded features (`policy.rs`), but
//! the textual prompt is still produced each step — it is the paper's
//! interface artifact (Table 1), it documents what the "LLM" sees, and the
//! `--dump-prompts` trainer flag writes them for inspection. Exemplar
//! implementations are rendered as C++-flavored module skeletons with the
//! knob values inlined, mirroring the paper's "Previous Implementations
//! with Speed" block.

use crate::crinn::database::Exemplar;
use crate::variants::Module;
use std::fmt::Write as _;

/// Render the Table-1 prompt for one training step.
pub fn render(module: Module, exemplars: &[&Exemplar]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "## Task Description");
    let _ = writeln!(
        out,
        "You are an approximate nearest neighbor search optimization expert \
specializing in high-performance similarity search algorithms. Given \
reference implementations for {}, your objective is to create an \
accelerated version that maintains identical functionality. You will \
receive previous module implementations accompanied by their scores \
indicating the general speed. Higher scores indicate higher speed. Conduct \
a comparative analysis of these implementations and use the insights to \
develop optimized {} code.",
        module.name(),
        module.name()
    );
    let _ = writeln!(out, "\n## Previous Implementations with Speed");
    for (i, e) in exemplars.iter().enumerate() {
        let _ = writeln!(out, "\n// Implementation {} (Score: {:.2})", i + 1, e.score);
        out.push_str(&render_module_code(e, i + 1));
    }
    let _ = writeln!(out, "\n## Generation Protocol");
    let _ = writeln!(
        out,
        "You MUST use exactly two hash symbols (##) at the beginning of each \
section.\n\
## Performance Analysis: Compare ANNS implementations above and articulate \
on: (1) which implementations achieve superior query throughput and what \
algorithmic factors contribute; (2) what indexing structures or search \
strategies demonstrate the best speed-accuracy tradeoffs; (3) the primary \
bottlenecks limiting query performance in slower implementations; (4) which \
vectorization, parallelization, or caching techniques remain unexploited.\n\
## Algorithm Design: Describe your optimization strategy as numbered points.\n\
## Code: Your code implementation"
    );
    let _ = writeln!(out, "\n## Critical Requirements");
    let _ = writeln!(
        out,
        "1. Search quality must match the reference implementation exactly \
(same recall, precision). Failure to maintain search accuracy will result \
in a score of 0.\n\
2. The module must support the same interface: build_index() and search() \
methods with identical parameters.\n\
3. Results must be deterministic and reproducible across runs."
    );
    out
}

/// Render an exemplar as a C++-flavored module skeleton with its knob
/// values inlined (the "code" the contrastive prompt compares).
pub fn render_module_code(e: &Exemplar, version: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "class Module_v{version} {{");
    match e.module {
        Module::Construction => {
            let c = &e.config.construction;
            let _ = writeln!(s, "  void build_index(const float* data, int n, int d) {{");
            let _ = writeln!(s, "    M = {}; ef_construction = {};", c.m, c.ef_construction);
            if c.adaptive_ef {
                let _ = writeln!(
                    s,
                    "    // Adaptive search budget based on recall needs\n    if (target_recall > {:.2})\n      dynamic_ef = ef_construction * (1.0 + recall_excess * {:.1});",
                    c.recall_threshold, c.ef_scale
                );
            } else {
                let _ = writeln!(s, "    size_t ef = ef_construction; // Always constant");
            }
            let _ = writeln!(
                s,
                "    for (int j = 0; j < min({}, size); ++j)\n      computer.prefetch(neighbors[j], {});",
                c.prefetch_depth, c.prefetch_locality
            );
            if c.num_entry_points > 1 {
                let _ = writeln!(
                    s,
                    "    // Multiple diverse entry points (up to {})\n    for (node : strategic_entrypoints)\n      if (distance_to_others(node) > q{:.2}) entry_points.add(node);",
                    c.num_entry_points, c.entry_diversity
                );
            }
            let _ = writeln!(s, "  }}");
        }
        Module::Search => {
            let k = &e.config.search;
            let _ = writeln!(
                s,
                "  void search(const float* query, int k, int* idx, float* dist) {{"
            );
            let _ = writeln!(s, "    add_entry(primary_entry_point);");
            if k.entry_tiers >= 2 {
                let _ = writeln!(
                    s,
                    "    if (search_budget > {}) add_entry(secondary_entry_point);",
                    k.tier_budget_1
                );
            }
            if k.entry_tiers >= 3 {
                let _ = writeln!(
                    s,
                    "    if (search_budget > {}) add_entry(tertiary_entry_point);",
                    k.tier_budget_2
                );
            }
            if k.edge_batch {
                let _ = writeln!(
                    s,
                    "    // Batch processing with adaptive prefetching\n    batch = collect_edges({}); prefetch_batch(batch, {});",
                    k.batch_size, k.prefetch_depth
                );
            }
            if k.early_termination {
                let _ = writeln!(
                    s,
                    "    // Smart termination\n    if (check_convergence(no_improvement_count, {})) break;",
                    k.patience
                );
            } else {
                let _ = writeln!(s, "    while (has_candidates()) process_neighbor();");
            }
            let _ = writeln!(s, "  }}");
        }
        Module::Refinement => {
            let r = &e.config.refine;
            let _ = writeln!(s, "  void refine(Candidates& cands, int k) {{");
            let _ = writeln!(s, "    use_sq8_primary = {};", r.quantized_primary);
            if r.adaptive_prefetch {
                let _ = writeln!(
                    s,
                    "    // Adaptive prefetching with lookahead\n    for (i, edge : node_edges) prefetch(edges[i + {}]);",
                    r.lookahead
                );
            }
            if r.precomputed_metadata {
                let _ = writeln!(
                    s,
                    "    metadata = get_precomputed_metadata(level, node);\n    edge_count = metadata.count;"
                );
            } else {
                let _ = writeln!(
                    s,
                    "    count = 0;\n    for (edge : node) if (edge != -1) count++; // runtime counting"
                );
            }
            let _ = writeln!(s, "    rerank_pool = max(k, ef * {:.2});", r.rerank_frac);
            let _ = writeln!(s, "  }}");
        }
    }
    let _ = writeln!(s, "}};");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::crinn::database::Exemplar;
    use crate::variants::VariantConfig;

    fn exemplar(module: Module, score: f64) -> Exemplar {
        Exemplar {
            config: VariantConfig::crinn_full(),
            module,
            score,
            iteration: 3,
        }
    }

    #[test]
    fn prompt_has_table1_sections() {
        let e1 = exemplar(Module::Search, 1.42);
        let e2 = exemplar(Module::Search, 1.34);
        let p = render(Module::Search, &[&e1, &e2]);
        for section in [
            "## Task Description",
            "## Previous Implementations with Speed",
            "## Generation Protocol",
            "## Critical Requirements",
        ] {
            assert!(p.contains(section), "missing {section}");
        }
        assert!(p.contains("(Score: 1.42)"));
        assert!(p.contains("(Score: 1.34)"));
        assert!(p.contains("deterministic and reproducible"));
    }

    #[test]
    fn code_rendering_reflects_knobs() {
        let e = exemplar(Module::Construction, 1.0);
        let code = render_module_code(&e, 1);
        assert!(code.contains("dynamic_ef")); // crinn_full has adaptive_ef
        assert!(code.contains("strategic_entrypoints"));
        let base = Exemplar {
            config: VariantConfig::glass_baseline(),
            ..exemplar(Module::Construction, 1.0)
        };
        let code_b = render_module_code(&base, 2);
        assert!(code_b.contains("Always constant"));
        assert!(!code_b.contains("strategic_entrypoints"));
    }

    #[test]
    fn refinement_code_paths() {
        let e = exemplar(Module::Refinement, 2.0);
        let code = render_module_code(&e, 1);
        assert!(code.contains("get_precomputed_metadata"));
        assert!(code.contains("lookahead") || code.contains("prefetch(edges"));
    }
}
