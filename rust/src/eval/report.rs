//! Report writers: CSV, markdown tables, and ASCII QPS–recall plots — the
//! bench targets regenerate each paper table/figure through these.

use crate::eval::sweep::{CurvePoint, SweepResult};
use std::fmt::Write as _;
use std::path::Path;

/// Write sweep results as CSV (one row per point; Figure-1 data file).
pub fn sweeps_to_csv(sweeps: &[SweepResult]) -> String {
    let mut out = String::from("dataset,algorithm,k,ef,recall,qps,mean_latency_s,p99_latency_s,build_seconds,memory_bytes\n");
    for s in sweeps {
        for p in &s.points {
            let _ = writeln!(
                out,
                "{},{},{},{},{:.6},{:.2},{:.9},{:.9},{:.3},{}",
                s.dataset, s.index_name, s.k, p.ef, p.recall, p.qps,
                p.mean_latency_s, p.p99_latency_s, s.build_seconds, s.memory_bytes
            );
        }
    }
    out
}

/// Save a string to a file, creating parent dirs.
pub fn save(path: &Path, content: &str) -> crate::Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    std::fs::write(path, content)?;
    Ok(())
}

/// ASCII QPS-recall plot (log-y), one letter per algorithm — the terminal
/// rendition of one Figure-1 panel.
pub fn ascii_plot(title: &str, sweeps: &[SweepResult], width: usize, height: usize) -> String {
    let mut out = format!("## {title}\n");
    let fronts: Vec<(char, &SweepResult, Vec<CurvePoint>)> = sweeps
        .iter()
        .enumerate()
        .map(|(i, s)| {
            (
                (b'A' + (i % 26) as u8) as char,
                s,
                crate::eval::pareto_frontier(&s.points),
            )
        })
        .collect();
    let all: Vec<&CurvePoint> = fronts.iter().flat_map(|(_, _, f)| f.iter()).collect();
    if all.is_empty() {
        return out + "(no data)\n";
    }
    let rmin: f64 = 0.5_f64.min(
        all.iter()
            .map(|p| p.recall)
            .fold(f64::INFINITY, f64::min),
    );
    let rmax = 1.0;
    let qmin = all.iter().map(|p| p.qps.max(1.0)).fold(f64::INFINITY, f64::min);
    let qmax = all.iter().map(|p| p.qps.max(1.0)).fold(0.0_f64, f64::max);
    let (lqmin, lqmax) = (qmin.ln(), (qmax * 1.2).ln());
    let mut grid = vec![vec![' '; width]; height];
    for (ch, _, front) in &fronts {
        for p in front {
            let x = ((p.recall - rmin) / (rmax - rmin) * (width as f64 - 1.0))
                .round()
                .clamp(0.0, width as f64 - 1.0) as usize;
            let y = if lqmax > lqmin {
                ((p.qps.max(1.0).ln() - lqmin) / (lqmax - lqmin) * (height as f64 - 1.0))
                    .round()
                    .clamp(0.0, height as f64 - 1.0) as usize
            } else {
                0
            };
            grid[height - 1 - y][x] = *ch;
        }
    }
    let _ = writeln!(out, "QPS (log) {:>10.0} ┐", qmax);
    for row in &grid {
        let _ = writeln!(out, "           {} │", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "{:>10.0} ┘{}", qmin, "─".repeat(width));
    let _ = writeln!(
        out,
        "            recall: {:.2} → 1.00   legend: {}",
        rmin,
        fronts
            .iter()
            .map(|(c, s, _)| format!("{c}={}", s.index_name))
            .collect::<Vec<_>>()
            .join("  ")
    );
    out
}

/// Markdown table of QPS at fixed recall targets (Table-3 shape):
/// rows = (dataset, recall target), columns = algorithms.
pub fn fixed_recall_table(
    sweeps: &[SweepResult],
    targets: &[f64],
) -> String {
    let mut algos: Vec<String> = sweeps.iter().map(|s| s.index_name.clone()).collect();
    algos.dedup();
    let mut datasets: Vec<String> = sweeps.iter().map(|s| s.dataset.clone()).collect();
    datasets.dedup();
    let mut out = String::new();
    let _ = write!(out, "| dataset | recall |");
    for a in &algos {
        let _ = write!(out, " {a} |");
    }
    out.push('\n');
    let _ = write!(out, "|---|---|");
    for _ in &algos {
        let _ = write!(out, "---|");
    }
    out.push('\n');
    for d in &datasets {
        for &t in targets {
            let _ = write!(out, "| {d} | {t:.3} |");
            for a in &algos {
                let q = sweeps
                    .iter()
                    .find(|s| &s.dataset == d && &s.index_name == a)
                    .and_then(|s| crate::eval::qps_at_recall(&s.points, t));
                match q {
                    Some(q) => {
                        let _ = write!(out, " {q:.0} |");
                    }
                    None => {
                        let _ = write!(out, " — |");
                    }
                }
            }
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_sweep(name: &str, dataset: &str) -> SweepResult {
        SweepResult {
            index_name: name.into(),
            dataset: dataset.into(),
            k: 10,
            points: vec![
                CurvePoint { ef: 10, recall: 0.8, qps: 10_000.0, mean_latency_s: 1e-4, p99_latency_s: 2e-4 },
                CurvePoint { ef: 50, recall: 0.95, qps: 4_000.0, mean_latency_s: 2.5e-4, p99_latency_s: 4e-4 },
            ],
            build_seconds: 1.0,
            memory_bytes: 1024,
        }
    }

    #[test]
    fn csv_contains_all_points() {
        let csv = sweeps_to_csv(&[fake_sweep("a", "d1"), fake_sweep("b", "d1")]);
        assert_eq!(csv.lines().count(), 1 + 4);
        assert!(csv.contains("d1,a,10,10,"));
    }

    #[test]
    fn ascii_plot_renders() {
        let plot = ascii_plot("demo", &[fake_sweep("hnsw", "d1")], 40, 10);
        assert!(plot.contains("A"));
        assert!(plot.contains("legend: A=hnsw"));
    }

    #[test]
    fn fixed_recall_table_shape() {
        let t = fixed_recall_table(&[fake_sweep("a", "d1"), fake_sweep("b", "d1")], &[0.9, 0.99]);
        assert!(t.contains("| d1 | 0.900 |"));
        assert!(t.contains("—")); // 0.99 unreachable
        let header_cols = t.lines().next().unwrap().matches('|').count();
        assert_eq!(header_cols, 5); // | dataset | recall | a | b |
    }
}
