//! Shared machinery for the paper-table bench targets (`rust/benches/`).
//!
//! Scale control: the paper ran million-vector datasets on a large
//! multicore testbed; this sandbox is single-core, so the default bench
//! scale is reduced (counts below). Env overrides:
//! `CRINN_BENCH_N` (base vectors cap), `CRINN_BENCH_QUERIES`,
//! `CRINN_BENCH_EF` (comma list), `CRINN_BENCH_DATASETS` (comma list),
//! and `CRINN_BATCH` (batched-throughput sweep protocol — see
//! [`crate::eval::sweep::batch_mode`]).

use crate::anns::{AnnIndex, VectorSet};
use crate::dataset::synth;
use crate::dataset::Dataset;
use crate::eval::sweep::{sweep_index, SweepResult};
use crate::util::error::{Context, Result};
use crate::variants::VariantConfig;
use std::sync::Arc;

/// Default per-dataset base count for benches (single-core budget).
pub const DEFAULT_BENCH_N: usize = 8_000;
pub const DEFAULT_BENCH_QUERIES: usize = 120;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Parse a comma-separated ef list. Empty tokens (trailing commas) are
/// skipped; any non-empty unparsable token rejects the whole value — a
/// typo must not silently shrink the sweep grid.
fn parse_ef_list(s: &str) -> Option<Vec<usize>> {
    let mut grid = Vec::new();
    for t in s.split(',') {
        let t = t.trim();
        if t.is_empty() {
            continue;
        }
        grid.push(t.parse().ok()?);
    }
    if grid.is_empty() {
        None
    } else {
        Some(grid)
    }
}

/// The ef grid used by the paper benches. An unparsable `CRINN_BENCH_EF`
/// (e.g. empty) falls back to the default grid with a warning — the old
/// behavior returned an empty grid and sweeps silently emitted zero rows.
pub fn bench_ef_grid() -> Vec<usize> {
    if let Ok(s) = std::env::var("CRINN_BENCH_EF") {
        match parse_ef_list(&s) {
            Some(grid) => return grid,
            None => eprintln!(
                "warning: CRINN_BENCH_EF={s:?} is empty or has an unparsable token; \
                 using the default ef grid"
            ),
        }
    }
    vec![10, 16, 24, 32, 48, 64, 96, 128, 192, 256]
}

/// Dataset names to bench (default: the six Table-2 datasets).
pub fn bench_dataset_names() -> Vec<String> {
    if let Ok(s) = std::env::var("CRINN_BENCH_DATASETS") {
        return s.split(',').map(|t| t.trim().to_string()).collect();
    }
    synth::paper_dataset_names()
        .into_iter()
        .map(String::from)
        .collect()
}

/// Generate one bench dataset with ground truth at the bench scale. An
/// unknown name (e.g. a typo in `CRINN_BENCH_DATASETS`) is an `Err`
/// listing the valid names, not a panic.
pub fn bench_dataset(name: &str, k: usize) -> Result<Dataset> {
    let sp = synth::spec(name).with_context(|| {
        let valid: Vec<&str> = synth::SPECS.iter().map(|s| s.name).collect();
        format!("unknown dataset {name:?}; valid names: {}", valid.join(", "))
    })?;
    let n = env_usize("CRINN_BENCH_N", DEFAULT_BENCH_N).min(sp.full_base);
    let nq = env_usize("CRINN_BENCH_QUERIES", DEFAULT_BENCH_QUERIES).min(sp.full_queries);
    Ok(synth::generate_with_gt(name, n, nq, k, 42))
}

/// The Figure-1 algorithm roster: `(label, builder)`.
pub fn algorithms() -> Vec<(&'static str, fn(&Dataset, u64) -> Arc<dyn AnnIndex>)> {
    fn crinn(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(
            crate::anns::glass::GlassIndex::build(
                VectorSet::from_dataset(ds),
                VariantConfig::crinn_full(),
                seed,
            )
            .with_label("crinn"),
        )
    }
    fn glass(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(ds),
            VariantConfig::glass_baseline(),
            seed,
        ))
    }
    fn parlayann(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::vamana::VamanaIndex::build(
            VectorSet::from_dataset(ds),
            crate::anns::vamana::VamanaParams::default(),
            seed,
        ))
    }
    fn nndescent(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::nndescent::NnDescentIndex::build(
            VectorSet::from_dataset(ds),
            crate::anns::nndescent::NnDescentParams::default(),
            seed,
        ))
    }
    fn pynndescent(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::nndescent::NnDescentIndex::build(
            VectorSet::from_dataset(ds),
            crate::anns::nndescent::NnDescentParams::pynndescent(),
            seed,
        ))
    }
    fn vearch(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::ivf::IvfIndex::build(
            VectorSet::from_dataset(ds),
            crate::anns::ivf::IvfParams::default(),
            seed,
        ))
    }
    fn ivfpq(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(crate::anns::ivf::IvfIndex::build(
            VectorSet::from_dataset(ds),
            crate::anns::ivf::IvfParams {
                pq_m: 16,
                pq_rerank: 8,
                ..crate::anns::ivf::IvfParams::default()
            },
            seed,
        ))
    }
    fn voyager(ds: &Dataset, seed: u64) -> Arc<dyn AnnIndex> {
        Arc::new(
            crate::anns::hnsw::HnswIndex::build(
                VectorSet::from_dataset(ds),
                &crate::variants::ConstructionKnobs {
                    m: 12,
                    ef_construction: 200,
                    ..Default::default()
                },
                crate::variants::SearchKnobs::default(),
                seed,
            )
            .with_label("voyager"),
        )
    }
    vec![
        ("crinn", crinn),
        ("glass", glass),
        ("parlayann", parlayann),
        ("nndescent", nndescent),
        ("pynndescent", pynndescent),
        ("vearch-ivf", vearch),
        ("ivfpq", ivfpq),
        ("voyager", voyager),
    ]
}

/// Build + sweep one algorithm on one dataset.
pub fn run_algorithm(
    ds: &Dataset,
    label: &str,
    builder: fn(&Dataset, u64) -> Arc<dyn AnnIndex>,
    ef_grid: &[usize],
) -> SweepResult {
    let (build_s, index) = crate::util::bench::time_once(|| builder(ds, 42));
    eprintln!(
        "  [{}] {} built in {:.2}s ({:.1} MiB)",
        ds.name,
        label,
        build_s,
        crate::util::bench::mib(index.memory_bytes())
    );
    sweep_index(index.as_ref(), ds, ds.gt_k, ef_grid, build_s)
}

/// Reports directory.
pub fn reports_dir() -> std::path::PathBuf {
    let p = std::path::PathBuf::from("reports");
    std::fs::create_dir_all(&p).ok();
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_ef_list_rejects_empty_or_garbage() {
        assert_eq!(parse_ef_list("10, 32,128"), Some(vec![10, 32, 128]));
        assert_eq!(parse_ef_list("64"), Some(vec![64]));
        assert_eq!(parse_ef_list("10,32,"), Some(vec![10, 32]));
        assert_eq!(parse_ef_list(""), None);
        assert_eq!(parse_ef_list("a,b"), None);
        // A typo rejects the whole value (silently dropping the token
        // would shrink the grid without a diagnostic).
        assert_eq!(parse_ef_list("10,1O0,32"), None);
    }

    #[test]
    fn bench_dataset_unknown_name_lists_valid_names() {
        let err = bench_dataset("bogus-dataset", 10).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("bogus-dataset"), "{msg}");
        assert!(msg.contains("sift-128-euclidean"), "{msg}");
    }
}
