//! ef sweeps: measure (recall, QPS) points for an index over a query set —
//! the measurement protocol behind Figure 1, Table 3, Table 4 and the
//! CRINN reward (§3.3).

use crate::anns::AnnIndex;
use crate::dataset::{gt::recall_at_k, Dataset};
use std::time::Instant;

/// One measured point on a QPS-recall curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub ef: usize,
    pub recall: f64,
    pub qps: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
}

/// A full sweep for one index on one dataset.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub index_name: String,
    pub dataset: String,
    pub k: usize,
    pub points: Vec<CurvePoint>,
    pub build_seconds: f64,
    pub memory_bytes: usize,
}

impl SweepResult {
    /// Pareto frontier of the measured points.
    pub fn frontier(&self) -> Vec<CurvePoint> {
        crate::eval::pareto_frontier(&self.points)
    }
}

/// Measure one ef setting: runs every query once (timed, single thread —
/// ann-benchmarks' protocol), returns the curve point.
pub fn measure_point(index: &dyn AnnIndex, ds: &Dataset, k: usize, ef: usize) -> CurvePoint {
    assert!(!ds.gt.is_empty(), "dataset needs ground truth");
    let nq = ds.n_queries();
    let mut lat = Vec::with_capacity(nq * 2);
    let mut recall_acc = 0.0;
    // Warmup on a few queries (pays one-time lazy costs).
    for qi in 0..nq.min(5) {
        std::hint::black_box(index.search(ds.query_vec(qi), k, ef));
    }
    // Repeat the full query set until >= MIN_SECS of measurement has
    // accumulated (up to MAX_PASSES) — a single 100-query pass is ~2 ms at
    // small scale and VM jitter dominates it.
    const MIN_SECS: f64 = 0.04;
    const MAX_PASSES: usize = 8;
    let mut passes = 0usize;
    let mut total = 0.0f64;
    while passes < MAX_PASSES && (passes == 0 || total < MIN_SECS) {
        for qi in 0..nq {
            let q = ds.query_vec(qi);
            let t = Instant::now();
            let found = index.search(q, k, ef);
            let dt = t.elapsed().as_secs_f64();
            lat.push(dt);
            total += dt;
            if passes == 0 {
                recall_acc += recall_at_k(&found, &ds.gt[qi], k);
            }
        }
        passes += 1;
    }
    let stats = crate::util::bench::Stats::from_samples(lat);
    CurvePoint {
        ef,
        recall: recall_acc / nq as f64,
        qps: if stats.mean > 0.0 { 1.0 / stats.mean } else { 0.0 },
        mean_latency_s: stats.mean,
        p99_latency_s: stats.p99,
    }
}

/// Sweep an index over an ef grid.
pub fn sweep_index(
    index: &dyn AnnIndex,
    ds: &Dataset,
    k: usize,
    ef_grid: &[usize],
    build_seconds: f64,
) -> SweepResult {
    let points = ef_grid
        .iter()
        .map(|&ef| measure_point(index, ds, k, ef))
        .collect();
    SweepResult {
        index_name: index.name(),
        dataset: ds.name.clone(),
        k,
        points,
        build_seconds,
        memory_bytes: index.memory_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    #[test]
    fn bruteforce_sweep_has_recall_one() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 20, 61);
        ds.compute_ground_truth(10);
        let idx = BruteForceIndex::build(VectorSet::from_dataset(&ds));
        let res = sweep_index(&idx, &ds, 10, &[10, 20], 0.0);
        assert_eq!(res.points.len(), 2);
        for p in &res.points {
            assert!((p.recall - 1.0).abs() < 1e-9, "brute force recall {}", p.recall);
            assert!(p.qps > 0.0);
            assert!(p.mean_latency_s > 0.0);
        }
    }

    #[test]
    fn hnsw_sweep_recall_increases_with_ef() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 62);
        ds.compute_ground_truth(10);
        let idx = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        let res = sweep_index(&idx, &ds, 10, &[10, 64, 256], 0.0);
        assert!(res.points[2].recall >= res.points[0].recall);
        assert!(res.points[2].recall > 0.9);
    }
}
