//! ef sweeps: measure (recall, QPS) points for an index over a query set —
//! the measurement protocol behind Figure 1, Table 3, Table 4 and the
//! CRINN reward (§3.3).
//!
//! Query evaluation is parallel: each pass runs the query set through
//! [`parallel_map`] (sized by `CRINN_THREADS`), with per-worker
//! [`crate::anns::hnsw::search::SearchContext`]s supplied by the shared
//! [`crate::anns::scratch::ScratchPool`]s. The map is order-preserving
//! and every index search is deterministic, so recall and per-query
//! results are **bit-identical** for every thread count —
//! `CRINN_THREADS=1` reproduces the sequential ann-benchmarks protocol
//! exactly (asserted by `tests/properties.rs` and the CLI determinism
//! test).
//!
//! `CRINN_BATCH=<B>` (default off) switches the *timed* passes to the
//! ANN-Benchmarks batch-query protocol: B-query chunks through
//! [`crate::anns::AnnIndex::search_batch`]. Recall and per-query results
//! are unchanged — the batch path is bitwise identical to per-query
//! search — so the knob is a pure throughput-protocol dial.

use crate::anns::AnnIndex;
use crate::dataset::{gt::recall_at_k, Dataset};
use crate::util::threadpool::{parallel_map, parallel_map_threads};
use std::time::Instant;

/// One measured point on a QPS-recall curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub ef: usize,
    pub recall: f64,
    pub qps: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
}

/// A full sweep for one index on one dataset.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub index_name: String,
    pub dataset: String,
    pub k: usize,
    pub points: Vec<CurvePoint>,
    pub build_seconds: f64,
    pub memory_bytes: usize,
}

impl SweepResult {
    /// Pareto frontier of the measured points.
    pub fn frontier(&self) -> Vec<CurvePoint> {
        crate::eval::pareto_frontier(&self.points)
    }
}

/// Parse the `CRINN_BATCH` batched-throughput knob: unset, empty, `0` or
/// `off` keep the per-query protocol; a positive integer selects batched
/// mode with that batch size. An unparsable value warns and falls back to
/// per-query (same discipline as `CRINN_BENCH_EF`: a typo must not
/// silently change the measurement protocol). Parsed once per process —
/// `measure_point` calls this per curve point, and a typo'd value must
/// warn once, not once per ef × dataset × algorithm.
pub fn batch_mode() -> Option<usize> {
    static MODE: std::sync::OnceLock<Option<usize>> = std::sync::OnceLock::new();
    *MODE.get_or_init(|| {
        let s = std::env::var("CRINN_BATCH").ok()?;
        match s.trim() {
            "" | "0" | "off" => None,
            t => match t.parse::<usize>() {
                Ok(b) => Some(b),
                Err(_) => {
                    eprintln!(
                        "warning: CRINN_BATCH={s:?} is not a batch size; \
                         using the per-query protocol"
                    );
                    None
                }
            },
        }
    })
}

/// Measure one ef setting: runs every query once per pass through the
/// parallel worker pool, returns the curve point. QPS is aggregate
/// wall-clock throughput across the pool (with `CRINN_THREADS=1` this
/// degrades to ann-benchmarks' sequential single-thread protocol);
/// latencies are always per-query. With `CRINN_BATCH=<B>` set (default
/// off) the timed passes switch to the batched-throughput protocol — see
/// [`measure_point_with_mode`].
pub fn measure_point(index: &dyn AnnIndex, ds: &Dataset, k: usize, ef: usize) -> CurvePoint {
    measure_point_with_mode(index, ds, k, ef, batch_mode())
}

/// [`measure_point`] with an explicit protocol: `batch = None` is the
/// per-query path (every existing number), `batch = Some(B)` times
/// `search_batch` over B-query chunks instead — the ANN-Benchmarks
/// batch-query protocol. Because batch results are bitwise identical to
/// per-query results, **recall is identical in both modes**; only the
/// timing changes (per-query latency becomes the amortized
/// `chunk_time / chunk_len`). The recall pass itself is untimed and stays
/// per-query in both modes.
pub fn measure_point_with_mode(
    index: &dyn AnnIndex,
    ds: &Dataset,
    k: usize,
    ef: usize,
    batch: Option<usize>,
) -> CurvePoint {
    measure_point_tuned(index, ds, k, ef, batch, None)
}

/// [`measure_point_with_mode`] with an explicit worker count for the
/// measurement pool (`None` = ambient `CRINN_THREADS`) — the seam the
/// tuner's reward oracle uses to score a candidate's serving knobs
/// (batch size, thread count) without touching process environment.
/// Recall is batch- and thread-count-invariant (bit-identical); only the
/// timing protocol changes.
pub fn measure_point_tuned(
    index: &dyn AnnIndex,
    ds: &Dataset,
    k: usize,
    ef: usize,
    batch: Option<usize>,
    threads: Option<usize>,
) -> CurvePoint {
    assert!(!ds.gt.is_empty(), "dataset needs ground truth");
    let nq = ds.n_queries();
    let nthreads = threads
        .unwrap_or_else(crate::util::threadpool::effective_threads)
        .max(1);
    // Untimed recall pass — keeps recall_at_k out of the timed window (it
    // would bias QPS low for fast configurations) and doubles as warmup
    // (pays one-time lazy costs: SIMD kernel dispatch, context-pool
    // growth, page faults). Order-preserving map: the sequential sum below
    // is identical for every thread count.
    let recalls: Vec<f64> = parallel_map_threads(nq, 4, nthreads, |qi| {
        let found = index.search(ds.query_vec(qi), k, ef);
        recall_at_k(&found, &ds.gt[qi], k)
    });
    let recall_acc: f64 = recalls.iter().sum();
    // Repeat the full query set until >= MIN_SECS of measurement has
    // accumulated (up to MAX_PASSES) — a single 100-query pass is ~2 ms at
    // small scale and VM jitter dominates it.
    const MIN_SECS: f64 = 0.04;
    const MAX_PASSES: usize = 8;
    let mut lat = Vec::with_capacity(nq * 2);
    let mut passes = 0usize;
    let mut wall = 0.0f64;
    while passes < MAX_PASSES && (passes == 0 || wall < MIN_SECS) {
        let t_pass = Instant::now();
        match batch {
            None => {
                let pass: Vec<f64> = parallel_map_threads(nq, 4, nthreads, |qi| {
                    let t = Instant::now();
                    std::hint::black_box(index.search(ds.query_vec(qi), k, ef));
                    t.elapsed().as_secs_f64()
                });
                lat.extend(pass);
            }
            Some(bs) => {
                // Batched protocol: the query set is cut into B-query
                // chunks, each served by one `search_batch` call; chunks
                // go through the same worker pool as the per-query path,
                // so CRINN_THREADS semantics carry over.
                let bs = bs.max(1);
                let n_chunks = nq.div_ceil(bs);
                let chunk_times: Vec<(f64, usize)> =
                    parallel_map_threads(n_chunks, 1, nthreads, |ci| {
                        let lo = ci * bs;
                        let hi = (lo + bs).min(nq);
                        let queries: Vec<&[f32]> =
                            (lo..hi).map(|qi| ds.query_vec(qi)).collect();
                        let t = Instant::now();
                        std::hint::black_box(index.search_batch(&queries, k, ef));
                        (t.elapsed().as_secs_f64(), hi - lo)
                    });
                for (dt, cnt) in chunk_times {
                    lat.extend(std::iter::repeat(dt / cnt as f64).take(cnt));
                }
            }
        }
        wall += t_pass.elapsed().as_secs_f64();
        passes += 1;
    }
    let stats = crate::util::bench::Stats::from_samples(lat);
    CurvePoint {
        ef,
        recall: recall_acc / nq as f64,
        qps: if wall > 0.0 {
            (nq * passes) as f64 / wall
        } else {
            0.0
        },
        mean_latency_s: stats.mean,
        p99_latency_s: stats.p99,
    }
}

/// Measure one ef setting under a filter bitset: recall is computed
/// against the **filtered** ground truth (the exact top-k over the ids
/// the bitset allows — `gt::topk_pairs_for_query_filtered` is the
/// oracle), and the timed passes run `search_filtered` per query. The
/// companion to [`measure_point`] for the filtered-QPS-vs-selectivity
/// rows in EXPERIMENTS.md: sweep the same index over filters of
/// decreasing popcount to see beam-path throughput hand over to the
/// exact fallback at the crossover threshold.
pub fn measure_filtered_point(
    index: &dyn AnnIndex,
    ds: &Dataset,
    k: usize,
    ef: usize,
    filter: &crate::anns::FilterBitset,
) -> CurvePoint {
    let nq = ds.n_queries();
    // Exact filtered ground truth, untimed (the stored ds.gt is unfiltered
    // and useless here).
    let gt: Vec<Vec<u32>> = parallel_map(nq, 2, |qi| {
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        crate::dataset::gt::topk_pairs_for_query_filtered(
            &ds.base,
            ds.query_vec(qi),
            ds.dim,
            ds.metric,
            k,
            &mut ids,
            &mut dists,
            |i| filter.matches(i),
        )
        .into_iter()
        .map(|(_, id)| id)
        .collect()
    });
    // Untimed recall pass — doubles as warmup, like `measure_point`'s.
    let recalls: Vec<f64> = parallel_map(nq, 4, |qi| {
        let found = index.search_filtered(ds.query_vec(qi), k, ef, Some(filter));
        recall_at_k(&found, &gt[qi], k)
    });
    let recall_acc: f64 = recalls.iter().sum();
    const MIN_SECS: f64 = 0.04;
    const MAX_PASSES: usize = 8;
    let mut lat = Vec::with_capacity(nq * 2);
    let mut passes = 0usize;
    let mut wall = 0.0f64;
    while passes < MAX_PASSES && (passes == 0 || wall < MIN_SECS) {
        let t_pass = Instant::now();
        let pass: Vec<f64> = parallel_map(nq, 4, |qi| {
            let t = Instant::now();
            std::hint::black_box(index.search_filtered(ds.query_vec(qi), k, ef, Some(filter)));
            t.elapsed().as_secs_f64()
        });
        lat.extend(pass);
        wall += t_pass.elapsed().as_secs_f64();
        passes += 1;
    }
    let stats = crate::util::bench::Stats::from_samples(lat);
    CurvePoint {
        ef,
        recall: recall_acc / nq as f64,
        qps: if wall > 0.0 {
            (nq * passes) as f64 / wall
        } else {
            0.0
        },
        mean_latency_s: stats.mean,
        p99_latency_s: stats.p99,
    }
}

/// Measured insert/delete throughput for a mutable index — the
/// EXPERIMENTS.md "Live updates" row. Wall-clock, sequential (the
/// mutation path is serialized by design; concurrency belongs to the
/// serving lock, not the index).
#[derive(Clone, Debug)]
pub struct MutationStats {
    pub inserts: usize,
    pub deletes: usize,
    pub inserts_per_s: f64,
    pub deletes_per_s: f64,
    /// Wall-clock seconds of the final `consolidate()` pass.
    pub consolidate_s: f64,
    /// Points physically dropped by that pass.
    pub consolidated: usize,
}

/// Apply `insert_vecs` then delete `delete_ids` (ids must be valid at the
/// time each delete runs) then consolidate, timing each phase. Errors out
/// on the first failed mutation — an `Unsupported` index reports instead
/// of measuring garbage.
pub fn measure_mutations(
    index: &mut dyn crate::anns::MutableAnnIndex,
    insert_vecs: &[Vec<f32>],
    delete_ids: &[u32],
) -> crate::Result<MutationStats> {
    let t = Instant::now();
    for v in insert_vecs {
        index.insert(v)?;
    }
    let insert_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    for &id in delete_ids {
        index.delete(id)?;
    }
    let delete_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let consolidated = index.consolidate()?;
    let consolidate_s = t.elapsed().as_secs_f64();
    Ok(MutationStats {
        inserts: insert_vecs.len(),
        deletes: delete_ids.len(),
        inserts_per_s: if insert_s > 0.0 {
            insert_vecs.len() as f64 / insert_s
        } else {
            0.0
        },
        deletes_per_s: if delete_s > 0.0 {
            delete_ids.len() as f64 / delete_s
        } else {
            0.0
        },
        consolidate_s,
        consolidated,
    })
}

/// Sweep an index over an ef grid.
pub fn sweep_index(
    index: &dyn AnnIndex,
    ds: &Dataset,
    k: usize,
    ef_grid: &[usize],
    build_seconds: f64,
) -> SweepResult {
    let points = ef_grid
        .iter()
        .map(|&ef| measure_point(index, ds, k, ef))
        .collect();
    SweepResult {
        index_name: index.name(),
        dataset: ds.name.clone(),
        k,
        points,
        build_seconds,
        memory_bytes: index.memory_bytes(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    #[test]
    fn bruteforce_sweep_has_recall_one() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 20, 61);
        ds.compute_ground_truth(10);
        let idx = BruteForceIndex::build(VectorSet::from_dataset(&ds));
        let res = sweep_index(&idx, &ds, 10, &[10, 20], 0.0);
        assert_eq!(res.points.len(), 2);
        for p in &res.points {
            assert!((p.recall - 1.0).abs() < 1e-9, "brute force recall {}", p.recall);
            assert!(p.qps > 0.0);
            assert!(p.mean_latency_s > 0.0);
        }
    }

    #[test]
    fn sweep_recall_matches_sequential_reference() {
        // Whatever CRINN_THREADS the ambient environment sets (CI runs the
        // suite at 2), the parallel sweep's recall must equal the plain
        // sequential loop bit-for-bit.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 900, 40, 63);
        ds.compute_ground_truth(10);
        let idx = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        for ef in [16usize, 64] {
            let mut acc = 0.0;
            for qi in 0..ds.n_queries() {
                let found = idx.search(ds.query_vec(qi), 10, ef);
                acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
            }
            let want = acc / ds.n_queries() as f64;
            let got = measure_point(&idx, &ds, 10, ef).recall;
            assert_eq!(got, want, "ef={ef}");
        }
    }

    #[test]
    fn batched_sweep_mode_matches_per_query_recall() {
        // CRINN_BATCH only changes the timing protocol: recall must be
        // bit-identical to the per-query mode for every batch size
        // (search_batch == per-query search is asserted upstream), and the
        // throughput stats must stay well-formed. Uses the explicit-mode
        // seam so the test never touches process environment.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 700, 30, 64);
        ds.compute_ground_truth(10);
        let idx = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        let per = measure_point_with_mode(&idx, &ds, 10, 64, None);
        for bs in [1usize, 7, 30, 100] {
            let b = measure_point_with_mode(&idx, &ds, 10, 64, Some(bs));
            assert_eq!(b.recall, per.recall, "batch size {bs}");
            assert!(b.qps > 0.0 && b.mean_latency_s > 0.0, "batch size {bs}");
        }
    }

    #[test]
    fn mutation_throughput_measurement_well_formed() {
        use crate::util::rng::Rng;
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 400, 5, 65);
        let mut idx = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        let mut rng = Rng::new(66);
        let inserts: Vec<Vec<f32>> = (0..20)
            .map(|_| (0..ds.dim).map(|_| rng.next_gaussian_f32()).collect())
            .collect();
        let deletes: Vec<u32> = (0..10).collect();
        let stats = measure_mutations(&mut idx, &inserts, &deletes).unwrap();
        assert_eq!((stats.inserts, stats.deletes), (20, 10));
        assert_eq!(stats.consolidated, 10);
        assert!(stats.inserts_per_s > 0.0 && stats.deletes_per_s > 0.0);
        assert!(stats.consolidate_s >= 0.0);
        use crate::anns::MutableAnnIndex;
        assert_eq!(idx.live_count(), 410);
        // An Unsupported index reports instead of measuring garbage.
        let mut vam = crate::anns::vamana::VamanaIndex::build(
            VectorSet::from_dataset(&ds),
            crate::anns::vamana::VamanaParams::default(),
            1,
        );
        assert!(measure_mutations(&mut vam, &inserts, &[]).is_err());
    }

    #[test]
    fn filtered_point_uses_filtered_ground_truth() {
        use crate::anns::FilterBitset;
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 600, 20, 67);
        ds.compute_ground_truth(10);
        // Brute force is the filtered oracle, so its filtered recall is
        // exactly 1.0 at every selectivity — including popcounts below k,
        // where recall_at_k caps k at the matching-set size.
        let idx = BruteForceIndex::build(VectorSet::from_dataset(&ds));
        for modulus in [2u32, 10, 100] {
            let f = FilterBitset::from_predicate(600, |id| id % modulus == 0);
            let p = measure_filtered_point(&idx, &ds, 10, 0, &f);
            assert!(
                (p.recall - 1.0).abs() < 1e-9,
                "modulus {modulus}: filtered recall {}",
                p.recall
            );
            assert!(p.qps > 0.0 && p.mean_latency_s > 0.0);
        }
        // A graph index under a wide filter still scores against the
        // filtered ground truth and lands in a sane recall band.
        let hnsw = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        let half = FilterBitset::from_predicate(600, |id| id % 2 == 0);
        let p = measure_filtered_point(&hnsw, &ds, 10, 128, &half);
        assert!(p.recall > 0.8, "filtered hnsw recall {}", p.recall);
    }

    #[test]
    fn hnsw_sweep_recall_increases_with_ef() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 62);
        ds.compute_ground_truth(10);
        let idx = crate::anns::hnsw::HnswIndex::build(
            VectorSet::from_dataset(&ds),
            &crate::variants::ConstructionKnobs::default(),
            crate::variants::SearchKnobs::default(),
            1,
        );
        let res = sweep_index(&idx, &ds, 10, &[10, 64, 256], 0.0);
        assert!(res.points[2].recall >= res.points[0].recall);
        assert!(res.points[2].recall > 0.9);
    }
}
