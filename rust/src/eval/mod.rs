//! Evaluation harness: ann-benchmarks-style ef sweeps, QPS/recall curves,
//! fixed-recall interpolation (Table 3), and report writers.

pub mod harness;
pub mod report;
pub mod sweep;

pub use sweep::{
    batch_mode, measure_filtered_point, measure_mutations, measure_point,
    measure_point_with_mode, sweep_index, CurvePoint, MutationStats, SweepResult,
};

/// Default ef sweep grid (ann-benchmarks-like spacing).
pub const DEFAULT_EF_GRID: &[usize] = &[10, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512];

/// Interpolate QPS at a fixed recall from a (recall-sorted) curve.
/// Linear in (recall, log QPS) between the bracketing points — the
/// standard way Table-3-style numbers are read off Figure-1-style curves.
/// Returns `None` when the curve never reaches `target`.
pub fn qps_at_recall(points: &[CurvePoint], target: f64) -> Option<f64> {
    let mut pts: Vec<&CurvePoint> = points.iter().collect();
    pts.sort_by(|a, b| a.recall.partial_cmp(&b.recall).unwrap());
    // Best (max) QPS among points at/above target, interpolated at the
    // crossing for fairness.
    let above: Vec<&&CurvePoint> = pts.iter().filter(|p| p.recall >= target).collect();
    if above.is_empty() {
        return None;
    }
    // Find bracketing pair (last below, first above).
    let below: Option<&&CurvePoint> = pts.iter().rev().find(|p| p.recall < target);
    let hi = above
        .iter()
        .max_by(|a, b| a.qps.partial_cmp(&b.qps).unwrap())
        .unwrap();
    match below {
        None => Some(hi.qps),
        Some(lo) => {
            // Interpolate between lo and the *first* point above target in
            // recall order (the pareto neighbor), in log-QPS space.
            let first_above = above
                .iter()
                .min_by(|a, b| a.recall.partial_cmp(&b.recall).unwrap())
                .unwrap();
            if first_above.recall == lo.recall {
                return Some(first_above.qps);
            }
            let t = (target - lo.recall) / (first_above.recall - lo.recall);
            let lq = lo.qps.max(1e-9).ln();
            let hq = first_above.qps.max(1e-9).ln();
            let interp = (lq + t * (hq - lq)).exp();
            // Never report more than the best measured point above target.
            Some(interp.max(first_above.qps.min(hi.qps)))
        }
    }
}

/// Reduce a curve to its pareto frontier (max QPS per recall level),
/// recall-ascending. Matches how ann-benchmarks plots Figure 1.
pub fn pareto_frontier(points: &[CurvePoint]) -> Vec<CurvePoint> {
    let mut pts = points.to_vec();
    pts.sort_by(|a, b| {
        a.recall
            .partial_cmp(&b.recall)
            .unwrap()
            .then(b.qps.partial_cmp(&a.qps).unwrap())
    });
    // One point per recall level: the fastest.
    pts.dedup_by(|b, a| {
        if a.recall == b.recall {
            if b.qps > a.qps {
                a.qps = b.qps;
            }
            true
        } else {
            false
        }
    });
    let mut out: Vec<CurvePoint> = Vec::new();
    for p in pts.into_iter().rev() {
        // iterate recall-descending; keep if QPS exceeds all kept so far
        if out.last().map(|l: &CurvePoint| p.qps > l.qps).unwrap_or(true) {
            out.push(p);
        }
    }
    out.reverse();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(recall: f64, qps: f64) -> CurvePoint {
        CurvePoint {
            ef: 0,
            recall,
            qps,
            mean_latency_s: 1.0 / qps,
            p99_latency_s: 1.0 / qps,
        }
    }

    #[test]
    fn qps_at_recall_interpolates() {
        let curve = vec![pt(0.80, 10_000.0), pt(0.90, 5_000.0), pt(0.99, 1_000.0)];
        let q = qps_at_recall(&curve, 0.85).unwrap();
        assert!(q < 10_000.0 && q > 5_000.0, "q={q}");
        assert_eq!(qps_at_recall(&curve, 0.999), None);
        let exact = qps_at_recall(&curve, 0.90).unwrap();
        assert!((exact - 5_000.0).abs() < 1e-6);
    }

    #[test]
    fn qps_at_recall_all_above() {
        let curve = vec![pt(0.95, 4_000.0), pt(0.99, 1_000.0)];
        assert_eq!(qps_at_recall(&curve, 0.90), Some(4_000.0));
    }

    #[test]
    fn pareto_removes_dominated() {
        let curve = vec![
            pt(0.8, 9_000.0),
            pt(0.85, 10_000.0), // dominates the previous
            pt(0.9, 6_000.0),
            pt(0.92, 7_000.0), // dominates the previous
            pt(0.99, 1_000.0),
        ];
        let front = pareto_frontier(&curve);
        let recalls: Vec<f64> = front.iter().map(|p| p.recall).collect();
        assert_eq!(recalls, vec![0.85, 0.92, 0.99]);
        for w in front.windows(2) {
            assert!(w[0].qps > w[1].qps);
            assert!(w[0].recall < w[1].recall);
        }
    }
}
