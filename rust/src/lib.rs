//! # CRINN — Contrastive Reinforcement Learning for ANNS (reproduction)
//!
//! Full-system reproduction of *CRINN: Contrastive Reinforcement Learning
//! for Approximate Nearest Neighbor Search* (cs.LG 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the request-path coordinator: a complete ANNS
//!   engine (HNSW / GLASS / NN-Descent / Vamana / IVF / brute force), the
//!   CRINN contrastive-RL optimization loop (reward, exemplar database,
//!   GRPO trainer), a batching/sharding serving layer, and the
//!   ann-benchmarks-style evaluation harness.
//! * **L2/L1 (python/, build-time only)** — JAX compute graphs calling
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/` and executed
//!   from [`runtime`] via the PJRT C API. Python never runs at request
//!   time.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! | module | role |
//! |---|---|
//! | [`util`] | substrates rebuilt from scratch (rng, json, threadpool, cli, bench) |
//! | [`distance`] | runtime-dispatched SIMD f32 + int8-quantized kernels, one-to-many batch API |
//! | [`dataset`] | Table-2-matched synthetic generators, IO, LID, ground truth |
//! | [`anns`] | index implementations incl. the GLASS starting point |
//! | [`variants`] | the §6 optimization-knob space (CRINN's action space) |
//! | [`crinn`] | the paper's contribution: contrastive RL over ANNS modules |
//! | [`runtime`] | PJRT engine: loads `artifacts/*.hlo.txt`, executes |
//! | [`coordinator`] | dynamic batcher + sharded router + query server |
//! | [`eval`] | ef sweeps, recall/QPS curves, fixed-recall tables, reports |
//!
//! ## Example
//!
//! Build an exact index over four 2-d points and query it:
//!
//! ```
//! use crinn::anns::{bruteforce::BruteForceIndex, AnnIndex, VectorSet};
//! use crinn::distance::Metric;
//!
//! let vs = VectorSet::new(vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 5.0, 5.0], 2, Metric::L2);
//! let index = BruteForceIndex::build(vs);
//! assert_eq!(index.len(), 4);
//! assert_eq!(index.search(&[0.2, 0.1], 2, 0), vec![0, 1]);
//!
//! // The trait is distance-carrying and batch-first: `search_with_dists`
//! // returns exact (dist, id) pairs, and `search_batch` answers a whole
//! // query batch with results bitwise identical to per-query calls.
//! let q: &[f32] = &[0.2, 0.1];
//! let batched = index.search_batch(&[q, q], 2, 0);
//! assert_eq!(batched[0], index.search_with_dists(q, 2, 0));
//! ```

pub mod anns;
pub mod coordinator;
pub mod crinn;
pub mod dataset;
pub mod distance;
pub mod eval;
pub mod runtime;
pub mod util;
pub mod variants;

pub use util::error::Error;

/// Crate-wide result type.
pub type Result<T> = util::error::Result<T>;

/// Default number of neighbors (k) used across benches — matches
/// ann-benchmarks' k=10 protocol that the paper's Figure 1 uses.
pub const DEFAULT_K: usize = 10;
