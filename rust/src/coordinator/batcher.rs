//! Dynamic batcher: vLLM-router-style accumulate-until-full-or-deadline.
//!
//! Requests arrive on a channel; the batching loop drains up to
//! `max_batch` of them, waiting at most `max_wait` after the first arrival
//! — the standard throughput/latency dial for batched ANN serving.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// Drain one batch from `rx`. Blocks for the first item (or returns `None`
/// when the channel is closed), then collects follow-ups until the batch
/// fills or the deadline passes.
pub fn next_batch<T>(rx: &Receiver<T>, policy: &BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Like [`next_batch`], but the wait for the *first* element polls `stop`:
/// returns `None` once `stop` is set and the queue is drained, even while
/// senders keep the channel open (live [`super::server::ServerHandle`]s).
pub fn next_batch_or_stop<T>(
    rx: &Receiver<T>,
    policy: &BatchPolicy,
    stop: &std::sync::atomic::AtomicBool,
) -> Option<Vec<T>> {
    use std::sync::atomic::Ordering;
    let first = loop {
        match rx.recv_timeout(Duration::from_millis(10)) {
            Ok(item) => break item,
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return None;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    };
    let mut batch = Vec::with_capacity(policy.max_batch);
    batch.push(first);
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(_) => break,
        }
    }
    Some(batch)
}

/// Partition a drained batch into groups sharing a key (the server groups
/// by `(k, ef)` so each group can go through one `search_batch` call).
/// Groups appear in first-seen order and items keep arrival order within
/// their group; a uniform batch stays a single group, so the common case
/// is one multi-query search per drained batch. Linear scan over the
/// group list — batches are small (≤ `max_batch`) and distinct keys rare.
pub fn group_by_key<T, K: PartialEq>(
    items: Vec<T>,
    key: impl Fn(&T) -> K,
) -> Vec<(K, Vec<T>)> {
    group_precomputed(items.into_iter().map(|item| (key(&item), item)).collect())
}

/// [`group_by_key`] over items whose keys were already computed — the
/// server precomputes one fingerprinted key per search request so group
/// membership tests never re-derive (or clone) anything per comparison.
/// Same contracts: groups in first-seen key order, arrival order within a
/// group, and the output is a partition of the input.
pub fn group_precomputed<K: PartialEq, T>(items: Vec<(K, T)>) -> Vec<(K, Vec<T>)> {
    let mut groups: Vec<(K, Vec<T>)> = Vec::new();
    for (k, item) in items {
        match groups.iter_mut().find(|(gk, _)| *gk == k) {
            Some((_, g)) => g.push(item),
            None => groups.push((k, vec![item])),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn fills_batch_when_items_ready() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(50),
        };
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![4, 5, 6, 7]);
    }

    #[test]
    fn deadline_cuts_partial_batch() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        let policy = BatchPolicy {
            max_batch: 100,
            max_wait: Duration::from_millis(5),
        };
        let t = Instant::now();
        let b = next_batch(&rx, &policy).unwrap();
        assert_eq!(b, vec![1]);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatchPolicy::default()).is_none());
    }

    #[test]
    fn group_by_key_preserves_order_and_splits_keys() {
        let items = vec![(10, 'a'), (20, 'b'), (10, 'c'), (30, 'd'), (20, 'e')];
        let groups = group_by_key(items, |&(k, _)| k);
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0], (10, vec![(10, 'a'), (10, 'c')]));
        assert_eq!(groups[1], (20, vec![(20, 'b'), (20, 'e')]));
        assert_eq!(groups[2], (30, vec![(30, 'd')]));
        // Uniform batch: one group, order untouched.
        let uniform = group_by_key(vec![1, 2, 3], |_| 0);
        assert_eq!(uniform, vec![(0, vec![1, 2, 3])]);
        assert!(group_by_key(Vec::<u8>::new(), |_| 0).is_empty());
    }

    #[test]
    fn group_by_key_is_a_stable_partition() {
        // Randomized check of the three contracts the server relies on:
        // (1) keys appear in first-seen order, (2) items keep arrival
        // order within their group (stability — responses are zipped back
        // positionally), (3) the groups are a partition: every item
        // appears exactly once and nothing is invented.
        use crate::util::rng::Rng;
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed ^ 0x6B0B);
            let n = rng.next_below(64);
            let items: Vec<(usize, usize)> =
                (0..n).map(|i| (rng.next_below(5), i)).collect();
            let groups = group_by_key(items.clone(), |&(k, _)| k);
            // (1) first-seen key order, no duplicate keys.
            let mut seen_keys = Vec::new();
            for &(k, _) in &items {
                if !seen_keys.contains(&k) {
                    seen_keys.push(k);
                }
            }
            let group_keys: Vec<usize> = groups.iter().map(|&(k, _)| k).collect();
            assert_eq!(group_keys, seen_keys, "seed {seed}");
            // (2) stability: each group equals the order-preserving filter.
            for (k, g) in &groups {
                let want: Vec<(usize, usize)> =
                    items.iter().copied().filter(|&(ik, _)| ik == *k).collect();
                assert_eq!(g, &want, "seed {seed} key {k}");
            }
            // (3) partition: concatenation is a permutation that restores
            // the original order under a stable sort by arrival index.
            let total: usize = groups.iter().map(|(_, g)| g.len()).sum();
            assert_eq!(total, items.len(), "seed {seed}");
            let mut flat: Vec<(usize, usize)> =
                groups.into_iter().flat_map(|(_, g)| g).collect();
            flat.sort_by_key(|&(_, i)| i);
            assert_eq!(flat, items, "seed {seed}");
        }
    }

    #[test]
    fn group_by_key_single_and_all_distinct() {
        // Degenerate shapes: every key distinct (one group per item, in
        // arrival order) and every key equal (one group, order untouched).
        let distinct = group_by_key(vec![(3, 'a'), (1, 'b'), (2, 'c')], |&(k, _)| k);
        assert_eq!(
            distinct,
            vec![
                (3, vec![(3, 'a')]),
                (1, vec![(1, 'b')]),
                (2, vec![(2, 'c')])
            ]
        );
        let same = group_by_key(vec![5, 6, 7, 8], |_| 42);
        assert_eq!(same, vec![(42, vec![5, 6, 7, 8])]);
    }

    #[test]
    fn group_precomputed_matches_group_by_key() {
        // The precomputed-key path must be the same stable partition the
        // closure path produces — the server switched to it for the
        // filter-fingerprint keys and the property suite rides on both.
        use crate::util::rng::Rng;
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed ^ 0x9409);
            let n = rng.next_below(64);
            let items: Vec<(usize, usize)> =
                (0..n).map(|i| (rng.next_below(5), i)).collect();
            let via_closure = group_by_key(items.clone(), |&(k, _)| k);
            let via_precomputed =
                group_precomputed(items.into_iter().map(|it| (it.0, it)).collect());
            assert_eq!(via_closure, via_precomputed, "seed {seed}");
        }
    }

    #[test]
    fn closed_mid_batch_returns_partial() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(
            &rx,
            &BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_millis(50),
            },
        )
        .unwrap();
        assert_eq!(b, vec![7]);
    }
}
