//! Wire protocol for the network serving edge: length-prefixed,
//! checksummed binary frames mirroring [`super::server::QueryRequest`].
//!
//! ## Frame layout
//!
//! ```text
//! [magic: u32 LE = "CRN1"] [len: u32 LE] [crc: u64 LE] [payload: len bytes]
//! ```
//!
//! `crc` is the storage tier's FNV-1a-64 (`persist::sections::checksum`)
//! over the payload. The payload starts `[version: u8] [kind: u8]
//! [request_id: u64 LE]`; request payloads continue `[tenant: str]
//! [deadline_ms: u32]` then the kind-specific body, response payloads go
//! straight to the body. Strings are `[len: u32 LE][utf-8 bytes]` and
//! capped at [`MAX_STR`]; filter expressions are a tagged recursive
//! encoding with depth and node budgets.
//!
//! ## Hostility discipline
//!
//! Decoding follows the persist tier's byte-patch rules: every length is
//! validated *before* any allocation (an oversized frame length is an
//! error the moment the header is readable — the reader never buffers
//! toward it, so hostile lengths cannot OOM), every payload must be
//! consumed exactly, and any violation is an `Err` — never a panic. The
//! checksum rejects corruption; the structural checks reject everything a
//! colliding or hand-built payload could still try.

use crate::anns::persist::sections::checksum;
use crate::anns::FilterExpr;
use crate::util::error::Result;

/// Frame magic: `b"CRN1"` little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"CRN1");
/// Protocol version carried in every payload.
pub const VERSION: u8 = 1;
/// `magic + len + crc`.
pub const FRAME_HEADER: usize = 16;
/// Hard cap on a frame payload — anything larger is hostile.
pub const MAX_PAYLOAD: usize = 1 << 20;
/// Hard cap on any string field (tenant, tag, error message, counter name).
pub const MAX_STR: usize = 4096;
/// Hard cap on a query/insert vector's dimension.
pub const MAX_DIM: usize = 65_536;
/// Caps on search parameters (sanity, not tuning).
pub const MAX_K: usize = 65_536;
pub const MAX_EF: usize = 1 << 20;
/// Filter expression budgets (match the storage tier's hostile-input
/// posture: bounded recursion, bounded fan-out).
pub const MAX_FILTER_DEPTH: usize = 8;
pub const MAX_FILTER_NODES: usize = 256;
/// Cap on metrics counter entries in one response.
pub const MAX_COUNTERS: usize = 4096;

/// Request payload kinds.
pub const REQ_SEARCH: u8 = 1;
pub const REQ_INSERT: u8 = 2;
pub const REQ_DELETE: u8 = 3;
pub const REQ_METRICS: u8 = 4;
/// Response payload kinds.
pub const RESP_SEARCH: u8 = 0x81;
pub const RESP_MUTATION: u8 = 0x82;
pub const RESP_METRICS: u8 = 0x83;
pub const RESP_OVERLOADED: u8 = 0x84;
pub const RESP_ERROR: u8 = 0xE0;

/// Error codes carried by [`Response::Error`].
pub const ERR_MALFORMED: u8 = 1;
/// Rejected at admission (queue full or server stopping).
pub const ERR_REJECTED: u8 = 2;
/// Accepted but dropped unserved (deadline passed, shutdown drain).
pub const ERR_DROPPED: u8 = 3;
pub const ERR_UNSUPPORTED: u8 = 4;

/// One decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    /// Client-chosen correlation id, echoed on the response.
    pub request_id: u64,
    /// Admission identity: the token bucket charges this tenant.
    pub tenant: String,
    /// Serve-by budget in milliseconds from arrival; 0 = no deadline.
    pub deadline_ms: u32,
    pub body: Request,
}

/// The request body, mirroring `QueryRequest`.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Search {
        k: usize,
        ef: usize,
        filter: Option<FilterExpr>,
        query: Vec<f32>,
    },
    Insert {
        /// Metadata tenant recorded for the assigned id (independent of
        /// the frame's admission tenant, though clients usually match).
        tenant: Option<String>,
        tags: Vec<String>,
        vector: Vec<f32>,
    },
    Delete {
        id: u32,
    },
    Metrics,
}

/// The response body.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Search {
        ids: Vec<u32>,
        dists: Vec<f32>,
        latency_s: f64,
    },
    Mutation {
        result: std::result::Result<u32, String>,
        latency_s: f64,
    },
    Metrics {
        counters: Vec<(String, u64)>,
    },
    /// Admission rejected the request before it touched the queue.
    Overloaded { retry_after_ms: u32 },
    Error { code: u8, message: String },
}

/// Encode one request frame (header + checksummed payload).
pub fn encode_request(frame: &RequestFrame) -> Vec<u8> {
    let kind = match frame.body {
        Request::Search { .. } => REQ_SEARCH,
        Request::Insert { .. } => REQ_INSERT,
        Request::Delete { .. } => REQ_DELETE,
        Request::Metrics => REQ_METRICS,
    };
    let mut p = Vec::new();
    p.push(VERSION);
    p.push(kind);
    p.extend_from_slice(&frame.request_id.to_le_bytes());
    put_str(&mut p, &frame.tenant);
    p.extend_from_slice(&frame.deadline_ms.to_le_bytes());
    match &frame.body {
        Request::Search {
            k,
            ef,
            filter,
            query,
        } => {
            p.extend_from_slice(&(*k as u32).to_le_bytes());
            p.extend_from_slice(&(*ef as u32).to_le_bytes());
            put_filter(&mut p, filter.as_ref());
            put_vector(&mut p, query);
        }
        Request::Insert {
            tenant,
            tags,
            vector,
        } => {
            match tenant {
                Some(t) => {
                    p.push(1);
                    put_str(&mut p, t);
                }
                None => p.push(0),
            }
            p.extend_from_slice(&(tags.len() as u32).to_le_bytes());
            for t in tags {
                put_str(&mut p, t);
            }
            put_vector(&mut p, vector);
        }
        Request::Delete { id } => p.extend_from_slice(&id.to_le_bytes()),
        Request::Metrics => {}
    }
    seal(p)
}

/// Encode one response frame for `request_id`.
pub fn encode_response(request_id: u64, resp: &Response) -> Vec<u8> {
    let kind = match resp {
        Response::Search { .. } => RESP_SEARCH,
        Response::Mutation { .. } => RESP_MUTATION,
        Response::Metrics { .. } => RESP_METRICS,
        Response::Overloaded { .. } => RESP_OVERLOADED,
        Response::Error { .. } => RESP_ERROR,
    };
    let mut p = Vec::new();
    p.push(VERSION);
    p.push(kind);
    p.extend_from_slice(&request_id.to_le_bytes());
    match resp {
        Response::Search {
            ids,
            dists,
            latency_s,
        } => {
            p.extend_from_slice(&(ids.len() as u32).to_le_bytes());
            for id in ids {
                p.extend_from_slice(&id.to_le_bytes());
            }
            for d in dists {
                p.extend_from_slice(&d.to_le_bytes());
            }
            p.extend_from_slice(&latency_s.to_le_bytes());
        }
        Response::Mutation { result, latency_s } => {
            match result {
                Ok(id) => {
                    p.push(1);
                    p.extend_from_slice(&id.to_le_bytes());
                }
                Err(msg) => {
                    p.push(0);
                    // Hard-cap the echoed error so a pathological message
                    // cannot blow the payload budget.
                    let msg: String = msg.chars().take(MAX_STR / 4).collect();
                    put_str(&mut p, &msg);
                }
            }
            p.extend_from_slice(&latency_s.to_le_bytes());
        }
        Response::Metrics { counters } => {
            p.extend_from_slice(&(counters.len() as u32).to_le_bytes());
            for (name, value) in counters {
                put_str(&mut p, name);
                p.extend_from_slice(&value.to_le_bytes());
            }
        }
        Response::Overloaded { retry_after_ms } => {
            p.extend_from_slice(&retry_after_ms.to_le_bytes());
        }
        Response::Error { code, message } => {
            p.push(*code);
            let message: String = message.chars().take(MAX_STR / 4).collect();
            put_str(&mut p, &message);
        }
    }
    seal(p)
}

/// Try to split one frame off the front of `buf`.
///
/// * `Ok(None)` — `buf` holds a valid prefix of an incomplete frame;
///   read more bytes.
/// * `Ok(Some((payload, consumed)))` — one whole frame: its checksummed
///   payload, and the total bytes (header + payload) to drain.
/// * `Err` — hostile input (bad magic, oversized length, checksum
///   mismatch): close the connection. Oversized lengths error as soon as
///   the header is readable, before any buffering toward them.
pub fn split_frame(buf: &[u8]) -> Result<Option<(&[u8], usize)>> {
    if buf.len() >= 4 {
        let magic = u32::from_le_bytes(buf[..4].try_into().unwrap());
        crate::ensure!(magic == MAGIC, "bad frame magic {magic:#010x}");
    }
    if buf.len() < FRAME_HEADER {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[4..8].try_into().unwrap()) as usize;
    crate::ensure!(len <= MAX_PAYLOAD, "frame payload of {len} bytes exceeds cap");
    if buf.len() < FRAME_HEADER + len {
        return Ok(None);
    }
    let crc = u64::from_le_bytes(buf[8..16].try_into().unwrap());
    let payload = &buf[FRAME_HEADER..FRAME_HEADER + len];
    crate::ensure!(checksum(payload) == crc, "frame checksum mismatch");
    Ok(Some((payload, FRAME_HEADER + len)))
}

/// Best-effort request id from a (possibly undecodable) payload, for
/// error frames that should still echo the client's correlation id.
/// Returns 0 when the payload is too short to carry one.
pub fn peek_request_id(payload: &[u8]) -> u64 {
    match payload.get(2..10) {
        Some(b) => u64::from_le_bytes(b.try_into().unwrap()),
        None => 0,
    }
}

/// Decode a request payload (as returned by [`split_frame`]).
pub fn decode_request(payload: &[u8]) -> Result<RequestFrame> {
    let mut c = Cursor(payload);
    let version = c.u8()?;
    crate::ensure!(version == VERSION, "unsupported protocol version {version}");
    let kind = c.u8()?;
    let request_id = c.u64()?;
    let tenant = c.string()?;
    let deadline_ms = c.u32()?;
    let body = match kind {
        REQ_SEARCH => {
            let k = c.u32()? as usize;
            let ef = c.u32()? as usize;
            crate::ensure!(k >= 1 && k <= MAX_K, "search k={k} out of range");
            crate::ensure!(ef <= MAX_EF, "search ef={ef} out of range");
            let filter = take_filter(&mut c)?;
            let query = c.vector()?;
            Request::Search {
                k,
                ef,
                filter,
                query,
            }
        }
        REQ_INSERT => {
            let tenant = match c.u8()? {
                0 => None,
                1 => Some(c.string()?),
                b => crate::bail!("insert has bad tenant marker {b}"),
            };
            let n = c.u32()? as usize;
            crate::ensure!(n <= MAX_FILTER_NODES, "insert claims {n} tags");
            let mut tags = Vec::with_capacity(n);
            for _ in 0..n {
                tags.push(c.string()?);
            }
            let vector = c.vector()?;
            Request::Insert {
                tenant,
                tags,
                vector,
            }
        }
        REQ_DELETE => Request::Delete { id: c.u32()? },
        REQ_METRICS => Request::Metrics,
        k => crate::bail!("unknown request kind {k:#04x}"),
    };
    crate::ensure!(c.0.is_empty(), "trailing bytes in request payload");
    Ok(RequestFrame {
        request_id,
        tenant,
        deadline_ms,
        body,
    })
}

/// Decode a response payload: `(echoed request id, body)`.
pub fn decode_response(payload: &[u8]) -> Result<(u64, Response)> {
    let mut c = Cursor(payload);
    let version = c.u8()?;
    crate::ensure!(version == VERSION, "unsupported protocol version {version}");
    let kind = c.u8()?;
    let request_id = c.u64()?;
    let body = match kind {
        RESP_SEARCH => {
            let n = c.u32()? as usize;
            crate::ensure!(n <= MAX_K, "search response claims {n} results");
            let mut ids = Vec::with_capacity(n);
            for _ in 0..n {
                ids.push(c.u32()?);
            }
            let mut dists = Vec::with_capacity(n);
            for _ in 0..n {
                dists.push(f32::from_le_bytes(c.take(4)?.try_into().unwrap()));
            }
            Response::Search {
                ids,
                dists,
                latency_s: c.f64()?,
            }
        }
        RESP_MUTATION => {
            let result = match c.u8()? {
                1 => Ok(c.u32()?),
                0 => Err(c.string()?),
                b => crate::bail!("mutation response has bad status {b}"),
            };
            Response::Mutation {
                result,
                latency_s: c.f64()?,
            }
        }
        RESP_METRICS => {
            let n = c.u32()? as usize;
            crate::ensure!(n <= MAX_COUNTERS, "metrics response claims {n} counters");
            let mut counters = Vec::with_capacity(n);
            for _ in 0..n {
                let name = c.string()?;
                counters.push((name, c.u64()?));
            }
            Response::Metrics { counters }
        }
        RESP_OVERLOADED => Response::Overloaded {
            retry_after_ms: c.u32()?,
        },
        RESP_ERROR => Response::Error {
            code: c.u8()?,
            message: c.string()?,
        },
        k => crate::bail!("unknown response kind {k:#04x}"),
    };
    crate::ensure!(c.0.is_empty(), "trailing bytes in response payload");
    Ok((request_id, body))
}

/// Wrap a payload in `[magic][len][crc]`.
fn seal(payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_PAYLOAD, "encoder built an oversized payload");
    let mut frame = Vec::with_capacity(FRAME_HEADER + payload.len());
    frame.extend_from_slice(&MAGIC.to_le_bytes());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(&checksum(&payload).to_le_bytes());
    frame.extend_from_slice(&payload);
    frame
}

fn put_str(p: &mut Vec<u8>, s: &str) {
    p.extend_from_slice(&(s.len() as u32).to_le_bytes());
    p.extend_from_slice(s.as_bytes());
}

fn put_vector(p: &mut Vec<u8>, v: &[f32]) {
    p.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        p.extend_from_slice(&x.to_le_bytes());
    }
}

/// `[0]` for none, `[1][expr]` for some; expr nodes are `[1][str]`
/// tenant, `[2][str]` tag, `[3][n: u32][exprs…]` conjunction.
fn put_filter(p: &mut Vec<u8>, f: Option<&FilterExpr>) {
    match f {
        None => p.push(0),
        Some(f) => {
            p.push(1);
            put_expr(p, f);
        }
    }
}

fn put_expr(p: &mut Vec<u8>, f: &FilterExpr) {
    match f {
        FilterExpr::Tenant(name) => {
            p.push(1);
            put_str(p, name);
        }
        FilterExpr::HasTag(name) => {
            p.push(2);
            put_str(p, name);
        }
        FilterExpr::And(parts) => {
            p.push(3);
            p.extend_from_slice(&(parts.len() as u32).to_le_bytes());
            for part in parts {
                put_expr(p, part);
            }
        }
    }
}

fn take_filter(c: &mut Cursor<'_>) -> Result<Option<FilterExpr>> {
    match c.u8()? {
        0 => Ok(None),
        1 => {
            let mut nodes = 0usize;
            Ok(Some(take_expr(c, 0, &mut nodes)?))
        }
        b => crate::bail!("bad filter marker {b}"),
    }
}

fn take_expr(c: &mut Cursor<'_>, depth: usize, nodes: &mut usize) -> Result<FilterExpr> {
    crate::ensure!(depth < MAX_FILTER_DEPTH, "filter expression nested too deep");
    *nodes += 1;
    crate::ensure!(*nodes <= MAX_FILTER_NODES, "filter expression too large");
    match c.u8()? {
        1 => Ok(FilterExpr::Tenant(c.string()?)),
        2 => Ok(FilterExpr::HasTag(c.string()?)),
        3 => {
            let n = c.u32()? as usize;
            crate::ensure!(n <= MAX_FILTER_NODES, "filter conjunction claims {n} parts");
            let mut parts = Vec::with_capacity(n);
            for _ in 0..n {
                parts.push(take_expr(c, depth + 1, nodes)?);
            }
            Ok(FilterExpr::And(parts))
        }
        t => crate::bail!("unknown filter node tag {t}"),
    }
}

/// Bounds-checked cursor (the WAL's, with the protocol's caps).
struct Cursor<'a>(&'a [u8]);

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(self.0.len() >= n, "payload truncated");
        let (head, rest) = self.0.split_at(n);
        self.0 = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        crate::ensure!(n <= MAX_STR, "string field of {n} bytes exceeds cap");
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| crate::util::error::Error::msg("string field is not UTF-8".into()))
    }

    fn vector(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        crate::ensure!(n >= 1 && n <= MAX_DIM, "vector dimension {n} out of range");
        let bytes = self.take(n * 4)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_requests() -> Vec<RequestFrame> {
        vec![
            RequestFrame {
                request_id: 1,
                tenant: "acme".to_string(),
                deadline_ms: 250,
                body: Request::Search {
                    k: 5,
                    ef: 64,
                    filter: None,
                    query: vec![0.25, -1.5, 3.0],
                },
            },
            RequestFrame {
                request_id: u64::MAX,
                tenant: String::new(),
                deadline_ms: 0,
                body: Request::Search {
                    k: 1,
                    ef: 0,
                    filter: Some(FilterExpr::and(vec![
                        FilterExpr::tenant("t1"),
                        FilterExpr::tag("hot"),
                        FilterExpr::and(vec![]),
                    ])),
                    query: vec![1.0],
                },
            },
            RequestFrame {
                request_id: 7,
                tenant: "acme".to_string(),
                deadline_ms: 100,
                body: Request::Insert {
                    tenant: Some("t1".to_string()),
                    tags: vec!["hot".to_string(), "eu".to_string()],
                    vector: vec![9.0, -0.0],
                },
            },
            RequestFrame {
                request_id: 8,
                tenant: "b".to_string(),
                deadline_ms: 0,
                body: Request::Insert {
                    tenant: None,
                    tags: vec![],
                    vector: vec![1.0, 2.0],
                },
            },
            RequestFrame {
                request_id: 9,
                tenant: "acme".to_string(),
                deadline_ms: 50,
                body: Request::Delete { id: 42 },
            },
            RequestFrame {
                request_id: 10,
                tenant: "ops".to_string(),
                deadline_ms: 0,
                body: Request::Metrics,
            },
        ]
    }

    fn sample_responses() -> Vec<Response> {
        vec![
            Response::Search {
                ids: vec![3, 1, 4],
                dists: vec![0.0, 0.5, 2.25],
                latency_s: 0.0015,
            },
            Response::Search {
                ids: vec![],
                dists: vec![],
                latency_s: 0.0,
            },
            Response::Mutation {
                result: Ok(400),
                latency_s: 0.25,
            },
            Response::Mutation {
                result: Err("applied but not logged: boom".to_string()),
                latency_s: 0.1,
            },
            Response::Metrics {
                counters: vec![
                    ("requests".to_string(), 100),
                    ("tenant.acme.admits".to_string(), 7),
                ],
            },
            Response::Overloaded { retry_after_ms: 40 },
            Response::Error {
                code: ERR_DROPPED,
                message: "dropped unserved".to_string(),
            },
        ]
    }

    #[test]
    fn request_frames_round_trip() {
        for want in sample_requests() {
            let frame = encode_request(&want);
            let (payload, consumed) = split_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len());
            assert_eq!(peek_request_id(payload), want.request_id);
            let got = decode_request(payload).unwrap();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn response_frames_round_trip() {
        for want in sample_responses() {
            let frame = encode_response(99, &want);
            let (payload, consumed) = split_frame(&frame).unwrap().unwrap();
            assert_eq!(consumed, frame.len());
            let (id, got) = decode_response(payload).unwrap();
            assert_eq!(id, 99);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn split_waits_for_whole_frames() {
        // Feeding a valid frame byte by byte: every proper prefix is
        // `Ok(None)`, the whole thing splits, and two frames
        // back-to-back split one at a time.
        let frame = encode_request(&sample_requests()[0]);
        for cut in 0..frame.len() {
            assert!(
                split_frame(&frame[..cut]).unwrap().is_none(),
                "prefix of {cut} bytes should be incomplete"
            );
        }
        let mut two = frame.clone();
        two.extend_from_slice(&encode_request(&sample_requests()[4]));
        let (_, consumed) = split_frame(&two).unwrap().unwrap();
        assert_eq!(consumed, frame.len());
        let (payload, _) = split_frame(&two[consumed..]).unwrap().unwrap();
        assert_eq!(decode_request(payload).unwrap().body, Request::Delete { id: 42 });
    }

    #[test]
    fn hostile_frames_error_without_panics() {
        // Bad magic: rejected as soon as 4 bytes are readable.
        assert!(split_frame(b"EVIL").is_err());
        // Oversized length: rejected at the header, long before the
        // claimed bytes could be buffered.
        let mut huge = Vec::new();
        huge.extend_from_slice(&MAGIC.to_le_bytes());
        huge.extend_from_slice(&(u32::MAX).to_le_bytes());
        huge.extend_from_slice(&0u64.to_le_bytes());
        assert!(split_frame(&huge).is_err());
        // Corrupt checksum.
        let mut frame = encode_request(&sample_requests()[0]);
        let n = frame.len();
        frame[n - 1] ^= 0x01;
        assert!(split_frame(&frame).is_err());
    }

    #[test]
    fn byte_patch_never_panics_or_wrongly_equals() {
        // The persist tier's discipline applied to the wire: flip each
        // byte of a valid frame — splitting/decoding must never panic,
        // and whenever it still decodes, it must not silently decode to
        // a *different* value while claiming to be the original (the
        // checksum makes accidental equality the only allowed outcome).
        for original in sample_requests() {
            let frame = encode_request(&original);
            for i in 0..frame.len() {
                let mut patched = frame.clone();
                patched[i] ^= 0x10;
                match split_frame(&patched) {
                    Err(_) => {}
                    Ok(None) => {} // length shrank; now an incomplete frame
                    Ok(Some((payload, _))) => {
                        if let Ok(got) = decode_request(payload) {
                            // The checksum survived the flip only if the
                            // flip landed in ignorable territory; a decode
                            // that differs from the original would mean
                            // silent corruption.
                            assert_eq!(got, original, "byte {i} silently corrupted");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn filter_budgets_reject_hostile_expressions() {
        // Depth: a chain of nested single-child Ands past MAX_FILTER_DEPTH.
        let mut deep = FilterExpr::tenant("t");
        for _ in 0..MAX_FILTER_DEPTH + 1 {
            deep = FilterExpr::and(vec![deep]);
        }
        let frame = encode_request(&RequestFrame {
            request_id: 1,
            tenant: "a".to_string(),
            deadline_ms: 0,
            body: Request::Search {
                k: 1,
                ef: 0,
                filter: Some(deep),
                query: vec![1.0],
            },
        });
        let (payload, _) = split_frame(&frame).unwrap().unwrap();
        let err = format!("{:#}", decode_request(payload).unwrap_err());
        assert!(err.contains("nested too deep"), "{err}");
        // Node budget: a flat conjunction of too many leaves.
        let wide = FilterExpr::and(
            (0..MAX_FILTER_NODES).map(|_| FilterExpr::tag("t")).collect(),
        );
        let frame = encode_request(&RequestFrame {
            request_id: 1,
            tenant: "a".to_string(),
            deadline_ms: 0,
            body: Request::Search {
                k: 1,
                ef: 0,
                filter: Some(wide),
                query: vec![1.0],
            },
        });
        let (payload, _) = split_frame(&frame).unwrap().unwrap();
        let err = format!("{:#}", decode_request(payload).unwrap_err());
        assert!(err.contains("too large"), "{err}");
    }

    #[test]
    fn structural_caps_reject_out_of_range_fields() {
        // Hand-seal payloads (valid checksum!) so the structural checks
        // are what rejects them, not the crc.
        let reseal = |mutate: &dyn Fn(&mut Vec<u8>)| {
            let frame = encode_request(&sample_requests()[0]);
            let mut payload = frame[FRAME_HEADER..].to_vec();
            mutate(&mut payload);
            seal(payload)
        };
        // Wrong version.
        let f = reseal(&|p| p[0] = 9);
        let (payload, _) = split_frame(&f).unwrap().unwrap();
        assert!(decode_request(payload).is_err());
        // Unknown kind.
        let f = reseal(&|p| p[1] = 0x7F);
        let (payload, _) = split_frame(&f).unwrap().unwrap();
        assert!(decode_request(payload).is_err());
        // k = 0 is out of range.
        let f = reseal(&|p| {
            // [ver u8][kind u8][id u64][tenant len u32 + 4 bytes][deadline u32] → k at 22
            p[22..26].copy_from_slice(&0u32.to_le_bytes());
        });
        let (payload, _) = split_frame(&f).unwrap().unwrap();
        let err = format!("{:#}", decode_request(payload).unwrap_err());
        assert!(err.contains("out of range"), "{err}");
        // Oversized string length inside a valid frame.
        let f = reseal(&|p| {
            p[10..14].copy_from_slice(&(MAX_STR as u32 + 1).to_le_bytes());
        });
        let (payload, _) = split_frame(&f).unwrap().unwrap();
        let err = format!("{:#}", decode_request(payload).unwrap_err());
        assert!(err.contains("exceeds cap"), "{err}");
        // Trailing garbage after a well-formed body.
        let f = reseal(&|p| p.push(0));
        let (payload, _) = split_frame(&f).unwrap().unwrap();
        let err = format!("{:#}", decode_request(payload).unwrap_err());
        assert!(err.contains("trailing bytes"), "{err}");
    }

    #[test]
    fn peek_request_id_tolerates_short_payloads() {
        assert_eq!(peek_request_id(&[]), 0);
        assert_eq!(peek_request_id(&[1, 2, 3]), 0);
        let frame = encode_request(&sample_requests()[2]);
        assert_eq!(peek_request_id(&frame[FRAME_HEADER..]), 7);
    }
}
