//! Per-tenant admission control: token buckets in front of the bounded
//! serving queue. The network edge charges each decoded request to its
//! tenant's bucket *before* submission; an over-quota tenant gets an
//! explicit `Overloaded` frame (with a retry hint) instead of competing
//! for queue slots — one greedy client cannot starve the others, and the
//! rejection costs no index work at all.
//!
//! Deterministic by construction: refill depends only on the `now`
//! passed in, so tests drive time explicitly.

use std::collections::HashMap;
use std::time::Instant;

/// Token-bucket parameters, shared by every tenant.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Sustained requests/second per tenant; `<= 0` disables admission
    /// control entirely (every request admits).
    pub rate: f64,
    /// Burst capacity (bucket size) in requests. Clamped to at least 1
    /// so a positive rate can never configure a bucket that admits
    /// nothing.
    pub burst: f64,
    /// Cap on tracked tenants; once reached, unseen tenants share one
    /// overflow bucket (hostile tenant-id churn cannot grow the map
    /// unboundedly).
    pub max_tenants: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            rate: 0.0, // unlimited unless the operator opts in
            burst: 64.0,
            max_tenants: 1024,
        }
    }
}

/// The verdict for one request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Admission {
    Admit,
    /// Over quota; serve an `Overloaded` frame carrying this hint.
    Reject {
        /// Milliseconds until one token will have refilled.
        retry_after_ms: u32,
    },
}

struct Bucket {
    tokens: f64,
    last: Instant,
}

/// Per-tenant token buckets. Owned by the single net-front thread, so no
/// interior locking.
pub struct AdmissionController {
    config: AdmissionConfig,
    buckets: HashMap<String, Bucket>,
}

impl AdmissionController {
    pub fn new(config: AdmissionConfig) -> AdmissionController {
        AdmissionController {
            config,
            buckets: HashMap::new(),
        }
    }

    /// Charge one request to `tenant`'s bucket at time `now`.
    pub fn admit(&mut self, tenant: &str, now: Instant) -> Admission {
        if self.config.rate <= 0.0 {
            return Admission::Admit;
        }
        let burst = self.config.burst.max(1.0);
        let rate = self.config.rate;
        // Unseen tenants beyond the cap share the "" overflow bucket.
        let key = if self.buckets.contains_key(tenant)
            || self.buckets.len() < self.config.max_tenants.max(1)
        {
            tenant
        } else {
            ""
        };
        let bucket = self.buckets.entry(key.to_string()).or_insert(Bucket {
            tokens: burst,
            last: now,
        });
        // Refill for elapsed time (duration_since saturates to zero if a
        // caller ever hands in a stale `now`).
        let dt = now.saturating_duration_since(bucket.last).as_secs_f64();
        bucket.tokens = (bucket.tokens + dt * rate).min(burst);
        bucket.last = now;
        if bucket.tokens >= 1.0 {
            bucket.tokens -= 1.0;
            Admission::Admit
        } else {
            let deficit = 1.0 - bucket.tokens;
            let retry_after_ms = ((deficit / rate) * 1000.0).ceil().min(60_000.0) as u32;
            Admission::Reject { retry_after_ms }
        }
    }

    /// Tenants currently tracked (includes the overflow bucket once used).
    pub fn tracked(&self) -> usize {
        self.buckets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn controller(rate: f64, burst: f64) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            rate,
            burst,
            max_tenants: 4,
        })
    }

    #[test]
    fn zero_rate_admits_everything() {
        let mut c = controller(0.0, 1.0);
        let t0 = Instant::now();
        for _ in 0..1000 {
            assert_eq!(c.admit("a", t0), Admission::Admit);
        }
        assert_eq!(c.tracked(), 0, "unlimited mode tracks nothing");
    }

    #[test]
    fn burst_then_reject_then_refill() {
        let mut c = controller(10.0, 3.0);
        let t0 = Instant::now();
        // The full burst admits back-to-back...
        for i in 0..3 {
            assert_eq!(c.admit("a", t0), Admission::Admit, "burst slot {i}");
        }
        // ...then the empty bucket rejects with a sensible retry hint
        // (1 token at 10/s = 100ms).
        match c.admit("a", t0) {
            Admission::Reject { retry_after_ms } => {
                assert!((1..=200).contains(&retry_after_ms), "{retry_after_ms}");
            }
            a => panic!("expected reject, got {a:?}"),
        }
        // 100ms later exactly one token has refilled: admit, reject.
        let t1 = t0 + Duration::from_millis(100);
        assert_eq!(c.admit("a", t1), Admission::Admit);
        assert!(matches!(c.admit("a", t1), Admission::Reject { .. }));
    }

    #[test]
    fn tenants_are_isolated() {
        let mut c = controller(1.0, 1.0);
        let t0 = Instant::now();
        assert_eq!(c.admit("a", t0), Admission::Admit);
        assert!(matches!(c.admit("a", t0), Admission::Reject { .. }));
        // Tenant b is unaffected by a's empty bucket.
        assert_eq!(c.admit("b", t0), Admission::Admit);
    }

    #[test]
    fn refill_caps_at_burst() {
        let mut c = controller(100.0, 2.0);
        let t0 = Instant::now();
        assert_eq!(c.admit("a", t0), Admission::Admit);
        // A long idle stretch refills to burst (2), not rate * dt (200).
        let t1 = t0 + Duration::from_secs(2);
        assert_eq!(c.admit("a", t1), Admission::Admit);
        assert_eq!(c.admit("a", t1), Admission::Admit);
        assert!(matches!(c.admit("a", t1), Admission::Reject { .. }));
    }

    #[test]
    fn tenant_churn_collapses_into_overflow_bucket() {
        // max_tenants = 4: beyond that, new names share one bucket, so
        // hostile id churn cannot grow the map or mint fresh bursts.
        let mut c = controller(1.0, 1.0);
        let t0 = Instant::now();
        for name in ["a", "b", "c", "d"] {
            assert_eq!(c.admit(name, t0), Admission::Admit);
        }
        assert_eq!(c.admit("fresh-1", t0), Admission::Admit); // overflow's burst
        assert!(matches!(c.admit("fresh-2", t0), Admission::Reject { .. }));
        assert!(matches!(c.admit("fresh-3", t0), Admission::Reject { .. }));
        assert_eq!(c.tracked(), 5, "4 named tenants + 1 overflow bucket");
        // Known tenants keep their own buckets across the churn.
        let t1 = t0 + Duration::from_secs(1);
        assert_eq!(c.admit("a", t1), Admission::Admit);
    }

    #[test]
    fn burst_below_one_is_clamped() {
        let mut c = controller(1.0, 0.0);
        let t0 = Instant::now();
        // A zero burst would admit nothing ever; the clamp makes it 1.
        assert_eq!(c.admit("a", t0), Admission::Admit);
        assert!(matches!(c.admit("a", t0), Admission::Reject { .. }));
    }
}
