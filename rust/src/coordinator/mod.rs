//! Serving coordinator: the L3 layer a deployment would actually run.
//!
//! * [`batcher`] — dynamic batching: requests accumulate until
//!   `max_batch` or `max_wait` (amortizes cache-warm graph walks and
//!   enables the PJRT batch-rerank path);
//! * [`router`] — sharded indexes with fan-out + top-k merge, in a static
//!   flavor ([`ShardedRouter`]) and a mutable one
//!   ([`MutableShardedRouter`]: mutations routed to the owning shard);
//! * [`server`] — thread-based request loop with bounded queues
//!   (backpressure), a search + insert/delete update path
//!   ([`server::QueryRequest`] is an enum; `Server::start_mutable` serves
//!   a `MutableAnnIndex` behind an `RwLock`), filtered search (filter
//!   expressions compiled once per batch group against a shared metadata
//!   store), durable serving (`Server::start_durable` writes every acked
//!   mutation through an fsync'd append-only log before replying), and
//!   latency/throughput/mutation/filtered metrics.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use router::{MutableShardedRouter, ShardedRouter};
pub use server::{
    MutationResponse, QueryRequest, QueryResponse, Server, ServerConfig, SharedLog,
    SharedMetadata, SharedMutableIndex,
};
