//! Serving coordinator: the L3 layer a deployment would actually run.
//!
//! * [`batcher`] — dynamic batching: requests accumulate until
//!   `max_batch` or `max_wait` (amortizes cache-warm graph walks and
//!   enables the PJRT batch-rerank path);
//! * [`router`] — sharded indexes with fan-out + top-k merge;
//! * [`server`] — thread-based request loop with bounded queues
//!   (backpressure) and latency/throughput metrics.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use router::ShardedRouter;
pub use server::{QueryRequest, QueryResponse, Server, ServerConfig};
