//! Serving coordinator: the L3 layer a deployment would actually run.
//!
//! * [`batcher`] — dynamic batching: requests accumulate until
//!   `max_batch` or `max_wait` (amortizes cache-warm graph walks and
//!   enables the PJRT batch-rerank path);
//! * [`router`] — sharded indexes with fan-out + top-k merge, in a static
//!   flavor ([`ShardedRouter`]) and a mutable one
//!   ([`MutableShardedRouter`]: mutations routed to the owning shard);
//! * [`server`] — thread-based request loop with bounded queues
//!   (backpressure), a search + insert/delete update path
//!   ([`server::QueryRequest`] is an enum; `Server::start_mutable` serves
//!   a `MutableAnnIndex` behind an `RwLock`), filtered search (filter
//!   expressions compiled once per batch group against a shared metadata
//!   store), durable serving (`Server::start_durable` writes every acked
//!   mutation through an fsync'd append-only log before replying),
//!   wire-supplied deadlines (expired requests are dropped at dequeue and
//!   counted), and latency/throughput/mutation/filtered metrics;
//! * [`proto`] — length-prefixed checksummed binary wire protocol
//!   (hostile-input hardened: every length is capped before allocation);
//! * [`admission`] — per-tenant token-bucket admission control in front
//!   of the bounded queue;
//! * [`net`] (unix) — non-blocking socket front end: `epoll(7)` on Linux,
//!   `poll(2)` elsewhere, zero dependencies; plus the blocking
//!   [`net::Client`].

pub mod admission;
pub mod batcher;
pub mod metrics;
#[cfg(unix)]
pub mod net;
pub mod proto;
pub mod router;
pub mod server;

pub use admission::{Admission, AdmissionConfig, AdmissionController};
#[cfg(unix)]
pub use net::{Client, NetConfig, NetServer};
pub use router::{MutableShardedRouter, ShardedRouter};
pub use server::{
    MutationResponse, QueryRequest, QueryResponse, Reply, Server, ServerConfig, SharedLog,
    SharedMetadata, SharedMutableIndex,
};
