//! Query server: bounded ingress queue (backpressure), dynamic batching,
//! worker threads over a shared index, per-request latency metrics.
//!
//! Thread-based rather than async: the workload is CPU-bound graph
//! traversal; a tokio reactor would add no concurrency on this substrate
//! (and tokio is unavailable offline — DESIGN.md §8).
//!
//! Two backends:
//! * [`Server::start`] — a read-only `Arc<dyn AnnIndex>`; mutation
//!   requests are answered with an error (the index is immutable).
//! * [`Server::start_mutable`] — an `Arc<RwLock<Box<dyn
//!   MutableAnnIndex>>>`: searches share the read lock (and still batch
//!   through one `search_batch` per `(k, ef)` group), while
//!   inserts/deletes take the write lock briefly per mutation.
//!
//! Mutations ride the same bounded queue and dynamic batcher as searches
//! ([`QueryRequest`] is an enum). Within one drained batch the worker
//! applies mutations first, in arrival order, then serves the batch's
//! searches — so a search batched together with a delete never resurrects
//! the deleted id. Across batches/workers, ordering is whatever the locks
//! give (as in any concurrent store); every response is keyed to its own
//! reply channel, so results never cross requests.

use crate::anns::store::VectorLog;
use crate::anns::{AnnIndex, FilterBitset, FilterExpr, MetadataStore, MutableAnnIndex};
use crate::coordinator::batcher::{group_precomputed, next_batch_or_stop, BatchPolicy};
use crate::coordinator::metrics::Metrics;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// The shared-ownership shape a mutable backend is served from.
pub type SharedMutableIndex = Arc<RwLock<Box<dyn MutableAnnIndex>>>;

/// The shared-ownership shape the id → tenant/tags store is served from:
/// searches compile filter expressions under the read lock, inserts that
/// carry metadata update it under the write lock.
pub type SharedMetadata = Arc<RwLock<MetadataStore>>;

/// The shared-ownership shape of the durability log: one append (with
/// fsync) at a time, taken by whichever worker just applied a mutation.
pub type SharedLog = Arc<Mutex<VectorLog>>;

/// One request through the serving queue: a search or a mutation.
pub enum QueryRequest {
    Search(SearchRequest),
    Insert(InsertRequest),
    Delete(DeleteRequest),
}

/// How a response travels back to whoever submitted the request: a
/// bounded channel (the in-process `submit_*` path) or a one-shot hook
/// (the network front end, which must learn about *unserved* requests
/// too). Dropping an unsent `Reply` — deadline shed, queue-full
/// rejection, shutdown — fires a hook with `None`, so a socket client
/// always gets an explicit "dropped" frame instead of a silent stall; a
/// dropped channel reply is simply gone, matching the old behavior where
/// an abandoned `Receiver` made `send` a no-op.
pub struct Reply<T>(Option<ReplyKind<T>>);

enum ReplyKind<T> {
    Channel(SyncSender<T>),
    Hook(Box<dyn FnOnce(Option<T>) + Send>),
}

impl<T> Reply<T> {
    /// Reply over a bounded channel; a gone receiver makes `send` a no-op.
    pub fn channel(tx: SyncSender<T>) -> Reply<T> {
        Reply(Some(ReplyKind::Channel(tx)))
    }

    /// Reply through a one-shot hook. The hook is ALWAYS called exactly
    /// once: with `Some(response)` when the request was served, with
    /// `None` when it was dropped unserved.
    pub fn hook(f: impl FnOnce(Option<T>) + Send + 'static) -> Reply<T> {
        Reply(Some(ReplyKind::Hook(Box::new(f))))
    }

    /// Deliver the response.
    pub fn send(mut self, value: T) {
        match self.0.take() {
            Some(ReplyKind::Channel(tx)) => {
                let _ = tx.send(value);
            }
            Some(ReplyKind::Hook(f)) => f(Some(value)),
            None => unreachable!("Reply sent twice"),
        }
    }
}

impl<T> Drop for Reply<T> {
    fn drop(&mut self) {
        if let Some(ReplyKind::Hook(f)) = self.0.take() {
            f(None);
        }
    }
}

/// One query.
pub struct SearchRequest {
    pub query: Vec<f32>,
    pub k: usize,
    pub ef: usize,
    /// Optional metadata predicate (tenant equality, tag membership,
    /// conjunctions). Compiled to a [`FilterBitset`] against the server's
    /// metadata store once per `(k, ef, filter)` batch group; `None` is
    /// the unfiltered fast path, bitwise identical to pre-filter serving.
    pub filter: Option<FilterExpr>,
    pub submitted: Instant,
    /// Serve-by time: a worker that dequeues this request at or after the
    /// deadline drops it unserved (counted in `deadline_drops`) — a
    /// backed-up queue sheds stale load instead of serving it late.
    /// `None` (every in-process `submit_*` helper) never expires.
    pub deadline: Option<Instant>,
    pub reply: Reply<QueryResponse>,
}

/// One online insert.
pub struct InsertRequest {
    pub vector: Vec<f32>,
    /// Metadata recorded for the assigned id (only when the server was
    /// started with a metadata store).
    pub tenant: Option<String>,
    pub tags: Vec<String>,
    pub submitted: Instant,
    /// See [`SearchRequest::deadline`].
    pub deadline: Option<Instant>,
    pub reply: Reply<MutationResponse>,
}

/// One tombstone delete.
pub struct DeleteRequest {
    pub id: u32,
    pub submitted: Instant,
    /// See [`SearchRequest::deadline`].
    pub deadline: Option<Instant>,
    pub reply: Reply<MutationResponse>,
}

/// Outcome of a mutation: the assigned id for inserts (the echoed id for
/// deletes), or the index's error rendered as a string.
#[derive(Clone, Debug)]
pub struct MutationResponse {
    pub result: Result<u32, String>,
    pub latency_s: f64,
}

/// The answer: ids nearest-first with their exact distances (`dists[i]`
/// belongs to `ids[i]`) — the distance-carrying `AnnIndex` trait means the
/// serving layer no longer throws distances away at the trait boundary.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub ids: Vec<u32>,
    pub dists: Vec<f32>,
    pub latency_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::threadpool::effective_threads(),
            queue_depth: 1024,
            batch: BatchPolicy::default(),
        }
    }
}

impl ServerConfig {
    /// Size the server from a tuned-config artifact's serving knobs:
    /// worker count from `serving.threads` (0 = auto, the ambient
    /// [`crate::util::threadpool::effective_threads`]) and the batcher's
    /// max batch from `serving.batch`. Everything else keeps its default.
    pub fn from_tuned(artifact: &crate::variants::TunedArtifact) -> ServerConfig {
        let serving = &artifact.config.serving;
        ServerConfig {
            workers: match serving.threads {
                0 => crate::util::threadpool::effective_threads(),
                t => t,
            },
            batch: BatchPolicy {
                max_batch: serving.batch.max(1),
                ..BatchPolicy::default()
            },
            ..ServerConfig::default()
        }
    }
}

/// The index a worker serves from: read-only, or mutable behind a lock.
#[derive(Clone)]
enum Backend {
    Fixed(Arc<dyn AnnIndex>),
    Mutable(SharedMutableIndex),
}

impl Backend {
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        match self {
            Backend::Fixed(index) => index.search_batch(queries, k, ef),
            Backend::Mutable(index) => index.read().unwrap().search_batch(queries, k, ef),
        }
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        match self {
            Backend::Fixed(index) => index.search_filtered_batch(queries, k, ef, filter),
            Backend::Mutable(index) => {
                index.read().unwrap().search_filtered_batch(queries, k, ef, filter)
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            Backend::Fixed(index) => index.len(),
            Backend::Mutable(index) => index.read().unwrap().len(),
        }
    }

    fn filtered_fallback_threshold(&self) -> usize {
        match self {
            Backend::Fixed(index) => index.filtered_fallback_threshold(),
            Backend::Mutable(index) => index.read().unwrap().filtered_fallback_threshold(),
        }
    }

    /// Apply one mutation under the write lock. The live-point gauge is
    /// updated while the lock is still held, so concurrent workers can
    /// never publish a stale count over a newer one.
    fn apply(&self, op: &Mutation, metrics: &Metrics) -> Result<u32, String> {
        match self {
            Backend::Fixed(_) => {
                Err("index is immutable (serve it with Server::start_mutable)".to_string())
            }
            Backend::Mutable(index) => {
                let mut idx = index.write().unwrap();
                let result = match op {
                    Mutation::Insert(v) => idx.insert(v).map_err(|e| format!("{e:#}")),
                    Mutation::Delete(id) => {
                        idx.delete(*id).map(|_| *id).map_err(|e| format!("{e:#}"))
                    }
                };
                metrics.set_live_points(idx.live_count() as u64);
                result
            }
        }
    }
}

enum Mutation {
    Insert(Vec<f32>),
    Delete(u32),
}

/// Batch-group key: `(k, ef, filter)` with the filter *taken* from the
/// request (not cloned) and fingerprinted once at construction. Equality
/// checks compare `(k, ef, fingerprint)` before walking the expression,
/// so the linear group scan costs integer compares per mismatch; the full
/// structural compare on fingerprint match keeps colliding-but-different
/// filters in separate groups (correctness never rests on the hash).
struct GroupKey {
    k: usize,
    ef: usize,
    fingerprint: u64,
    filter: Option<FilterExpr>,
}

impl GroupKey {
    fn new(k: usize, ef: usize, filter: Option<FilterExpr>) -> GroupKey {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a-64 offset basis
        if let Some(f) = &filter {
            fingerprint_filter(f, &mut h);
        }
        GroupKey {
            k,
            ef,
            fingerprint: h,
            filter,
        }
    }
}

impl PartialEq for GroupKey {
    fn eq(&self, other: &Self) -> bool {
        self.k == other.k
            && self.ef == other.ef
            && self.fingerprint == other.fingerprint
            && self.filter == other.filter
    }
}

/// FNV-1a-64 over a tagged, length-prefixed walk of the expression — an
/// unambiguous serialization, so structurally different filters hash
/// differently except for true 64-bit collisions (which the structural
/// compare in [`GroupKey::eq`] absorbs).
fn fingerprint_filter(f: &FilterExpr, h: &mut u64) {
    fn eat(h: &mut u64, bytes: &[u8]) {
        for &b in bytes {
            *h ^= b as u64;
            *h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    match f {
        FilterExpr::Tenant(name) => {
            eat(h, &[1]);
            eat(h, &(name.len() as u32).to_le_bytes());
            eat(h, name.as_bytes());
        }
        FilterExpr::HasTag(name) => {
            eat(h, &[2]);
            eat(h, &(name.len() as u32).to_le_bytes());
            eat(h, name.as_bytes());
        }
        FilterExpr::And(parts) => {
            eat(h, &[3]);
            eat(h, &(parts.len() as u32).to_le_bytes());
            for p in parts {
                fingerprint_filter(p, h);
            }
        }
    }
}

/// A running server. Submit with [`Server::handle`]; drop to stop.
pub struct Server {
    tx: Option<SyncSender<QueryRequest>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl Server {
    /// Start worker threads over a shared read-only index. Mutation
    /// requests submitted to this server are answered with an error, and
    /// filtered searches (there is no metadata store) match nothing.
    pub fn start(index: Arc<dyn AnnIndex>, config: ServerConfig) -> Server {
        Server::start_backend(Backend::Fixed(index), None, None, config)
    }

    /// [`Server::start`] plus a metadata store: filter expressions compile
    /// against it, and inserts are still rejected (read-only backend).
    pub fn start_with_metadata(
        index: Arc<dyn AnnIndex>,
        metadata: SharedMetadata,
        config: ServerConfig,
    ) -> Server {
        Server::start_backend(Backend::Fixed(index), Some(metadata), None, config)
    }

    /// Start worker threads over a mutable index: searches share the read
    /// lock, inserts/deletes serialize on the write lock, and the
    /// tombstone/consolidation semantics come from the index itself.
    pub fn start_mutable(index: SharedMutableIndex, config: ServerConfig) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server = Server::start_backend(Backend::Mutable(index), None, None, config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    /// [`Server::start_mutable`] plus a metadata store: filter expressions
    /// compile against it and successful inserts record their
    /// tenant/tags for the assigned id.
    pub fn start_mutable_with_metadata(
        index: SharedMutableIndex,
        metadata: SharedMetadata,
        config: ServerConfig,
    ) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server =
            Server::start_backend(Backend::Mutable(index), Some(metadata), None, config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    /// [`Server::start_mutable`] with durability: every acked mutation is
    /// appended (checksummed, fsync'd) to the shared mutation log before
    /// the client sees the ack, so a crash loses nothing that was acked —
    /// restart through `anns::store::restore_glass` replays the log tail
    /// on top of the last snapshot. An apply that succeeds but fails to
    /// log is acked as an error (`"applied but not logged"`): the client
    /// must not count on a mutation the next restart may not see.
    pub fn start_durable(
        index: SharedMutableIndex,
        metadata: Option<SharedMetadata>,
        wal: SharedLog,
        config: ServerConfig,
    ) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server = Server::start_backend(Backend::Mutable(index), metadata, Some(wal), config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    fn start_backend(
        backend: Backend,
        metadata: Option<SharedMetadata>,
        wal: Option<SharedLog>,
        config: ServerConfig,
    ) -> Server {
        let (tx, rx) = sync_channel::<QueryRequest>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = rx.clone();
            let backend = backend.clone();
            let metadata = metadata.clone();
            let wal = wal.clone();
            let metrics = metrics.clone();
            let policy = config.batch.clone();
            let inflight = inflight.clone();
            let stop = stopping.clone();
            workers.push(std::thread::spawn(move || loop {
                // One worker holds the receiver lock while it drains a
                // batch; others serve previous batches meanwhile. The
                // first-element wait polls the stop flag: live handles may
                // keep the channel open past shutdown, so Disconnected
                // alone is not a sufficient exit signal.
                let batch = {
                    let guard = rx.lock().unwrap();
                    next_batch_or_stop(&guard, &policy, &stop)
                };
                let Some(batch) = batch else { break };
                metrics.record_batch(batch.len());
                // Split the drained batch: mutations apply first (arrival
                // order preserved), then the searches — so a search
                // batched alongside a delete observes it. One shared
                // apply-and-reply block serves both mutation kinds, so
                // the accounting protocol cannot drift between them.
                let mut searches = Vec::with_capacity(batch.len());
                for req in batch {
                    let (op, reply, submitted, deadline, ins_meta) = match req {
                        QueryRequest::Search(s) => {
                            searches.push(s);
                            continue;
                        }
                        QueryRequest::Insert(r) => (
                            Mutation::Insert(r.vector),
                            r.reply,
                            r.submitted,
                            r.deadline,
                            Some((r.tenant, r.tags)),
                        ),
                        QueryRequest::Delete(r) => {
                            (Mutation::Delete(r.id), r.reply, r.submitted, r.deadline, None)
                        }
                    };
                    // Deadline shed at dequeue: an already-late mutation is
                    // dropped unserved (the dropped reply notifies a hook
                    // completion) rather than applied late.
                    if deadline.map_or(false, |d| Instant::now() >= d) {
                        metrics.record_deadline_drop();
                        drop(reply);
                        inflight.fetch_sub(1, Ordering::Relaxed);
                        continue;
                    }
                    let is_insert = ins_meta.is_some();
                    let result = backend.apply(&op, &metrics);
                    // Durable write-through: the applied mutation reaches
                    // the fsync'd log before the client sees the ack. A
                    // mutation that applied but failed to log is acked as
                    // an error — the client must not rely on state the
                    // next restart may not replay.
                    let result = match (result, wal.as_ref()) {
                        (Ok(id), Some(wal)) => {
                            let mut w = wal.lock().unwrap();
                            let logged = match &op {
                                Mutation::Insert(v) => {
                                    w.append_vector(id, v).and_then(|()| match &ins_meta {
                                        Some((tenant, tags))
                                            if tenant.is_some() || !tags.is_empty() =>
                                        {
                                            let tags: Vec<&str> =
                                                tags.iter().map(|t| t.as_str()).collect();
                                            w.append_metadata(id, tenant.as_deref(), &tags)
                                        }
                                        _ => Ok(()),
                                    })
                                }
                                Mutation::Delete(_) => w.append_tombstone(id),
                            };
                            match logged {
                                Ok(()) => Ok(id),
                                Err(e) => Err(format!("applied but not logged: {e:#}")),
                            }
                        }
                        (other, _) => other,
                    };
                    // Record the insert's tenant/tags under the assigned id
                    // only once the mutation fully succeeded — applied AND
                    // logged — but still before replying: once the client
                    // holds the ack, a filtered search must already see the
                    // metadata, while an insert acked as "applied but not
                    // logged" must leave no metadata a restart would not
                    // replay.
                    if let (Ok(id), Some(meta), Some((tenant, tags))) =
                        (&result, metadata.as_ref(), ins_meta.as_ref())
                    {
                        let tags: Vec<&str> = tags.iter().map(|t| t.as_str()).collect();
                        meta.write().unwrap().set_for(*id, tenant.as_deref(), &tags);
                    }
                    match (&result, is_insert) {
                        (Ok(_), true) => metrics.record_insert(),
                        (Ok(_), false) => metrics.record_delete(),
                        (Err(_), _) => metrics.record_mutation_error(),
                    }
                    reply.send(MutationResponse {
                        result,
                        latency_s: submitted.elapsed().as_secs_f64(),
                    });
                    inflight.fetch_sub(1, Ordering::Relaxed);
                }
                // Deadline shed for searches, also at dequeue: drop the
                // already-late ones before any grouping or bitset work.
                // The dropped requests' replies notify hook completions.
                let now = Instant::now();
                let searches: Vec<SearchRequest> = searches
                    .into_iter()
                    .filter(|s| {
                        if s.deadline.map_or(false, |d| now >= d) {
                            metrics.record_deadline_drop();
                            inflight.fetch_sub(1, Ordering::Relaxed);
                            false
                        } else {
                            true
                        }
                    })
                    .collect();
                // Serve each (k, ef, filter) group through one multi-query
                // `search_batch` call — the index reuses a single pooled
                // scratch context across the group, and results are
                // bitwise identical to per-request `search_with_dists`.
                // A filter expression is compiled to a bitset ONCE per
                // group under the metadata read lock; with no store, a
                // filtered query matches nothing (deny-safe). The group
                // key takes each request's filter (no clone) and carries
                // its fingerprint, so membership tests cost a few integer
                // compares instead of an expression walk.
                let keyed: Vec<(GroupKey, SearchRequest)> = searches
                    .into_iter()
                    .map(|mut r| {
                        let filter = r.filter.take();
                        (GroupKey::new(r.k, r.ef, filter), r)
                    })
                    .collect();
                for (key, group) in group_precomputed(keyed) {
                    let (k, ef, filter) = (key.k, key.ef, key.filter);
                    let queries: Vec<&[f32]> =
                        group.iter().map(|r| r.query.as_slice()).collect();
                    let results = match &filter {
                        None => backend.search_batch(&queries, k, ef),
                        Some(expr) => {
                            let bitset = match metadata.as_ref() {
                                Some(meta) => {
                                    meta.read().unwrap().compile(expr, backend.len())
                                }
                                None => FilterBitset::new(backend.len()),
                            };
                            metrics.record_filtered(group.len());
                            if bitset.count() <= backend.filtered_fallback_threshold() {
                                metrics.record_filtered_fallback(group.len());
                            }
                            backend.search_filtered_batch(&queries, k, ef, Some(&bitset))
                        }
                    };
                    metrics.record_group(group.len());
                    for (req, pairs) in group.into_iter().zip(results) {
                        let latency = req.submitted.elapsed().as_secs_f64();
                        metrics.record_request(latency);
                        let (dists, ids) = pairs.into_iter().unzip();
                        req.reply.send(QueryResponse {
                            ids,
                            dists,
                            latency_s: latency,
                        });
                        inflight.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        Server {
            tx: Some(tx),
            metrics,
            workers,
            stopping,
            inflight,
        }
    }

    /// Create a handle for submitting queries.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.as_ref().expect("server running").clone(),
            metrics: self.metrics.clone(),
            stopping: self.stopping.clone(),
            inflight: self.inflight.clone(),
        }
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) -> crate::coordinator::metrics::MetricsSnapshot {
        self.stopping.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<QueryRequest>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Enqueue one request; shared admission control for searches and
    /// mutations (stop flag, bounded-queue backpressure, inflight count).
    fn push(&self, req: QueryRequest) -> bool {
        if self.stopping.load(Ordering::Relaxed) {
            self.metrics.record_rejected();
            return false;
        }
        match self.tx.try_send(req) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.metrics.record_rejected();
                false
            }
        }
    }

    /// Submit a query; returns the reply receiver, or `None` when the
    /// server rejects (shutting down / queue full — backpressure).
    pub fn submit(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<Receiver<QueryResponse>> {
        self.submit_filtered(query, k, ef, None)
    }

    /// Submit a query with an optional metadata filter; `filter = None`
    /// is exactly [`Self::submit`].
    pub fn submit_filtered(
        &self,
        query: Vec<f32>,
        k: usize,
        ef: usize,
        filter: Option<FilterExpr>,
    ) -> Option<Receiver<QueryResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Search(SearchRequest {
            query,
            k,
            ef,
            filter,
            submitted: Instant::now(),
            deadline: None,
            reply: Reply::channel(reply_tx),
        }))
        .then_some(reply_rx)
    }

    /// Enqueue a fully-formed request — the network front end builds
    /// these itself ([`Reply::hook`] completions, wire-supplied
    /// deadlines). Same admission control as the typed `submit_*`
    /// helpers; `false` means rejected (shutting down or queue full), and
    /// the dropped request fires any hook reply with `None`.
    pub fn submit_request(&self, req: QueryRequest) -> bool {
        self.push(req)
    }

    /// Submit an online insert; same admission control as [`Self::submit`].
    pub fn submit_insert(&self, vector: Vec<f32>) -> Option<Receiver<MutationResponse>> {
        self.submit_insert_with_metadata(vector, None, Vec::new())
    }

    /// Submit an online insert carrying tenant/tags for the assigned id
    /// (recorded only when the server holds a metadata store).
    pub fn submit_insert_with_metadata(
        &self,
        vector: Vec<f32>,
        tenant: Option<String>,
        tags: Vec<String>,
    ) -> Option<Receiver<MutationResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Insert(InsertRequest {
            vector,
            tenant,
            tags,
            submitted: Instant::now(),
            deadline: None,
            reply: Reply::channel(reply_tx),
        }))
        .then_some(reply_rx)
    }

    /// Submit a tombstone delete; same admission control as
    /// [`Self::submit`].
    pub fn submit_delete(&self, id: u32) -> Option<Receiver<MutationResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Delete(DeleteRequest {
            id,
            submitted: Instant::now(),
            deadline: None,
            reply: Reply::channel(reply_tx),
        }))
        .then_some(reply_rx)
    }

    /// Blocking convenience: submit + wait.
    pub fn query(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<QueryResponse> {
        self.submit(query, k, ef)?.recv().ok()
    }

    /// Blocking convenience: filtered submit + wait.
    pub fn query_filtered(
        &self,
        query: Vec<f32>,
        k: usize,
        ef: usize,
        filter: Option<FilterExpr>,
    ) -> Option<QueryResponse> {
        self.submit_filtered(query, k, ef, filter)?.recv().ok()
    }

    /// Blocking convenience: insert + wait for the assigned id.
    pub fn insert(&self, vector: Vec<f32>) -> Option<MutationResponse> {
        self.submit_insert(vector)?.recv().ok()
    }

    /// Blocking convenience: insert with tenant/tags + wait.
    pub fn insert_with_metadata(
        &self,
        vector: Vec<f32>,
        tenant: Option<String>,
        tags: Vec<String>,
    ) -> Option<MutationResponse> {
        self.submit_insert_with_metadata(vector, tenant, tags)?
            .recv()
            .ok()
    }

    /// Blocking convenience: delete + wait for the ack.
    pub fn delete(&self, id: u32) -> Option<MutationResponse> {
        self.submit_delete(id)?.recv().ok()
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    fn make_server(queue_depth: usize) -> (Server, crate::dataset::Dataset) {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 93);
        ds.compute_ground_truth(5);
        let idx: Arc<dyn AnnIndex> =
            Arc::new(BruteForceIndex::build(VectorSet::from_dataset(&ds)));
        let server = Server::start(
            idx,
            ServerConfig {
                workers: 2,
                queue_depth,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        (server, ds)
    }

    #[test]
    fn serves_correct_results() {
        let (server, ds) = make_server(128);
        let h = server.handle();
        for qi in 0..10 {
            let resp = h.query(ds.query_vec(qi).to_vec(), 5, 0).unwrap();
            assert_eq!(resp.ids, ds.gt[qi][..5].to_vec(), "query {qi}");
            assert_eq!(resp.dists.len(), resp.ids.len());
            // Distances surfaced by the server are the exact metric values.
            for (&id, &d) in resp.ids.iter().zip(&resp.dists) {
                let want = ds.metric.distance(ds.query_vec(qi), ds.base_vec(id as usize));
                assert_eq!(d, want, "query {qi} id {id}");
            }
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 10);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn coordinator_batched_distances_match_direct_search() {
        // The serving path goes through `search_batch` grouped by (k, ef);
        // every response's (dist, id) pairs must be bitwise identical to a
        // direct `search_with_dists` call on the underlying index — the
        // trait-level batch identity observed end to end through the
        // coordinator, on the real GLASS pipeline with mixed parameters.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 94);
        ds.compute_ground_truth(5);
        let idx = Arc::new(crate::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(&ds),
            crate::variants::VariantConfig::glass_baseline(),
            3,
        ));
        let index: Arc<dyn AnnIndex> = idx.clone();
        let server = Server::start(
            index,
            ServerConfig {
                workers: 2,
                queue_depth: 256,
                batch: BatchPolicy {
                    max_batch: 16,
                    max_wait: std::time::Duration::from_millis(2),
                },
            },
        );
        let h = server.handle();
        // Mixed (k, ef) across the flood exercises the per-group dispatch.
        let mut pending = Vec::new();
        for qi in 0..ds.n_queries() {
            let (k, ef) = if qi % 2 == 0 { (5, 64) } else { (3, 32) };
            let rx = h.submit(ds.query_vec(qi).to_vec(), k, ef).unwrap();
            pending.push((qi, k, ef, rx));
        }
        for (qi, k, ef, rx) in pending {
            let resp = rx.recv().unwrap();
            let got: Vec<(f32, u32)> = resp
                .dists
                .iter()
                .copied()
                .zip(resp.ids.iter().copied())
                .collect();
            let want = idx.search_with_dists(ds.query_vec(qi), k, ef);
            assert_eq!(got, want, "query {qi} k={k} ef={ef}");
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests as usize, ds.n_queries());
    }

    #[test]
    fn concurrent_clients() {
        let (server, ds) = make_server(256);
        let h = server.handle();
        let ds = Arc::new(ds);
        let mut clients = Vec::new();
        for c in 0..4 {
            let h = h.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for qi in 0..10 {
                    let q = ds.query_vec((c * 7 + qi) % ds.n_queries()).to_vec();
                    let resp = h.query(q, 5, 0).unwrap();
                    assert_eq!(resp.ids.len(), 5);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 40);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (server, ds) = make_server(1);
        let h = server.handle();
        // Flood without reading replies; with queue depth 1 at least one
        // submit must be rejected.
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match h.submit(ds.query_vec(0).to_vec(), 5, 0) {
                Some(r) => receivers.push(r),
                None => rejected += 1,
            }
        }
        for r in receivers {
            let _ = r.recv();
        }
        let snap = server.shutdown();
        assert!(rejected > 0 || snap.rejected > 0);
    }

    #[test]
    fn shutdown_is_clean() {
        let (server, _) = make_server(16);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 0);
    }

    #[test]
    fn mutation_on_immutable_server_errors_cleanly() {
        let (server, ds) = make_server(64);
        let h = server.handle();
        let resp = h.insert(ds.base_vec(0).to_vec()).unwrap();
        assert!(resp.result.is_err(), "immutable backend accepted an insert");
        assert!(resp.result.unwrap_err().contains("immutable"));
        let resp = h.delete(3).unwrap();
        assert!(resp.result.is_err());
        // Searches still work on the same server.
        assert!(h.query(ds.query_vec(0).to_vec(), 5, 0).is_some());
        let snap = server.shutdown();
        assert_eq!(snap.mutation_errors, 2);
        assert_eq!((snap.inserts, snap.deletes), (0, 0));
    }

    #[test]
    fn filtered_queries_end_to_end() {
        // Filter expressions compile against the metadata store, inserts
        // carry tenant/tags, and the counters reconcile.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 90);
        ds.compute_ground_truth(5);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let mut store = MetadataStore::new();
        for id in 0..300u32 {
            let tenant = format!("t{}", id % 3);
            let tags: &[&str] = if id % 2 == 0 { &["even"] } else { &[] };
            store.push(Some(&tenant), tags);
        }
        let metadata: SharedMetadata = Arc::new(RwLock::new(store));
        let server = Server::start_mutable_with_metadata(
            index,
            metadata.clone(),
            ServerConfig {
                workers: 2,
                queue_depth: 128,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        // filter=None serves the unfiltered path.
        let resp = h.query_filtered(ds.query_vec(0).to_vec(), 5, 0, None).unwrap();
        assert_eq!(resp.ids, ds.gt[0][..5].to_vec());
        // Tenant filter: every id belongs to t1.
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tenant("t1")))
            .unwrap();
        assert_eq!(resp.ids.len(), 5);
        assert!(resp.ids.iter().all(|&id| id % 3 == 1), "{:?}", resp.ids);
        // Conjunction: tenant t1 AND tag "even" → id ≡ 4 (mod 6).
        let conj = FilterExpr::and(vec![FilterExpr::tenant("t1"), FilterExpr::tag("even")]);
        let resp = h
            .query_filtered(ds.query_vec(1).to_vec(), 5, 0, Some(conj))
            .unwrap();
        assert!(resp.ids.iter().all(|&id| id % 3 == 1 && id % 2 == 0));
        // Unknown names match nothing.
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tag("nope")))
            .unwrap();
        assert!(resp.ids.is_empty());
        // An insert carrying metadata is immediately filterable once acked.
        let ack = h
            .insert_with_metadata(
                ds.query_vec(2).to_vec(),
                Some("t1".to_string()),
                vec!["even".to_string()],
            )
            .unwrap();
        let new_id = ack.result.expect("insert must succeed");
        let resp = h
            .query_filtered(ds.query_vec(2).to_vec(), 1, 0, Some(FilterExpr::tenant("t1")))
            .unwrap();
        assert_eq!((resp.ids, resp.dists), (vec![new_id], vec![0.0]));
        assert_eq!(metadata.read().unwrap().tenant(new_id), Some("t1"));
        let snap = server.shutdown();
        assert_eq!(snap.filtered_queries, 4);
        assert_eq!(snap.requests, 5);
    }

    #[test]
    fn filtered_query_without_store_matches_nothing() {
        // A filter on a server started without a metadata store is
        // deny-safe: it cannot be satisfied, so it returns no ids (rather
        // than silently ignoring the predicate).
        let (server, ds) = make_server(64);
        let h = server.handle();
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tenant("t0")))
            .unwrap();
        assert!(resp.ids.is_empty());
        let unfiltered = h.query_filtered(ds.query_vec(0).to_vec(), 5, 0, None).unwrap();
        assert_eq!(unfiltered.ids, ds.gt[0][..5].to_vec());
        let snap = server.shutdown();
        assert_eq!(snap.filtered_queries, 1);
        // The empty bitset is at or below every fallback threshold.
        assert_eq!(snap.filtered_fallbacks, 1);
    }

    #[test]
    fn durable_server_logs_every_acked_mutation() {
        use crate::anns::store::LogRecord;
        // Every acked mutation must be in the log after shutdown, in ack
        // order; a rejected mutation must NOT be.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 200, 5, 95);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let metadata: SharedMetadata = Arc::new(RwLock::new(MetadataStore::new()));
        let path = std::env::temp_dir()
            .join(format!("crinn_{}_server_durable.wal", std::process::id()));
        let wal: SharedLog = Arc::new(Mutex::new(VectorLog::create(&path).unwrap()));
        let server = Server::start_durable(
            index,
            Some(metadata),
            wal,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        // Sequential (wait for each ack) so the log order is fixed.
        let inserted = h
            .insert_with_metadata(
                ds.query_vec(0).to_vec(),
                Some("t1".to_string()),
                vec!["hot".to_string()],
            )
            .unwrap()
            .result
            .unwrap();
        let plain = h.insert(ds.query_vec(1).to_vec()).unwrap().result.unwrap();
        assert_eq!(h.delete(3).unwrap().result, Ok(3));
        assert!(h.delete(3).unwrap().result.is_err(), "double delete rejected");
        server.shutdown();

        let (records, _) = VectorLog::recover(&path).unwrap();
        assert_eq!(
            records,
            vec![
                LogRecord::Vector {
                    id: inserted,
                    vector: ds.query_vec(0).to_vec()
                },
                LogRecord::Metadata {
                    id: inserted,
                    tenant: Some("t1".to_string()),
                    tags: vec!["hot".to_string()]
                },
                // A metadata-free insert logs no metadata record.
                LogRecord::Vector {
                    id: plain,
                    vector: ds.query_vec(1).to_vec()
                },
                LogRecord::Tombstone { id: 3 },
            ]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mutation_update_path_end_to_end() {
        // Sequential (submit + wait each step) so the interleaving is
        // deterministic: an acked delete must be invisible to the next
        // search, an acked insert must be findable, and the counters/live
        // gauge must reconcile exactly.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 97);
        ds.compute_ground_truth(6); // k=5 served + 1 spare for the delete
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let server = Server::start_mutable(
            index.clone(),
            ServerConfig {
                workers: 2,
                queue_depth: 128,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        assert_eq!(server.metrics.live_points.load(Ordering::Relaxed), 400);
        let h = server.handle();
        // Delete the exact NN of query 0: the served result must shift to
        // the remainder of the ground-truth list.
        let victim = ds.gt[0][0];
        let ack = h.delete(victim).unwrap();
        assert_eq!(ack.result, Ok(victim));
        let resp = h.query(ds.query_vec(0).to_vec(), 5, 0).unwrap();
        assert_eq!(resp.ids, ds.gt[0][1..6].to_vec());
        // Insert the query vector itself: it becomes its own NN.
        let ack = h.insert(ds.query_vec(0).to_vec()).unwrap();
        let new_id = ack.result.expect("insert must succeed");
        assert_eq!(new_id, 400);
        let resp = h.query(ds.query_vec(0).to_vec(), 1, 0).unwrap();
        assert_eq!(resp.ids, vec![new_id]);
        assert_eq!(resp.dists, vec![0.0]);
        // Double delete errors but does not poison the server.
        let ack = h.delete(victim).unwrap();
        assert!(ack.result.is_err());
        let snap = server.shutdown();
        assert_eq!((snap.inserts, snap.deletes, snap.mutation_errors), (1, 1, 1));
        assert_eq!(snap.live_points, 400); // 400 - 1 deleted + 1 inserted
        assert_eq!(snap.requests, 2, "searches counted separately from mutations");
        // The mutations really landed in the shared index.
        let idx = index.read().unwrap();
        assert_eq!(idx.live_count(), 400);
        assert!(idx.is_deleted(victim));
    }

    #[test]
    fn reply_hook_fires_exactly_once() {
        // Served → Some(response); dropped unserved → None. Exactly one
        // call either way — the network front end's pending-count
        // bookkeeping rests on this.
        let got: Arc<Mutex<Vec<Option<u32>>>> = Arc::new(Mutex::new(Vec::new()));
        let g = got.clone();
        Reply::hook(move |v: Option<u32>| g.lock().unwrap().push(v)).send(7);
        let g = got.clone();
        drop(Reply::hook(move |v: Option<u32>| g.lock().unwrap().push(v)));
        assert_eq!(*got.lock().unwrap(), vec![Some(7), None]);
        // A channel reply with a gone receiver stays a silent no-op.
        let (tx, rx) = sync_channel::<u32>(1);
        drop(rx);
        Reply::channel(tx).send(1);
    }

    #[test]
    fn group_key_fingerprints_agree_with_equality() {
        let k1 = GroupKey::new(5, 64, Some(FilterExpr::tenant("t1")));
        let k2 = GroupKey::new(5, 64, Some(FilterExpr::tenant("t1")));
        assert!(k1 == k2);
        assert_eq!(k1.fingerprint, k2.fingerprint);
        // Same string under a different node kind must not collide: the
        // walk is tagged and length-prefixed.
        let k3 = GroupKey::new(5, 64, Some(FilterExpr::tag("t1")));
        assert!(k1 != k3);
        assert_ne!(k1.fingerprint, k3.fingerprint);
        // And(vec![x]) is structurally distinct from x.
        let k4 = GroupKey::new(5, 64, Some(FilterExpr::and(vec![FilterExpr::tenant("t1")])));
        assert!(k1 != k4);
        assert_ne!(k1.fingerprint, k4.fingerprint);
        let unfiltered = GroupKey::new(5, 64, None);
        assert!(unfiltered == GroupKey::new(5, 64, None));
        assert!(unfiltered != GroupKey::new(5, 32, None));
        assert!(unfiltered != k1);
    }

    #[test]
    fn expired_deadline_requests_are_dropped_and_counted() {
        let (server, ds) = make_server(64);
        let h = server.handle();
        // A deadline of "now" is in the past by the time a worker
        // dequeues. The channel reply sender is dropped unsent, so the
        // receiver sees a disconnect, not a response.
        let (tx, rx) = sync_channel(1);
        assert!(h.submit_request(QueryRequest::Search(SearchRequest {
            query: ds.query_vec(0).to_vec(),
            k: 5,
            ef: 0,
            filter: None,
            submitted: Instant::now(),
            deadline: Some(Instant::now()),
            reply: Reply::channel(tx),
        })));
        assert!(rx.recv().is_err(), "expired search must be dropped, not served");
        // Same for mutations — and the drop happens before apply, so an
        // expired delete on this immutable backend is NOT a mutation
        // error (it never touched the backend).
        let (tx, rx) = sync_channel(1);
        assert!(h.submit_request(QueryRequest::Delete(DeleteRequest {
            id: 1,
            submitted: Instant::now(),
            deadline: Some(Instant::now()),
            reply: Reply::channel(tx),
        })));
        assert!(rx.recv().is_err(), "expired delete must be dropped, not applied");
        // A deadline comfortably in the future serves normally.
        let (tx, rx) = sync_channel(1);
        assert!(h.submit_request(QueryRequest::Search(SearchRequest {
            query: ds.query_vec(0).to_vec(),
            k: 5,
            ef: 0,
            filter: None,
            submitted: Instant::now(),
            deadline: Some(Instant::now() + std::time::Duration::from_secs(60)),
            reply: Reply::channel(tx),
        })));
        assert_eq!(rx.recv().unwrap().ids, ds.gt[0][..5].to_vec());
        let snap = server.shutdown();
        assert_eq!(snap.deadline_drops, 2);
        assert_eq!(snap.requests, 1, "dropped requests are not served requests");
        assert_eq!(snap.mutation_errors, 0);
    }

    #[test]
    fn dropped_reply_receiver_neither_panics_nor_leaks_inflight() {
        let (server, ds) = make_server(64);
        let h = server.handle();
        // Submit and immediately abandon the receivers — the worker's
        // send fails, which must not panic it and must still decrement
        // the inflight gauge.
        for qi in 0..8 {
            drop(h.submit(ds.query_vec(qi % ds.n_queries()).to_vec(), 5, 0).unwrap());
        }
        // Mutation replies too (this backend answers inserts with an
        // error; the error response also has nowhere to go).
        drop(h.submit_insert(ds.base_vec(0).to_vec()).unwrap());
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        while h.inflight() > 0 && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(h.inflight(), 0, "abandoned replies leaked inflight slots");
        // The workers survived: a live client is still served.
        let resp = h.query(ds.query_vec(0).to_vec(), 5, 0).unwrap();
        assert_eq!(resp.ids, ds.gt[0][..5].to_vec());
        let snap = server.shutdown();
        assert_eq!(snap.requests, 9, "abandoned searches are still served");
        assert_eq!(snap.mutation_errors, 1);
    }

    #[test]
    fn shutdown_drains_already_queued_requests() {
        // One worker, batch size 1: plug it on a rendezvous reply channel
        // so everything submitted next stays queued, call shutdown while
        // they wait, then release the plug — shutdown must serve the
        // queued requests before joining, not strand them.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 91);
        ds.compute_ground_truth(5);
        let idx: Arc<dyn AnnIndex> =
            Arc::new(BruteForceIndex::build(VectorSet::from_dataset(&ds)));
        let server = Server::start(
            idx,
            ServerConfig {
                workers: 1,
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 1,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        let (plug_tx, plug_rx) = sync_channel(0); // rendezvous: send blocks
        assert!(h.submit_request(QueryRequest::Search(SearchRequest {
            query: ds.query_vec(0).to_vec(),
            k: 5,
            ef: 0,
            filter: None,
            submitted: Instant::now(),
            deadline: None,
            reply: Reply::channel(plug_tx),
        })));
        let receivers: Vec<_> = (0..5)
            .map(|qi| h.submit(ds.query_vec(qi).to_vec(), 5, 0).unwrap())
            .collect();
        let releaser = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(100));
            plug_rx.recv().unwrap();
        });
        let snap = server.shutdown(); // entered while the 5 are queued
        releaser.join().unwrap();
        for (qi, rx) in receivers.into_iter().enumerate() {
            let resp = rx.try_recv().unwrap_or_else(|_| panic!("request {qi} stranded"));
            assert_eq!(resp.ids, ds.gt[qi][..5].to_vec(), "request {qi}");
        }
        assert_eq!(snap.requests, 6);
    }

    #[test]
    fn failed_wal_append_leaves_no_metadata_behind() {
        // The durability-ordering regression: an insert that applies but
        // fails to log is acked as an error — and must leave NO metadata
        // visible, because a restart will not replay it. Before the fix,
        // `set_for` ran before the WAL append, so filtered searches
        // matched state the client was told failed.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 200, 5, 96);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let metadata: SharedMetadata = Arc::new(RwLock::new(MetadataStore::new()));
        let path = std::env::temp_dir()
            .join(format!("crinn_{}_server_poisoned.wal", std::process::id()));
        let mut log = VectorLog::create(&path).unwrap();
        log.poison_appends(true);
        let wal: SharedLog = Arc::new(Mutex::new(log));
        let server = Server::start_durable(
            index,
            Some(metadata.clone()),
            wal,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        let ack = h
            .insert_with_metadata(
                ds.query_vec(0).to_vec(),
                Some("t1".to_string()),
                vec!["hot".to_string()],
            )
            .unwrap();
        let err = ack.result.unwrap_err();
        assert!(err.contains("applied but not logged"), "{err}");
        // No metadata for the failed insert: the tenant filter matches
        // nothing and the store has no tenant for the assigned id.
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 1, 0, Some(FilterExpr::tenant("t1")))
            .unwrap();
        assert!(resp.ids.is_empty(), "{:?}", resp.ids);
        assert_eq!(metadata.read().unwrap().tenant(200), None);
        let snap = server.shutdown();
        assert_eq!(snap.mutation_errors, 1);
        assert_eq!((snap.inserts, snap.deletes), (0, 0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mixed_search_mutation_batch_accounting() {
        // The mean-batch-size skew regression: mutations must count into
        // `batch_items`, so `mean_batch_size` reconciles exactly against
        // the drained batches even when the traffic mixes kinds.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 92);
        ds.compute_ground_truth(5);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let server = Server::start_mutable(
            index,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        for qi in 0..3 {
            h.query(ds.query_vec(qi).to_vec(), 5, 0).unwrap();
        }
        h.insert(ds.query_vec(0).to_vec()).unwrap().result.unwrap();
        h.insert(ds.query_vec(1).to_vec()).unwrap().result.unwrap();
        assert_eq!(h.delete(0).unwrap().result, Ok(0));
        let snap = server.shutdown();
        assert_eq!(snap.requests, 3, "requests still counts searches only");
        assert_eq!((snap.inserts, snap.deletes), (2, 1));
        assert_eq!(snap.batch_items, 6, "every kind counts into batch_items");
        assert!(
            (snap.mean_batch_size() * snap.batches as f64 - snap.batch_items as f64).abs()
                < 1e-9,
            "mean_batch_size must reconcile: {} * {} vs {}",
            snap.mean_batch_size(),
            snap.batches,
            snap.batch_items
        );
    }
}
