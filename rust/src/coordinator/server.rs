//! Query server: bounded ingress queue (backpressure), dynamic batching,
//! worker threads over a shared index, per-request latency metrics.
//!
//! Thread-based rather than async: the workload is CPU-bound graph
//! traversal; a tokio reactor would add no concurrency on this substrate
//! (and tokio is unavailable offline — DESIGN.md §8).

use crate::anns::AnnIndex;
use crate::coordinator::batcher::{next_batch_or_stop, BatchPolicy};
use crate::coordinator::metrics::Metrics;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One query.
pub struct QueryRequest {
    pub query: Vec<f32>,
    pub k: usize,
    pub ef: usize,
    pub submitted: Instant,
    /// Reply channel.
    pub reply: SyncSender<QueryResponse>,
}

/// The answer.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub ids: Vec<u32>,
    pub latency_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::threadpool::effective_threads(),
            queue_depth: 1024,
            batch: BatchPolicy::default(),
        }
    }
}

/// A running server. Submit with [`Server::handle`]; drop to stop.
pub struct Server {
    tx: Option<SyncSender<QueryRequest>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl Server {
    /// Start worker threads over a shared index.
    pub fn start(index: Arc<dyn AnnIndex>, config: ServerConfig) -> Server {
        let (tx, rx) = sync_channel::<QueryRequest>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = rx.clone();
            let index = index.clone();
            let metrics = metrics.clone();
            let policy = config.batch.clone();
            let inflight = inflight.clone();
            let stop = stopping.clone();
            workers.push(std::thread::spawn(move || loop {
                // One worker holds the receiver lock while it drains a
                // batch; others serve previous batches meanwhile. The
                // first-element wait polls the stop flag: live handles may
                // keep the channel open past shutdown, so Disconnected
                // alone is not a sufficient exit signal.
                let batch = {
                    let guard = rx.lock().unwrap();
                    next_batch_or_stop(&guard, &policy, &stop)
                };
                let Some(batch) = batch else { break };
                metrics.record_batch();
                for req in batch {
                    let ids = index.search(&req.query, req.k, req.ef);
                    let latency = req.submitted.elapsed().as_secs_f64();
                    metrics.record_request(latency);
                    let _ = req.reply.send(QueryResponse {
                        ids,
                        latency_s: latency,
                    });
                    inflight.fetch_sub(1, Ordering::Relaxed);
                }
            }));
        }
        Server {
            tx: Some(tx),
            metrics,
            workers,
            stopping,
            inflight,
        }
    }

    /// Create a handle for submitting queries.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.as_ref().expect("server running").clone(),
            metrics: self.metrics.clone(),
            stopping: self.stopping.clone(),
            inflight: self.inflight.clone(),
        }
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) -> crate::coordinator::metrics::MetricsSnapshot {
        self.stopping.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<QueryRequest>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a query; returns the reply receiver, or `None` when the
    /// server rejects (shutting down / queue full — backpressure).
    pub fn submit(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<Receiver<QueryResponse>> {
        if self.stopping.load(Ordering::Relaxed) {
            self.metrics.record_rejected();
            return None;
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = QueryRequest {
            query,
            k,
            ef,
            submitted: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Some(reply_rx)
            }
            Err(_) => {
                self.metrics.record_rejected();
                None
            }
        }
    }

    /// Blocking convenience: submit + wait.
    pub fn query(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<QueryResponse> {
        self.submit(query, k, ef)?.recv().ok()
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    fn make_server(queue_depth: usize) -> (Server, crate::dataset::Dataset) {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 93);
        ds.compute_ground_truth(5);
        let idx: Arc<dyn AnnIndex> =
            Arc::new(BruteForceIndex::build(VectorSet::from_dataset(&ds)));
        let server = Server::start(
            idx,
            ServerConfig {
                workers: 2,
                queue_depth,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        (server, ds)
    }

    #[test]
    fn serves_correct_results() {
        let (server, ds) = make_server(128);
        let h = server.handle();
        for qi in 0..10 {
            let resp = h.query(ds.query_vec(qi).to_vec(), 5, 0).unwrap();
            assert_eq!(resp.ids, ds.gt[qi][..5].to_vec(), "query {qi}");
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 10);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn concurrent_clients() {
        let (server, ds) = make_server(256);
        let h = server.handle();
        let ds = Arc::new(ds);
        let mut clients = Vec::new();
        for c in 0..4 {
            let h = h.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for qi in 0..10 {
                    let q = ds.query_vec((c * 7 + qi) % ds.n_queries()).to_vec();
                    let resp = h.query(q, 5, 0).unwrap();
                    assert_eq!(resp.ids.len(), 5);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 40);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (server, ds) = make_server(1);
        let h = server.handle();
        // Flood without reading replies; with queue depth 1 at least one
        // submit must be rejected.
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match h.submit(ds.query_vec(0).to_vec(), 5, 0) {
                Some(r) => receivers.push(r),
                None => rejected += 1,
            }
        }
        for r in receivers {
            let _ = r.recv();
        }
        let snap = server.shutdown();
        assert!(rejected > 0 || snap.rejected > 0);
    }

    #[test]
    fn shutdown_is_clean() {
        let (server, _) = make_server(16);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 0);
    }
}
