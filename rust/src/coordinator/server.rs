//! Query server: bounded ingress queue (backpressure), dynamic batching,
//! worker threads over a shared index, per-request latency metrics.
//!
//! Thread-based rather than async: the workload is CPU-bound graph
//! traversal; a tokio reactor would add no concurrency on this substrate
//! (and tokio is unavailable offline — DESIGN.md §8).
//!
//! Two backends:
//! * [`Server::start`] — a read-only `Arc<dyn AnnIndex>`; mutation
//!   requests are answered with an error (the index is immutable).
//! * [`Server::start_mutable`] — an `Arc<RwLock<Box<dyn
//!   MutableAnnIndex>>>`: searches share the read lock (and still batch
//!   through one `search_batch` per `(k, ef)` group), while
//!   inserts/deletes take the write lock briefly per mutation.
//!
//! Mutations ride the same bounded queue and dynamic batcher as searches
//! ([`QueryRequest`] is an enum). Within one drained batch the worker
//! applies mutations first, in arrival order, then serves the batch's
//! searches — so a search batched together with a delete never resurrects
//! the deleted id. Across batches/workers, ordering is whatever the locks
//! give (as in any concurrent store); every response is keyed to its own
//! reply channel, so results never cross requests.

use crate::anns::store::VectorLog;
use crate::anns::{AnnIndex, FilterBitset, FilterExpr, MetadataStore, MutableAnnIndex};
use crate::coordinator::batcher::{group_by_key, next_batch_or_stop, BatchPolicy};
use crate::coordinator::metrics::Metrics;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// The shared-ownership shape a mutable backend is served from.
pub type SharedMutableIndex = Arc<RwLock<Box<dyn MutableAnnIndex>>>;

/// The shared-ownership shape the id → tenant/tags store is served from:
/// searches compile filter expressions under the read lock, inserts that
/// carry metadata update it under the write lock.
pub type SharedMetadata = Arc<RwLock<MetadataStore>>;

/// The shared-ownership shape of the durability log: one append (with
/// fsync) at a time, taken by whichever worker just applied a mutation.
pub type SharedLog = Arc<Mutex<VectorLog>>;

/// One request through the serving queue: a search or a mutation.
pub enum QueryRequest {
    Search(SearchRequest),
    Insert(InsertRequest),
    Delete(DeleteRequest),
}

/// One query.
pub struct SearchRequest {
    pub query: Vec<f32>,
    pub k: usize,
    pub ef: usize,
    /// Optional metadata predicate (tenant equality, tag membership,
    /// conjunctions). Compiled to a [`FilterBitset`] against the server's
    /// metadata store once per `(k, ef, filter)` batch group; `None` is
    /// the unfiltered fast path, bitwise identical to pre-filter serving.
    pub filter: Option<FilterExpr>,
    pub submitted: Instant,
    /// Reply channel.
    pub reply: SyncSender<QueryResponse>,
}

/// One online insert.
pub struct InsertRequest {
    pub vector: Vec<f32>,
    /// Metadata recorded for the assigned id (only when the server was
    /// started with a metadata store).
    pub tenant: Option<String>,
    pub tags: Vec<String>,
    pub submitted: Instant,
    pub reply: SyncSender<MutationResponse>,
}

/// One tombstone delete.
pub struct DeleteRequest {
    pub id: u32,
    pub submitted: Instant,
    pub reply: SyncSender<MutationResponse>,
}

/// Outcome of a mutation: the assigned id for inserts (the echoed id for
/// deletes), or the index's error rendered as a string.
#[derive(Clone, Debug)]
pub struct MutationResponse {
    pub result: Result<u32, String>,
    pub latency_s: f64,
}

/// The answer: ids nearest-first with their exact distances (`dists[i]`
/// belongs to `ids[i]`) — the distance-carrying `AnnIndex` trait means the
/// serving layer no longer throws distances away at the trait boundary.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub ids: Vec<u32>,
    pub dists: Vec<f32>,
    pub latency_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::threadpool::effective_threads(),
            queue_depth: 1024,
            batch: BatchPolicy::default(),
        }
    }
}

impl ServerConfig {
    /// Size the server from a tuned-config artifact's serving knobs:
    /// worker count from `serving.threads` (0 = auto, the ambient
    /// [`crate::util::threadpool::effective_threads`]) and the batcher's
    /// max batch from `serving.batch`. Everything else keeps its default.
    pub fn from_tuned(artifact: &crate::variants::TunedArtifact) -> ServerConfig {
        let serving = &artifact.config.serving;
        ServerConfig {
            workers: match serving.threads {
                0 => crate::util::threadpool::effective_threads(),
                t => t,
            },
            batch: BatchPolicy {
                max_batch: serving.batch.max(1),
                ..BatchPolicy::default()
            },
            ..ServerConfig::default()
        }
    }
}

/// The index a worker serves from: read-only, or mutable behind a lock.
#[derive(Clone)]
enum Backend {
    Fixed(Arc<dyn AnnIndex>),
    Mutable(SharedMutableIndex),
}

impl Backend {
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        match self {
            Backend::Fixed(index) => index.search_batch(queries, k, ef),
            Backend::Mutable(index) => index.read().unwrap().search_batch(queries, k, ef),
        }
    }

    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        match self {
            Backend::Fixed(index) => index.search_filtered_batch(queries, k, ef, filter),
            Backend::Mutable(index) => {
                index.read().unwrap().search_filtered_batch(queries, k, ef, filter)
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            Backend::Fixed(index) => index.len(),
            Backend::Mutable(index) => index.read().unwrap().len(),
        }
    }

    fn filtered_fallback_threshold(&self) -> usize {
        match self {
            Backend::Fixed(index) => index.filtered_fallback_threshold(),
            Backend::Mutable(index) => index.read().unwrap().filtered_fallback_threshold(),
        }
    }

    /// Apply one mutation under the write lock. The live-point gauge is
    /// updated while the lock is still held, so concurrent workers can
    /// never publish a stale count over a newer one.
    fn apply(&self, op: &Mutation, metrics: &Metrics) -> Result<u32, String> {
        match self {
            Backend::Fixed(_) => {
                Err("index is immutable (serve it with Server::start_mutable)".to_string())
            }
            Backend::Mutable(index) => {
                let mut idx = index.write().unwrap();
                let result = match op {
                    Mutation::Insert(v) => idx.insert(v).map_err(|e| format!("{e:#}")),
                    Mutation::Delete(id) => {
                        idx.delete(*id).map(|_| *id).map_err(|e| format!("{e:#}"))
                    }
                };
                metrics.set_live_points(idx.live_count() as u64);
                result
            }
        }
    }
}

enum Mutation {
    Insert(Vec<f32>),
    Delete(u32),
}

/// A running server. Submit with [`Server::handle`]; drop to stop.
pub struct Server {
    tx: Option<SyncSender<QueryRequest>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl Server {
    /// Start worker threads over a shared read-only index. Mutation
    /// requests submitted to this server are answered with an error, and
    /// filtered searches (there is no metadata store) match nothing.
    pub fn start(index: Arc<dyn AnnIndex>, config: ServerConfig) -> Server {
        Server::start_backend(Backend::Fixed(index), None, None, config)
    }

    /// [`Server::start`] plus a metadata store: filter expressions compile
    /// against it, and inserts are still rejected (read-only backend).
    pub fn start_with_metadata(
        index: Arc<dyn AnnIndex>,
        metadata: SharedMetadata,
        config: ServerConfig,
    ) -> Server {
        Server::start_backend(Backend::Fixed(index), Some(metadata), None, config)
    }

    /// Start worker threads over a mutable index: searches share the read
    /// lock, inserts/deletes serialize on the write lock, and the
    /// tombstone/consolidation semantics come from the index itself.
    pub fn start_mutable(index: SharedMutableIndex, config: ServerConfig) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server = Server::start_backend(Backend::Mutable(index), None, None, config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    /// [`Server::start_mutable`] plus a metadata store: filter expressions
    /// compile against it and successful inserts record their
    /// tenant/tags for the assigned id.
    pub fn start_mutable_with_metadata(
        index: SharedMutableIndex,
        metadata: SharedMetadata,
        config: ServerConfig,
    ) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server =
            Server::start_backend(Backend::Mutable(index), Some(metadata), None, config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    /// [`Server::start_mutable`] with durability: every acked mutation is
    /// appended (checksummed, fsync'd) to the shared mutation log before
    /// the client sees the ack, so a crash loses nothing that was acked —
    /// restart through `anns::store::restore_glass` replays the log tail
    /// on top of the last snapshot. An apply that succeeds but fails to
    /// log is acked as an error (`"applied but not logged"`): the client
    /// must not count on a mutation the next restart may not see.
    pub fn start_durable(
        index: SharedMutableIndex,
        metadata: Option<SharedMetadata>,
        wal: SharedLog,
        config: ServerConfig,
    ) -> Server {
        let metrics_live = index.read().unwrap().live_count() as u64;
        let server = Server::start_backend(Backend::Mutable(index), metadata, Some(wal), config);
        server.metrics.set_live_points(metrics_live);
        server
    }

    fn start_backend(
        backend: Backend,
        metadata: Option<SharedMetadata>,
        wal: Option<SharedLog>,
        config: ServerConfig,
    ) -> Server {
        let (tx, rx) = sync_channel::<QueryRequest>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = rx.clone();
            let backend = backend.clone();
            let metadata = metadata.clone();
            let wal = wal.clone();
            let metrics = metrics.clone();
            let policy = config.batch.clone();
            let inflight = inflight.clone();
            let stop = stopping.clone();
            workers.push(std::thread::spawn(move || loop {
                // One worker holds the receiver lock while it drains a
                // batch; others serve previous batches meanwhile. The
                // first-element wait polls the stop flag: live handles may
                // keep the channel open past shutdown, so Disconnected
                // alone is not a sufficient exit signal.
                let batch = {
                    let guard = rx.lock().unwrap();
                    next_batch_or_stop(&guard, &policy, &stop)
                };
                let Some(batch) = batch else { break };
                metrics.record_batch();
                // Split the drained batch: mutations apply first (arrival
                // order preserved), then the searches — so a search
                // batched alongside a delete observes it. One shared
                // apply-and-reply block serves both mutation kinds, so
                // the accounting protocol cannot drift between them.
                let mut searches = Vec::with_capacity(batch.len());
                for req in batch {
                    let (op, reply, submitted, ins_meta) = match req {
                        QueryRequest::Search(s) => {
                            searches.push(s);
                            continue;
                        }
                        QueryRequest::Insert(r) => (
                            Mutation::Insert(r.vector),
                            r.reply,
                            r.submitted,
                            Some((r.tenant, r.tags)),
                        ),
                        QueryRequest::Delete(r) => {
                            (Mutation::Delete(r.id), r.reply, r.submitted, None)
                        }
                    };
                    let is_insert = ins_meta.is_some();
                    let result = backend.apply(&op, &metrics);
                    // Record the insert's tenant/tags under the assigned id
                    // before replying: once the client holds the ack, a
                    // filtered search must already see the metadata.
                    if let (Ok(id), Some(meta), Some((tenant, tags))) =
                        (&result, metadata.as_ref(), ins_meta.as_ref())
                    {
                        let tags: Vec<&str> = tags.iter().map(|t| t.as_str()).collect();
                        meta.write().unwrap().set_for(*id, tenant.as_deref(), &tags);
                    }
                    // Durable write-through: the applied mutation reaches
                    // the fsync'd log before the client sees the ack. A
                    // mutation that applied but failed to log is acked as
                    // an error — the client must not rely on state the
                    // next restart may not replay.
                    let result = match (result, wal.as_ref()) {
                        (Ok(id), Some(wal)) => {
                            let mut w = wal.lock().unwrap();
                            let logged = match &op {
                                Mutation::Insert(v) => {
                                    w.append_vector(id, v).and_then(|()| match &ins_meta {
                                        Some((tenant, tags))
                                            if tenant.is_some() || !tags.is_empty() =>
                                        {
                                            let tags: Vec<&str> =
                                                tags.iter().map(|t| t.as_str()).collect();
                                            w.append_metadata(id, tenant.as_deref(), &tags)
                                        }
                                        _ => Ok(()),
                                    })
                                }
                                Mutation::Delete(_) => w.append_tombstone(id),
                            };
                            match logged {
                                Ok(()) => Ok(id),
                                Err(e) => Err(format!("applied but not logged: {e:#}")),
                            }
                        }
                        (other, _) => other,
                    };
                    match (&result, is_insert) {
                        (Ok(_), true) => metrics.record_insert(),
                        (Ok(_), false) => metrics.record_delete(),
                        (Err(_), _) => metrics.record_mutation_error(),
                    }
                    let _ = reply.send(MutationResponse {
                        result,
                        latency_s: submitted.elapsed().as_secs_f64(),
                    });
                    inflight.fetch_sub(1, Ordering::Relaxed);
                }
                // Serve each (k, ef, filter) group through one multi-query
                // `search_batch` call — the index reuses a single pooled
                // scratch context across the group, and results are
                // bitwise identical to per-request `search_with_dists`.
                // A filter expression is compiled to a bitset ONCE per
                // group under the metadata read lock; with no store, a
                // filtered query matches nothing (deny-safe).
                for ((k, ef, filter), group) in
                    group_by_key(searches, |r| (r.k, r.ef, r.filter.clone()))
                {
                    let queries: Vec<&[f32]> =
                        group.iter().map(|r| r.query.as_slice()).collect();
                    let results = match &filter {
                        None => backend.search_batch(&queries, k, ef),
                        Some(expr) => {
                            let bitset = match metadata.as_ref() {
                                Some(meta) => {
                                    meta.read().unwrap().compile(expr, backend.len())
                                }
                                None => FilterBitset::new(backend.len()),
                            };
                            metrics.record_filtered(group.len());
                            if bitset.count() <= backend.filtered_fallback_threshold() {
                                metrics.record_filtered_fallback(group.len());
                            }
                            backend.search_filtered_batch(&queries, k, ef, Some(&bitset))
                        }
                    };
                    metrics.record_group(group.len());
                    for (req, pairs) in group.into_iter().zip(results) {
                        let latency = req.submitted.elapsed().as_secs_f64();
                        metrics.record_request(latency);
                        let (dists, ids) = pairs.into_iter().unzip();
                        let _ = req.reply.send(QueryResponse {
                            ids,
                            dists,
                            latency_s: latency,
                        });
                        inflight.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        Server {
            tx: Some(tx),
            metrics,
            workers,
            stopping,
            inflight,
        }
    }

    /// Create a handle for submitting queries.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.as_ref().expect("server running").clone(),
            metrics: self.metrics.clone(),
            stopping: self.stopping.clone(),
            inflight: self.inflight.clone(),
        }
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) -> crate::coordinator::metrics::MetricsSnapshot {
        self.stopping.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<QueryRequest>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Enqueue one request; shared admission control for searches and
    /// mutations (stop flag, bounded-queue backpressure, inflight count).
    fn push(&self, req: QueryRequest) -> bool {
        if self.stopping.load(Ordering::Relaxed) {
            self.metrics.record_rejected();
            return false;
        }
        match self.tx.try_send(req) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.metrics.record_rejected();
                false
            }
        }
    }

    /// Submit a query; returns the reply receiver, or `None` when the
    /// server rejects (shutting down / queue full — backpressure).
    pub fn submit(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<Receiver<QueryResponse>> {
        self.submit_filtered(query, k, ef, None)
    }

    /// Submit a query with an optional metadata filter; `filter = None`
    /// is exactly [`Self::submit`].
    pub fn submit_filtered(
        &self,
        query: Vec<f32>,
        k: usize,
        ef: usize,
        filter: Option<FilterExpr>,
    ) -> Option<Receiver<QueryResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Search(SearchRequest {
            query,
            k,
            ef,
            filter,
            submitted: Instant::now(),
            reply: reply_tx,
        }))
        .then_some(reply_rx)
    }

    /// Submit an online insert; same admission control as [`Self::submit`].
    pub fn submit_insert(&self, vector: Vec<f32>) -> Option<Receiver<MutationResponse>> {
        self.submit_insert_with_metadata(vector, None, Vec::new())
    }

    /// Submit an online insert carrying tenant/tags for the assigned id
    /// (recorded only when the server holds a metadata store).
    pub fn submit_insert_with_metadata(
        &self,
        vector: Vec<f32>,
        tenant: Option<String>,
        tags: Vec<String>,
    ) -> Option<Receiver<MutationResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Insert(InsertRequest {
            vector,
            tenant,
            tags,
            submitted: Instant::now(),
            reply: reply_tx,
        }))
        .then_some(reply_rx)
    }

    /// Submit a tombstone delete; same admission control as
    /// [`Self::submit`].
    pub fn submit_delete(&self, id: u32) -> Option<Receiver<MutationResponse>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.push(QueryRequest::Delete(DeleteRequest {
            id,
            submitted: Instant::now(),
            reply: reply_tx,
        }))
        .then_some(reply_rx)
    }

    /// Blocking convenience: submit + wait.
    pub fn query(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<QueryResponse> {
        self.submit(query, k, ef)?.recv().ok()
    }

    /// Blocking convenience: filtered submit + wait.
    pub fn query_filtered(
        &self,
        query: Vec<f32>,
        k: usize,
        ef: usize,
        filter: Option<FilterExpr>,
    ) -> Option<QueryResponse> {
        self.submit_filtered(query, k, ef, filter)?.recv().ok()
    }

    /// Blocking convenience: insert + wait for the assigned id.
    pub fn insert(&self, vector: Vec<f32>) -> Option<MutationResponse> {
        self.submit_insert(vector)?.recv().ok()
    }

    /// Blocking convenience: insert with tenant/tags + wait.
    pub fn insert_with_metadata(
        &self,
        vector: Vec<f32>,
        tenant: Option<String>,
        tags: Vec<String>,
    ) -> Option<MutationResponse> {
        self.submit_insert_with_metadata(vector, tenant, tags)?
            .recv()
            .ok()
    }

    /// Blocking convenience: delete + wait for the ack.
    pub fn delete(&self, id: u32) -> Option<MutationResponse> {
        self.submit_delete(id)?.recv().ok()
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    fn make_server(queue_depth: usize) -> (Server, crate::dataset::Dataset) {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 93);
        ds.compute_ground_truth(5);
        let idx: Arc<dyn AnnIndex> =
            Arc::new(BruteForceIndex::build(VectorSet::from_dataset(&ds)));
        let server = Server::start(
            idx,
            ServerConfig {
                workers: 2,
                queue_depth,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        (server, ds)
    }

    #[test]
    fn serves_correct_results() {
        let (server, ds) = make_server(128);
        let h = server.handle();
        for qi in 0..10 {
            let resp = h.query(ds.query_vec(qi).to_vec(), 5, 0).unwrap();
            assert_eq!(resp.ids, ds.gt[qi][..5].to_vec(), "query {qi}");
            assert_eq!(resp.dists.len(), resp.ids.len());
            // Distances surfaced by the server are the exact metric values.
            for (&id, &d) in resp.ids.iter().zip(&resp.dists) {
                let want = ds.metric.distance(ds.query_vec(qi), ds.base_vec(id as usize));
                assert_eq!(d, want, "query {qi} id {id}");
            }
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 10);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn coordinator_batched_distances_match_direct_search() {
        // The serving path goes through `search_batch` grouped by (k, ef);
        // every response's (dist, id) pairs must be bitwise identical to a
        // direct `search_with_dists` call on the underlying index — the
        // trait-level batch identity observed end to end through the
        // coordinator, on the real GLASS pipeline with mixed parameters.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 94);
        ds.compute_ground_truth(5);
        let idx = Arc::new(crate::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(&ds),
            crate::variants::VariantConfig::glass_baseline(),
            3,
        ));
        let index: Arc<dyn AnnIndex> = idx.clone();
        let server = Server::start(
            index,
            ServerConfig {
                workers: 2,
                queue_depth: 256,
                batch: BatchPolicy {
                    max_batch: 16,
                    max_wait: std::time::Duration::from_millis(2),
                },
            },
        );
        let h = server.handle();
        // Mixed (k, ef) across the flood exercises the per-group dispatch.
        let mut pending = Vec::new();
        for qi in 0..ds.n_queries() {
            let (k, ef) = if qi % 2 == 0 { (5, 64) } else { (3, 32) };
            let rx = h.submit(ds.query_vec(qi).to_vec(), k, ef).unwrap();
            pending.push((qi, k, ef, rx));
        }
        for (qi, k, ef, rx) in pending {
            let resp = rx.recv().unwrap();
            let got: Vec<(f32, u32)> = resp
                .dists
                .iter()
                .copied()
                .zip(resp.ids.iter().copied())
                .collect();
            let want = idx.search_with_dists(ds.query_vec(qi), k, ef);
            assert_eq!(got, want, "query {qi} k={k} ef={ef}");
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests as usize, ds.n_queries());
    }

    #[test]
    fn concurrent_clients() {
        let (server, ds) = make_server(256);
        let h = server.handle();
        let ds = Arc::new(ds);
        let mut clients = Vec::new();
        for c in 0..4 {
            let h = h.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for qi in 0..10 {
                    let q = ds.query_vec((c * 7 + qi) % ds.n_queries()).to_vec();
                    let resp = h.query(q, 5, 0).unwrap();
                    assert_eq!(resp.ids.len(), 5);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 40);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (server, ds) = make_server(1);
        let h = server.handle();
        // Flood without reading replies; with queue depth 1 at least one
        // submit must be rejected.
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match h.submit(ds.query_vec(0).to_vec(), 5, 0) {
                Some(r) => receivers.push(r),
                None => rejected += 1,
            }
        }
        for r in receivers {
            let _ = r.recv();
        }
        let snap = server.shutdown();
        assert!(rejected > 0 || snap.rejected > 0);
    }

    #[test]
    fn shutdown_is_clean() {
        let (server, _) = make_server(16);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 0);
    }

    #[test]
    fn mutation_on_immutable_server_errors_cleanly() {
        let (server, ds) = make_server(64);
        let h = server.handle();
        let resp = h.insert(ds.base_vec(0).to_vec()).unwrap();
        assert!(resp.result.is_err(), "immutable backend accepted an insert");
        assert!(resp.result.unwrap_err().contains("immutable"));
        let resp = h.delete(3).unwrap();
        assert!(resp.result.is_err());
        // Searches still work on the same server.
        assert!(h.query(ds.query_vec(0).to_vec(), 5, 0).is_some());
        let snap = server.shutdown();
        assert_eq!(snap.mutation_errors, 2);
        assert_eq!((snap.inserts, snap.deletes), (0, 0));
    }

    #[test]
    fn filtered_queries_end_to_end() {
        // Filter expressions compile against the metadata store, inserts
        // carry tenant/tags, and the counters reconcile.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 300, 10, 90);
        ds.compute_ground_truth(5);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let mut store = MetadataStore::new();
        for id in 0..300u32 {
            let tenant = format!("t{}", id % 3);
            let tags: &[&str] = if id % 2 == 0 { &["even"] } else { &[] };
            store.push(Some(&tenant), tags);
        }
        let metadata: SharedMetadata = Arc::new(RwLock::new(store));
        let server = Server::start_mutable_with_metadata(
            index,
            metadata.clone(),
            ServerConfig {
                workers: 2,
                queue_depth: 128,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        // filter=None serves the unfiltered path.
        let resp = h.query_filtered(ds.query_vec(0).to_vec(), 5, 0, None).unwrap();
        assert_eq!(resp.ids, ds.gt[0][..5].to_vec());
        // Tenant filter: every id belongs to t1.
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tenant("t1")))
            .unwrap();
        assert_eq!(resp.ids.len(), 5);
        assert!(resp.ids.iter().all(|&id| id % 3 == 1), "{:?}", resp.ids);
        // Conjunction: tenant t1 AND tag "even" → id ≡ 4 (mod 6).
        let conj = FilterExpr::and(vec![FilterExpr::tenant("t1"), FilterExpr::tag("even")]);
        let resp = h
            .query_filtered(ds.query_vec(1).to_vec(), 5, 0, Some(conj))
            .unwrap();
        assert!(resp.ids.iter().all(|&id| id % 3 == 1 && id % 2 == 0));
        // Unknown names match nothing.
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tag("nope")))
            .unwrap();
        assert!(resp.ids.is_empty());
        // An insert carrying metadata is immediately filterable once acked.
        let ack = h
            .insert_with_metadata(
                ds.query_vec(2).to_vec(),
                Some("t1".to_string()),
                vec!["even".to_string()],
            )
            .unwrap();
        let new_id = ack.result.expect("insert must succeed");
        let resp = h
            .query_filtered(ds.query_vec(2).to_vec(), 1, 0, Some(FilterExpr::tenant("t1")))
            .unwrap();
        assert_eq!((resp.ids, resp.dists), (vec![new_id], vec![0.0]));
        assert_eq!(metadata.read().unwrap().tenant(new_id), Some("t1"));
        let snap = server.shutdown();
        assert_eq!(snap.filtered_queries, 4);
        assert_eq!(snap.requests, 5);
    }

    #[test]
    fn filtered_query_without_store_matches_nothing() {
        // A filter on a server started without a metadata store is
        // deny-safe: it cannot be satisfied, so it returns no ids (rather
        // than silently ignoring the predicate).
        let (server, ds) = make_server(64);
        let h = server.handle();
        let resp = h
            .query_filtered(ds.query_vec(0).to_vec(), 5, 0, Some(FilterExpr::tenant("t0")))
            .unwrap();
        assert!(resp.ids.is_empty());
        let unfiltered = h.query_filtered(ds.query_vec(0).to_vec(), 5, 0, None).unwrap();
        assert_eq!(unfiltered.ids, ds.gt[0][..5].to_vec());
        let snap = server.shutdown();
        assert_eq!(snap.filtered_queries, 1);
        // The empty bitset is at or below every fallback threshold.
        assert_eq!(snap.filtered_fallbacks, 1);
    }

    #[test]
    fn durable_server_logs_every_acked_mutation() {
        use crate::anns::store::LogRecord;
        // Every acked mutation must be in the log after shutdown, in ack
        // order; a rejected mutation must NOT be.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 200, 5, 95);
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let metadata: SharedMetadata = Arc::new(RwLock::new(MetadataStore::new()));
        let path = std::env::temp_dir()
            .join(format!("crinn_{}_server_durable.wal", std::process::id()));
        let wal: SharedLog = Arc::new(Mutex::new(VectorLog::create(&path).unwrap()));
        let server = Server::start_durable(
            index,
            Some(metadata),
            wal,
            ServerConfig {
                workers: 2,
                queue_depth: 64,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        let h = server.handle();
        // Sequential (wait for each ack) so the log order is fixed.
        let inserted = h
            .insert_with_metadata(
                ds.query_vec(0).to_vec(),
                Some("t1".to_string()),
                vec!["hot".to_string()],
            )
            .unwrap()
            .result
            .unwrap();
        let plain = h.insert(ds.query_vec(1).to_vec()).unwrap().result.unwrap();
        assert_eq!(h.delete(3).unwrap().result, Ok(3));
        assert!(h.delete(3).unwrap().result.is_err(), "double delete rejected");
        server.shutdown();

        let (records, _) = VectorLog::recover(&path).unwrap();
        assert_eq!(
            records,
            vec![
                LogRecord::Vector {
                    id: inserted,
                    vector: ds.query_vec(0).to_vec()
                },
                LogRecord::Metadata {
                    id: inserted,
                    tenant: Some("t1".to_string()),
                    tags: vec!["hot".to_string()]
                },
                // A metadata-free insert logs no metadata record.
                LogRecord::Vector {
                    id: plain,
                    vector: ds.query_vec(1).to_vec()
                },
                LogRecord::Tombstone { id: 3 },
            ]
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mutation_update_path_end_to_end() {
        // Sequential (submit + wait each step) so the interleaving is
        // deterministic: an acked delete must be invisible to the next
        // search, an acked insert must be findable, and the counters/live
        // gauge must reconcile exactly.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 97);
        ds.compute_ground_truth(6); // k=5 served + 1 spare for the delete
        let index: crate::coordinator::SharedMutableIndex = Arc::new(RwLock::new(Box::new(
            BruteForceIndex::build(VectorSet::from_dataset(&ds)),
        )));
        let server = Server::start_mutable(
            index.clone(),
            ServerConfig {
                workers: 2,
                queue_depth: 128,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        assert_eq!(server.metrics.live_points.load(Ordering::Relaxed), 400);
        let h = server.handle();
        // Delete the exact NN of query 0: the served result must shift to
        // the remainder of the ground-truth list.
        let victim = ds.gt[0][0];
        let ack = h.delete(victim).unwrap();
        assert_eq!(ack.result, Ok(victim));
        let resp = h.query(ds.query_vec(0).to_vec(), 5, 0).unwrap();
        assert_eq!(resp.ids, ds.gt[0][1..6].to_vec());
        // Insert the query vector itself: it becomes its own NN.
        let ack = h.insert(ds.query_vec(0).to_vec()).unwrap();
        let new_id = ack.result.expect("insert must succeed");
        assert_eq!(new_id, 400);
        let resp = h.query(ds.query_vec(0).to_vec(), 1, 0).unwrap();
        assert_eq!(resp.ids, vec![new_id]);
        assert_eq!(resp.dists, vec![0.0]);
        // Double delete errors but does not poison the server.
        let ack = h.delete(victim).unwrap();
        assert!(ack.result.is_err());
        let snap = server.shutdown();
        assert_eq!((snap.inserts, snap.deletes, snap.mutation_errors), (1, 1, 1));
        assert_eq!(snap.live_points, 400); // 400 - 1 deleted + 1 inserted
        assert_eq!(snap.requests, 2, "searches counted separately from mutations");
        // The mutations really landed in the shared index.
        let idx = index.read().unwrap();
        assert_eq!(idx.live_count(), 400);
        assert!(idx.is_deleted(victim));
    }
}
