//! Query server: bounded ingress queue (backpressure), dynamic batching,
//! worker threads over a shared index, per-request latency metrics.
//!
//! Thread-based rather than async: the workload is CPU-bound graph
//! traversal; a tokio reactor would add no concurrency on this substrate
//! (and tokio is unavailable offline — DESIGN.md §8).

use crate::anns::AnnIndex;
use crate::coordinator::batcher::{group_by_key, next_batch_or_stop, BatchPolicy};
use crate::coordinator::metrics::Metrics;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One query.
pub struct QueryRequest {
    pub query: Vec<f32>,
    pub k: usize,
    pub ef: usize,
    pub submitted: Instant,
    /// Reply channel.
    pub reply: SyncSender<QueryResponse>,
}

/// The answer: ids nearest-first with their exact distances (`dists[i]`
/// belongs to `ids[i]`) — the distance-carrying `AnnIndex` trait means the
/// serving layer no longer throws distances away at the trait boundary.
#[derive(Clone, Debug)]
pub struct QueryResponse {
    pub ids: Vec<u32>,
    pub dists: Vec<f32>,
    pub latency_s: f64,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub queue_depth: usize,
    pub batch: BatchPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            workers: crate::util::threadpool::effective_threads(),
            queue_depth: 1024,
            batch: BatchPolicy::default(),
        }
    }
}

/// A running server. Submit with [`Server::handle`]; drop to stop.
pub struct Server {
    tx: Option<SyncSender<QueryRequest>>,
    pub metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl Server {
    /// Start worker threads over a shared index.
    pub fn start(index: Arc<dyn AnnIndex>, config: ServerConfig) -> Server {
        let (tx, rx) = sync_channel::<QueryRequest>(config.queue_depth.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let inflight = Arc::new(AtomicUsize::new(0));
        let mut workers = Vec::new();
        for _ in 0..config.workers.max(1) {
            let rx = rx.clone();
            let index = index.clone();
            let metrics = metrics.clone();
            let policy = config.batch.clone();
            let inflight = inflight.clone();
            let stop = stopping.clone();
            workers.push(std::thread::spawn(move || loop {
                // One worker holds the receiver lock while it drains a
                // batch; others serve previous batches meanwhile. The
                // first-element wait polls the stop flag: live handles may
                // keep the channel open past shutdown, so Disconnected
                // alone is not a sufficient exit signal.
                let batch = {
                    let guard = rx.lock().unwrap();
                    next_batch_or_stop(&guard, &policy, &stop)
                };
                let Some(batch) = batch else { break };
                metrics.record_batch();
                // Serve each (k, ef) group through one multi-query
                // `search_batch` call — the index reuses a single pooled
                // scratch context across the group, and results are
                // bitwise identical to per-request `search_with_dists`.
                for ((k, ef), group) in group_by_key(batch, |r| (r.k, r.ef)) {
                    let queries: Vec<&[f32]> =
                        group.iter().map(|r| r.query.as_slice()).collect();
                    let results = index.search_batch(&queries, k, ef);
                    metrics.record_group(group.len());
                    for (req, pairs) in group.into_iter().zip(results) {
                        let latency = req.submitted.elapsed().as_secs_f64();
                        metrics.record_request(latency);
                        let (dists, ids) = pairs.into_iter().unzip();
                        let _ = req.reply.send(QueryResponse {
                            ids,
                            dists,
                            latency_s: latency,
                        });
                        inflight.fetch_sub(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        Server {
            tx: Some(tx),
            metrics,
            workers,
            stopping,
            inflight,
        }
    }

    /// Create a handle for submitting queries.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle {
            tx: self.tx.as_ref().expect("server running").clone(),
            metrics: self.metrics.clone(),
            stopping: self.stopping.clone(),
            inflight: self.inflight.clone(),
        }
    }

    /// Stop accepting work and join the workers.
    pub fn shutdown(mut self) -> crate::coordinator::metrics::MetricsSnapshot {
        self.stopping.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.metrics.snapshot()
    }
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct ServerHandle {
    tx: SyncSender<QueryRequest>,
    metrics: Arc<Metrics>,
    stopping: Arc<AtomicBool>,
    inflight: Arc<AtomicUsize>,
}

impl ServerHandle {
    /// Submit a query; returns the reply receiver, or `None` when the
    /// server rejects (shutting down / queue full — backpressure).
    pub fn submit(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<Receiver<QueryResponse>> {
        if self.stopping.load(Ordering::Relaxed) {
            self.metrics.record_rejected();
            return None;
        }
        let (reply_tx, reply_rx) = sync_channel(1);
        let req = QueryRequest {
            query,
            k,
            ef,
            submitted: Instant::now(),
            reply: reply_tx,
        };
        match self.tx.try_send(req) {
            Ok(()) => {
                self.inflight.fetch_add(1, Ordering::Relaxed);
                Some(reply_rx)
            }
            Err(_) => {
                self.metrics.record_rejected();
                None
            }
        }
    }

    /// Blocking convenience: submit + wait.
    pub fn query(&self, query: Vec<f32>, k: usize, ef: usize) -> Option<QueryResponse> {
        self.submit(query, k, ef)?.recv().ok()
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anns::bruteforce::BruteForceIndex;
    use crate::anns::VectorSet;
    use crate::dataset::synth;

    fn make_server(queue_depth: usize) -> (Server, crate::dataset::Dataset) {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 400, 30, 93);
        ds.compute_ground_truth(5);
        let idx: Arc<dyn AnnIndex> =
            Arc::new(BruteForceIndex::build(VectorSet::from_dataset(&ds)));
        let server = Server::start(
            idx,
            ServerConfig {
                workers: 2,
                queue_depth,
                batch: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
            },
        );
        (server, ds)
    }

    #[test]
    fn serves_correct_results() {
        let (server, ds) = make_server(128);
        let h = server.handle();
        for qi in 0..10 {
            let resp = h.query(ds.query_vec(qi).to_vec(), 5, 0).unwrap();
            assert_eq!(resp.ids, ds.gt[qi][..5].to_vec(), "query {qi}");
            assert_eq!(resp.dists.len(), resp.ids.len());
            // Distances surfaced by the server are the exact metric values.
            for (&id, &d) in resp.ids.iter().zip(&resp.dists) {
                let want = ds.metric.distance(ds.query_vec(qi), ds.base_vec(id as usize));
                assert_eq!(d, want, "query {qi} id {id}");
            }
            assert!(resp.latency_s >= 0.0);
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 10);
        assert!(snap.batches >= 1);
    }

    #[test]
    fn coordinator_batched_distances_match_direct_search() {
        // The serving path goes through `search_batch` grouped by (k, ef);
        // every response's (dist, id) pairs must be bitwise identical to a
        // direct `search_with_dists` call on the underlying index — the
        // trait-level batch identity observed end to end through the
        // coordinator, on the real GLASS pipeline with mixed parameters.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 800, 30, 94);
        ds.compute_ground_truth(5);
        let idx = Arc::new(crate::anns::glass::GlassIndex::build(
            VectorSet::from_dataset(&ds),
            crate::variants::VariantConfig::glass_baseline(),
            3,
        ));
        let index: Arc<dyn AnnIndex> = idx.clone();
        let server = Server::start(
            index,
            ServerConfig {
                workers: 2,
                queue_depth: 256,
                batch: BatchPolicy {
                    max_batch: 16,
                    max_wait: std::time::Duration::from_millis(2),
                },
            },
        );
        let h = server.handle();
        // Mixed (k, ef) across the flood exercises the per-group dispatch.
        let mut pending = Vec::new();
        for qi in 0..ds.n_queries() {
            let (k, ef) = if qi % 2 == 0 { (5, 64) } else { (3, 32) };
            let rx = h.submit(ds.query_vec(qi).to_vec(), k, ef).unwrap();
            pending.push((qi, k, ef, rx));
        }
        for (qi, k, ef, rx) in pending {
            let resp = rx.recv().unwrap();
            let got: Vec<(f32, u32)> = resp
                .dists
                .iter()
                .copied()
                .zip(resp.ids.iter().copied())
                .collect();
            let want = idx.search_with_dists(ds.query_vec(qi), k, ef);
            assert_eq!(got, want, "query {qi} k={k} ef={ef}");
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests as usize, ds.n_queries());
    }

    #[test]
    fn concurrent_clients() {
        let (server, ds) = make_server(256);
        let h = server.handle();
        let ds = Arc::new(ds);
        let mut clients = Vec::new();
        for c in 0..4 {
            let h = h.clone();
            let ds = ds.clone();
            clients.push(std::thread::spawn(move || {
                for qi in 0..10 {
                    let q = ds.query_vec((c * 7 + qi) % ds.n_queries()).to_vec();
                    let resp = h.query(q, 5, 0).unwrap();
                    assert_eq!(resp.ids.len(), 5);
                }
            }));
        }
        for c in clients {
            c.join().unwrap();
        }
        let snap = server.shutdown();
        assert_eq!(snap.requests, 40);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let (server, ds) = make_server(1);
        let h = server.handle();
        // Flood without reading replies; with queue depth 1 at least one
        // submit must be rejected.
        let mut receivers = Vec::new();
        let mut rejected = 0;
        for _ in 0..200 {
            match h.submit(ds.query_vec(0).to_vec(), 5, 0) {
                Some(r) => receivers.push(r),
                None => rejected += 1,
            }
        }
        for r in receivers {
            let _ = r.recv();
        }
        let snap = server.shutdown();
        assert!(rejected > 0 || snap.rejected > 0);
    }

    #[test]
    fn shutdown_is_clean() {
        let (server, _) = make_server(16);
        let snap = server.shutdown();
        assert_eq!(snap.requests, 0);
    }
}
