//! Non-blocking TCP front end for the serving coordinator.
//!
//! Zero-dependency in the style of the mmap FFI in `store/region.rs`:
//! raw `epoll(7)` syscalls on Linux, a portable `poll(2)` fallback on
//! other unixes, `std::net` non-blocking sockets everywhere — no tokio
//! (unavailable offline, and the workload is CPU-bound graph traversal;
//! see DESIGN.md §Network-Edge). One event-loop thread owns every
//! connection and the per-tenant admission controller; decoded requests
//! flow into the existing bounded queue + dynamic batcher through
//! [`super::server::ServerHandle::submit_request`] with
//! [`Reply::hook`] completions, and worker threads hand finished frames
//! back through a mutex-guarded completion list plus a loopback wake
//! socket.
//!
//! Protocol, admission, and deadline semantics live in [`super::proto`]
//! and [`super::admission`]; hostile frames (bad magic, oversized
//! length, checksum mismatch, undecodable body) get an error frame and a
//! connection close — never a panic, never unbounded buffering.

use super::admission::{Admission, AdmissionConfig, AdmissionController};
use super::metrics::{Metrics, MetricsSnapshot};
use super::proto::{self, Request, RequestFrame, Response};
use super::server::{
    DeleteRequest, InsertRequest, QueryRequest, Reply, SearchRequest, Server, ServerHandle,
};
use crate::util::error::{Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Readiness event surfaced by [`Poller`].
struct Event {
    token: u64,
    readable: bool,
    writable: bool,
}

/// Interest registration tokens: listener, wake pipe, then connections.
const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKE: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Per-read chunk size; frames larger than this just take several reads.
const READ_CHUNK: usize = 16 * 1024;

#[cfg(target_os = "linux")]
mod sys {
    //! `epoll(7)` via local `extern "C"` declarations (no libc crate).
    use super::{Event, RawFd};
    use crate::util::error::Result;

    // The kernel ABI packs epoll_event on x86-64 only.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EINTR: i32 = 4;

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub struct Poller {
        epfd: i32,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        pub fn new() -> Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            crate::ensure!(
                epfd >= 0,
                "epoll_create1 failed: {}",
                std::io::Error::last_os_error()
            );
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 64],
            })
        }

        fn ctl(&mut self, op: i32, fd: RawFd, events: u32, token: u64) -> Result<()> {
            let mut ev = EpollEvent {
                events,
                data: token,
            };
            let rc = unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) };
            crate::ensure!(
                rc == 0,
                "epoll_ctl(op={op}, fd={fd}) failed: {}",
                std::io::Error::last_os_error()
            );
            Ok(())
        }

        pub fn add(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, interest(readable, writable), token)
        }

        pub fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, interest(readable, writable), token)
        }

        pub fn remove(&mut self, fd: RawFd) -> Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> Result<()> {
            out.clear();
            let n = loop {
                let n = unsafe {
                    epoll_wait(
                        self.epfd,
                        self.buf.as_mut_ptr(),
                        self.buf.len() as i32,
                        timeout_ms,
                    )
                };
                if n >= 0 {
                    break n as usize;
                }
                let err = std::io::Error::last_os_error();
                crate::ensure!(err.raw_os_error() == Some(EINTR), "epoll_wait failed: {err}");
            };
            for ev in &self.buf[..n] {
                // Copy out of the (possibly packed) struct before use.
                let (events, data) = (ev.events, ev.data);
                out.push(Event {
                    token: data,
                    readable: events & (EPOLLIN | EPOLLERR | EPOLLHUP) != 0,
                    writable: events & (EPOLLOUT | EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe { close(self.epfd) };
        }
    }

    fn interest(readable: bool, writable: bool) -> u32 {
        let mut e = 0;
        if readable {
            e |= EPOLLIN;
        }
        if writable {
            e |= EPOLLOUT;
        }
        e
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    //! Portable `poll(2)` fallback: interest kept in a map, pollfd array
    //! rebuilt per wait — fine at the connection counts this front end
    //! is configured for.
    use super::{Event, RawFd};
    use crate::util::error::Result;
    use std::collections::HashMap;

    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const EINTR: i32 = 4;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: u32, timeout: i32) -> i32;
    }

    pub struct Poller {
        interest: HashMap<RawFd, (u64, bool, bool)>,
    }

    impl Poller {
        pub fn new() -> Result<Poller> {
            Ok(Poller {
                interest: HashMap::new(),
            })
        }

        pub fn add(&mut self, fd: RawFd, token: u64, readable: bool, writable: bool) -> Result<()> {
            self.interest.insert(fd, (token, readable, writable));
            Ok(())
        }

        pub fn modify(
            &mut self,
            fd: RawFd,
            token: u64,
            readable: bool,
            writable: bool,
        ) -> Result<()> {
            self.interest.insert(fd, (token, readable, writable));
            Ok(())
        }

        pub fn remove(&mut self, fd: RawFd) -> Result<()> {
            self.interest.remove(&fd);
            Ok(())
        }

        pub fn wait(&mut self, timeout_ms: i32, out: &mut Vec<Event>) -> Result<()> {
            out.clear();
            let mut entries: Vec<u64> = Vec::with_capacity(self.interest.len());
            let mut fds: Vec<PollFd> = Vec::with_capacity(self.interest.len());
            for (&fd, &(token, r, w)) in &self.interest {
                entries.push(token);
                fds.push(PollFd {
                    fd,
                    events: (if r { POLLIN } else { 0 }) | (if w { POLLOUT } else { 0 }),
                    revents: 0,
                });
            }
            let n = loop {
                let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as u32, timeout_ms) };
                if n >= 0 {
                    break n;
                }
                let err = std::io::Error::last_os_error();
                crate::ensure!(err.raw_os_error() == Some(EINTR), "poll failed: {err}");
            };
            if n > 0 {
                for (pfd, &token) in fds.iter().zip(entries.iter()) {
                    if pfd.revents != 0 {
                        out.push(Event {
                            token,
                            readable: pfd.revents & (POLLIN | POLLERR | POLLHUP) != 0,
                            writable: pfd.revents & (POLLOUT | POLLERR | POLLHUP) != 0,
                        });
                    }
                }
            }
            Ok(())
        }
    }
}

/// Network front-end configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Connections beyond this are accepted and immediately closed.
    pub max_conns: usize,
    /// Per-tenant token-bucket parameters.
    pub admission: AdmissionConfig,
    /// How long a graceful [`NetServer::shutdown`] waits for in-flight
    /// requests to finish and flush before giving up.
    pub drain_timeout: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 1024,
            admission: AdmissionConfig::default(),
            drain_timeout: Duration::from_secs(5),
        }
    }
}

/// State shared between the event loop, worker-side reply hooks, and the
/// owning [`NetServer`].
struct Shared {
    /// Hard stop: exit the loop now, dropping everything.
    stop: AtomicBool,
    /// Graceful drain: stop accepting/reading, finish + flush in-flight
    /// requests, then exit.
    drain: AtomicBool,
    /// Finished response frames awaiting delivery: `(conn token, frame)`.
    completions: Mutex<Vec<(u64, Vec<u8>)>>,
    /// True while a wake byte is (probably) in flight — collapses a
    /// burst of completions into one write.
    wake_flag: AtomicBool,
    /// Write half of the loopback wake connection (non-blocking).
    wake_tx: Mutex<TcpStream>,
}

impl Shared {
    /// Queue a finished frame for `token` and nudge the event loop.
    fn push_completion(&self, token: u64, frame: Vec<u8>) {
        self.completions.lock().unwrap().push((token, frame));
        self.wake();
    }

    fn wake(&self) {
        if !self.wake_flag.swap(true, Ordering::SeqCst) {
            // A full buffer (WouldBlock) is fine: the loop polls with a
            // bounded timeout and drains completions every iteration.
            let _ = (&*self.wake_tx.lock().unwrap()).write(&[1]);
        }
    }
}

/// One client connection owned by the event loop.
struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// Flushed prefix of `wbuf` (drained lazily to avoid per-write
    /// memmoves).
    wpos: usize,
    /// Requests submitted to the queue whose responses have not been
    /// delivered to `wbuf` yet.
    pending: usize,
    /// Protocol error: stop reading, close once flushed and drained.
    closing: bool,
    /// EOF or socket error from the peer: remove as soon as convenient.
    peer_gone: bool,
    /// Interest currently registered with the poller.
    want_read: bool,
    want_write: bool,
}

impl Conn {
    fn flushed(&self) -> bool {
        self.wpos == self.wbuf.len()
    }

    /// Append a frame and opportunistically flush.
    fn queue_frame(&mut self, frame: &[u8]) {
        self.wbuf.extend_from_slice(frame);
        self.flush();
    }

    fn flush(&mut self) {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.peer_gone = true;
                    break;
                }
                Ok(n) => self.wpos += n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.peer_gone = true;
                    break;
                }
            }
        }
        if self.flushed() {
            self.wbuf.clear();
            self.wpos = 0;
        } else if self.wpos > 64 * 1024 {
            self.wbuf.drain(..self.wpos);
            self.wpos = 0;
        }
    }
}

/// A running socket front end. Owns the [`Server`] it feeds; shut down
/// with [`NetServer::shutdown`] for a graceful drain, or just drop it for
/// a hard stop.
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    thread: Option<std::thread::JoinHandle<()>>,
    server: Option<Server>,
    metrics: Arc<Metrics>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"`) and start the event loop over
    /// an already-started [`Server`].
    pub fn start(server: Server, listen: &str, config: NetConfig) -> Result<NetServer> {
        let listener =
            TcpListener::bind(listen).with_context(|| format!("bind {listen}"))?;
        listener
            .set_nonblocking(true)
            .context("listener set_nonblocking")?;
        let addr = listener.local_addr().context("listener local_addr")?;

        // Loopback wake pair: the one-byte channel worker threads use to
        // interrupt a poll. A throwaway ephemeral listener mints a
        // connected pair from std alone — no pipe2/eventfd FFI, and the
        // same code works on every unix.
        let pair_listener =
            TcpListener::bind("127.0.0.1:0").context("bind wake-pair listener")?;
        let pair_addr = pair_listener.local_addr().context("wake-pair local_addr")?;
        let wake_tx = TcpStream::connect(pair_addr).context("connect wake pair")?;
        let (wake_rx, _) = pair_listener.accept().context("accept wake pair")?;
        for s in [&wake_tx, &wake_rx] {
            s.set_nonblocking(true).context("wake pair set_nonblocking")?;
            s.set_nodelay(true).ok();
        }

        let shared = Arc::new(Shared {
            stop: AtomicBool::new(false),
            drain: AtomicBool::new(false),
            completions: Mutex::new(Vec::new()),
            wake_flag: AtomicBool::new(false),
            wake_tx: Mutex::new(wake_tx),
        });
        let metrics = server.metrics.clone();
        let handle = server.handle();
        let loop_shared = shared.clone();
        let loop_metrics = metrics.clone();
        let thread = std::thread::Builder::new()
            .name("crinn-net".to_string())
            .spawn(move || {
                event_loop(listener, wake_rx, loop_shared, handle, loop_metrics, config)
            })
            .context("spawn net event loop")?;
        Ok(NetServer {
            addr,
            shared,
            thread: Some(thread),
            server: Some(server),
            metrics,
        })
    }

    /// The actual bound address (resolves `:0` listens).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// In-process submission handle to the same server the sockets feed —
    /// the loopback-identity tests compare the two paths.
    pub fn handle(&self) -> ServerHandle {
        self.server.as_ref().expect("server running").handle()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Graceful drain: stop accepting and reading, let submitted requests
    /// finish and their responses flush (bounded by
    /// [`NetConfig::drain_timeout`]), then stop the inner server and
    /// return its final metrics snapshot.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.shared.drain.store(true, Ordering::SeqCst);
        self.shared.wake();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        let server = self.server.take().expect("server running");
        server.shutdown()
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        if let Some(s) = self.server.take() {
            s.shutdown();
        }
    }
}

/// The event loop: single thread, owns the poller, the connections, and
/// the admission controller.
fn event_loop(
    listener: TcpListener,
    wake_rx: TcpStream,
    shared: Arc<Shared>,
    handle: ServerHandle,
    metrics: Arc<Metrics>,
    config: NetConfig,
) {
    let Ok(mut poller) = sys::Poller::new() else { return };
    if poller.add(listener.as_raw_fd(), TOKEN_LISTENER, true, false).is_err() {
        return;
    }
    if poller.add(wake_rx.as_raw_fd(), TOKEN_WAKE, true, false).is_err() {
        return;
    }
    let mut admission = AdmissionController::new(config.admission.clone());
    let mut conns: HashMap<u64, Conn> = HashMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut events: Vec<Event> = Vec::new();
    let mut wake_buf = [0u8; 256];
    let mut drain_deadline: Option<Instant> = None;
    let mut accepting = true;

    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        let draining = shared.drain.load(Ordering::SeqCst);
        if draining {
            let deadline =
                *drain_deadline.get_or_insert_with(|| Instant::now() + config.drain_timeout);
            if accepting {
                // Deregister the listener: a level-triggered poller would
                // otherwise spin on unaccepted connections.
                let _ = poller.remove(listener.as_raw_fd());
                accepting = false;
                for conn in conns.values_mut() {
                    conn.closing = true;
                }
            }
            let all_idle = conns.values().all(|c| c.pending == 0 && c.flushed())
                && shared.completions.lock().unwrap().is_empty();
            if all_idle || Instant::now() >= deadline {
                break;
            }
        }

        if poller.wait(50, &mut events).is_err() {
            break;
        }
        for ev in &events {
            match ev.token {
                TOKEN_LISTENER => {
                    if !accepting {
                        continue;
                    }
                    loop {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                if conns.len() >= config.max_conns {
                                    drop(stream); // at capacity: refuse
                                    continue;
                                }
                                if stream.set_nonblocking(true).is_err() {
                                    continue;
                                }
                                stream.set_nodelay(true).ok();
                                let token = next_token;
                                next_token += 1;
                                if poller.add(stream.as_raw_fd(), token, true, false).is_ok() {
                                    metrics.record_connection();
                                    conns.insert(
                                        token,
                                        Conn {
                                            stream,
                                            rbuf: Vec::new(),
                                            wbuf: Vec::new(),
                                            wpos: 0,
                                            pending: 0,
                                            closing: false,
                                            peer_gone: false,
                                            want_read: true,
                                            want_write: false,
                                        },
                                    );
                                }
                            }
                            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                            Err(_) => break,
                        }
                    }
                }
                TOKEN_WAKE => {
                    // Drain the wake bytes, then lower the flag; a racing
                    // wake after the drain re-raises it and the bounded
                    // poll timeout covers the window either way.
                    loop {
                        match (&wake_rx).read(&mut wake_buf) {
                            Ok(0) => break,
                            Ok(_) => continue,
                            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                            Err(_) => break,
                        }
                    }
                    shared.wake_flag.store(false, Ordering::SeqCst);
                }
                token => {
                    let Some(conn) = conns.get_mut(&token) else { continue };
                    if ev.readable && conn.want_read {
                        read_conn(
                            token, conn, &mut admission, &handle, &metrics, &shared,
                        );
                    }
                    if ev.writable {
                        conn.flush();
                    }
                }
            }
        }

        // Deliver finished responses to their connections.
        let finished: Vec<(u64, Vec<u8>)> =
            std::mem::take(&mut *shared.completions.lock().unwrap());
        for (token, frame) in finished {
            if let Some(conn) = conns.get_mut(&token) {
                conn.pending = conn.pending.saturating_sub(1);
                conn.queue_frame(&frame);
            }
            // A gone connection's responses are discarded.
        }

        // Re-arm interest and reap finished connections.
        conns.retain(|&token, conn| {
            if conn.peer_gone {
                let _ = poller.remove(conn.stream.as_raw_fd());
                return false;
            }
            if conn.closing && conn.pending == 0 && conn.flushed() {
                let _ = poller.remove(conn.stream.as_raw_fd());
                return false;
            }
            let want_read = !conn.closing && !draining;
            let want_write = !conn.flushed();
            if (want_read, want_write) != (conn.want_read, conn.want_write) {
                conn.want_read = want_read;
                conn.want_write = want_write;
                let _ =
                    poller.modify(conn.stream.as_raw_fd(), token, want_read, want_write);
            }
            true
        });
    }
}

/// Pull bytes off one readable connection and act on every whole frame.
fn read_conn(
    token: u64,
    conn: &mut Conn,
    admission: &mut AdmissionController,
    handle: &ServerHandle,
    metrics: &Arc<Metrics>,
    shared: &Arc<Shared>,
) {
    let mut chunk = [0u8; READ_CHUNK];
    loop {
        match conn.stream.read(&mut chunk) {
            Ok(0) => {
                conn.peer_gone = true;
                break;
            }
            Ok(n) => {
                conn.rbuf.extend_from_slice(&chunk[..n]);
                parse_frames(token, conn, admission, handle, metrics, shared);
                if conn.closing || conn.peer_gone {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.peer_gone = true;
                break;
            }
        }
    }
}

/// Split and dispatch every whole frame in `conn.rbuf`. A hostile frame
/// answers with an error frame and flips the connection to `closing`.
fn parse_frames(
    token: u64,
    conn: &mut Conn,
    admission: &mut AdmissionController,
    handle: &ServerHandle,
    metrics: &Arc<Metrics>,
    shared: &Arc<Shared>,
) {
    loop {
        let (payload_range, consumed) = match proto::split_frame(&conn.rbuf) {
            Ok(None) => break,
            Ok(Some((payload, consumed))) => {
                ((proto::FRAME_HEADER, proto::FRAME_HEADER + payload.len()), consumed)
            }
            Err(e) => {
                // Hostile framing: error frame, then close. The request
                // id is unknowable (the header itself is suspect), so 0.
                metrics.record_protocol_error();
                let frame = proto::encode_response(
                    0,
                    &Response::Error {
                        code: proto::ERR_MALFORMED,
                        message: format!("{e:#}"),
                    },
                );
                conn.queue_frame(&frame);
                conn.closing = true;
                conn.rbuf.clear();
                return;
            }
        };
        let payload = &conn.rbuf[payload_range.0..payload_range.1];
        metrics.record_frame();
        match proto::decode_request(payload) {
            Ok(frame) => handle_request(token, conn, frame, admission, handle, metrics, shared),
            Err(e) => {
                // Framing was fine (checksum matched) but the body is
                // malformed: echo the id if it was readable, then close.
                metrics.record_protocol_error();
                let id = proto::peek_request_id(payload);
                let frame = proto::encode_response(
                    id,
                    &Response::Error {
                        code: proto::ERR_MALFORMED,
                        message: format!("{e:#}"),
                    },
                );
                conn.queue_frame(&frame);
                conn.closing = true;
                conn.rbuf.clear();
                return;
            }
        }
        conn.rbuf.drain(..consumed);
        if conn.closing {
            return;
        }
    }
}

/// Admit, then submit one decoded request into the serving queue with a
/// hook completion; or answer immediately (metrics, overload).
fn handle_request(
    token: u64,
    conn: &mut Conn,
    frame: RequestFrame,
    admission: &mut AdmissionController,
    handle: &ServerHandle,
    metrics: &Arc<Metrics>,
    shared: &Arc<Shared>,
) {
    let RequestFrame {
        request_id,
        tenant,
        deadline_ms,
        body,
    } = frame;

    // Metrics frames bypass admission: they are cheap, carry no index
    // work, and operators need them most during overload.
    if let Request::Metrics = body {
        let counters = metrics.snapshot().counters();
        let resp = proto::encode_response(request_id, &Response::Metrics { counters });
        conn.queue_frame(&resp);
        return;
    }

    let now = Instant::now();
    match admission.admit(&tenant, now) {
        Admission::Reject { retry_after_ms } => {
            metrics.record_tenant_reject(&tenant);
            let resp =
                proto::encode_response(request_id, &Response::Overloaded { retry_after_ms });
            conn.queue_frame(&resp);
            return;
        }
        Admission::Admit => metrics.record_tenant_admit(&tenant),
    }

    let deadline = if deadline_ms > 0 {
        Some(now + Duration::from_millis(deadline_ms as u64))
    } else {
        None
    };
    let submitted = now;

    let req = match body {
        Request::Search {
            k,
            ef,
            filter,
            query,
        } => {
            let shared = shared.clone();
            QueryRequest::Search(SearchRequest {
                query,
                k,
                ef,
                filter,
                submitted,
                deadline,
                reply: Reply::hook(move |resp| {
                    let body = match resp {
                        Some(r) => Response::Search {
                            ids: r.ids,
                            dists: r.dists,
                            latency_s: r.latency_s,
                        },
                        None => dropped_unserved(),
                    };
                    shared.push_completion(token, proto::encode_response(request_id, &body));
                }),
            })
        }
        Request::Insert {
            tenant: meta_tenant,
            tags,
            vector,
        } => {
            let shared = shared.clone();
            QueryRequest::Insert(InsertRequest {
                vector,
                tenant: meta_tenant,
                tags,
                submitted,
                deadline,
                reply: Reply::hook(move |resp| {
                    let body = match resp {
                        Some(r) => Response::Mutation {
                            result: r.result,
                            latency_s: r.latency_s,
                        },
                        None => dropped_unserved(),
                    };
                    shared.push_completion(token, proto::encode_response(request_id, &body));
                }),
            })
        }
        Request::Delete { id } => {
            let shared = shared.clone();
            QueryRequest::Delete(DeleteRequest {
                id,
                submitted,
                deadline,
                reply: Reply::hook(move |resp| {
                    let body = match resp {
                        Some(r) => Response::Mutation {
                            result: r.result,
                            latency_s: r.latency_s,
                        },
                        None => dropped_unserved(),
                    };
                    shared.push_completion(token, proto::encode_response(request_id, &body));
                }),
            })
        }
        Request::Metrics => unreachable!("handled above"),
    };

    conn.pending += 1;
    // On rejection (queue full / stopping) the dropped request fires the
    // hook with `None`, which queues the explicit dropped-frame — the
    // client always hears back.
    let _ = handle.submit_request(req);
}

fn dropped_unserved() -> Response {
    Response::Error {
        code: proto::ERR_DROPPED,
        message: "dropped unserved (queue full, deadline passed, or shutting down)".to_string(),
    }
}

/// Blocking client for the wire protocol — used by `benches/net_qps.rs`,
/// the integration tests, and as the reference implementation for other
/// languages.
pub struct Client {
    stream: TcpStream,
    rbuf: Vec<u8>,
    next_id: u64,
    tenant: String,
    deadline_ms: u32,
}

impl Client {
    /// Connect to `addr`, identifying as `tenant` for admission control.
    pub fn connect(addr: &str, tenant: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            stream,
            rbuf: Vec::new(),
            next_id: 1,
            tenant: tenant.to_string(),
            deadline_ms: 0,
        })
    }

    /// Serve-by budget attached to every subsequent request (0 = none).
    pub fn set_deadline_ms(&mut self, ms: u32) {
        self.deadline_ms = ms;
    }

    pub fn search(&mut self, query: &[f32], k: usize, ef: usize) -> Result<Response> {
        self.search_filtered(query, k, ef, None)
    }

    pub fn search_filtered(
        &mut self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<crate::anns::FilterExpr>,
    ) -> Result<Response> {
        self.call(Request::Search {
            k,
            ef,
            filter,
            query: query.to_vec(),
        })
    }

    pub fn insert(
        &mut self,
        vector: &[f32],
        tenant: Option<&str>,
        tags: &[&str],
    ) -> Result<Response> {
        self.call(Request::Insert {
            tenant: tenant.map(|t| t.to_string()),
            tags: tags.iter().map(|t| t.to_string()).collect(),
            vector: vector.to_vec(),
        })
    }

    pub fn delete(&mut self, id: u32) -> Result<Response> {
        self.call(Request::Delete { id })
    }

    pub fn metrics(&mut self) -> Result<Response> {
        self.call(Request::Metrics)
    }

    /// One request/response round trip (requests on one client are
    /// serial; open more clients for concurrency).
    pub fn call(&mut self, body: Request) -> Result<Response> {
        let request_id = self.next_id;
        self.next_id += 1;
        let frame = proto::encode_request(&RequestFrame {
            request_id,
            tenant: self.tenant.clone(),
            deadline_ms: self.deadline_ms,
            body,
        });
        self.stream
            .write_all(&frame)
            .context("write request frame")?;
        loop {
            if let Some((payload, consumed)) = proto::split_frame(&self.rbuf)? {
                let (echoed, resp) = proto::decode_response(payload)?;
                self.rbuf.drain(..consumed);
                crate::ensure!(
                    echoed == request_id || echoed == 0,
                    "response for request {echoed}, expected {request_id}"
                );
                return Ok(resp);
            }
            let mut chunk = [0u8; READ_CHUNK];
            let n = self
                .stream
                .read(&mut chunk)
                .context("read response frame")?;
            crate::ensure!(n > 0, "server closed the connection");
            self.rbuf.extend_from_slice(&chunk[..n]);
        }
    }
}
