//! Serving metrics: counters + latency reservoir (p50/p99), lock-light.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Admission decisions recorded for one tenant at the network edge.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Requests the token bucket let through to the bounded queue.
    pub admits: u64,
    /// Requests answered with an `Overloaded` frame instead.
    pub rejects: u64,
}

/// Uniform latency reservoir: Algorithm R (Vitter) over the stream of
/// per-request latencies. Once the buffer is full, the `n`-th sample
/// replaces a uniformly chosen slot with probability `RESERVOIR / n`, so
/// the snapshot stays an unbiased sample of the whole stream. The old
/// scheme hashed the latency value itself into a slot index, which made
/// equal or similar latencies (coarse timers, steady-state load) hammer
/// one slot and let p50/p99 go stale once the reservoir filled.
struct Reservoir {
    samples: Vec<f64>,
    /// Latencies observed so far (including the current one while
    /// recording) — Algorithm R's `n`.
    seen: u64,
    rng: crate::util::rng::Rng,
}

impl Default for Reservoir {
    fn default() -> Self {
        Reservoir {
            samples: Vec::new(),
            seen: 0,
            // Fixed seed: sampling stays deterministic run-to-run, which
            // keeps the reservoir tests exact.
            rng: crate::util::rng::Rng::new(0x1a7e_4c7),
        }
    }
}

/// Shared serving metrics.
#[derive(Default)]
pub struct Metrics {
    /// Searches served (mutations and deadline drops count separately).
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Requests drained into batches — searches *and* mutations, served
    /// or deadline-dropped. The numerator of
    /// [`MetricsSnapshot::mean_batch_size`]: dividing `requests` by
    /// `batches` under-reported whenever mutations flowed, because
    /// mutation-only batches inflated the denominator only.
    pub batch_items: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests answered through a multi-query `search_batch` group (size
    /// > 1) — how much of the traffic actually amortized per-query
    /// overhead, vs. batches that drained a single request.
    pub batched_queries: AtomicU64,
    /// Successful mutations through the update path.
    pub inserts: AtomicU64,
    pub deletes: AtomicU64,
    /// Mutations that failed (immutable backend, bad id, wrong dim, …).
    pub mutation_errors: AtomicU64,
    /// Gauge: live (searchable) points after the most recent mutation —
    /// 0 until the first mutation on a mutable backend.
    pub live_points: AtomicU64,
    /// Searches that carried a filter expression.
    pub filtered_queries: AtomicU64,
    /// Filtered searches whose compiled bitset popcount was at or below
    /// the backend's selectivity crossover — served (entirely, for single
    /// indexes; per matching shard, for routers) by the exact fallback
    /// scan rather than the beam.
    pub filtered_fallbacks: AtomicU64,
    /// Gauge: FNV-1a-64 payload hash of the tuned-config artifact this
    /// server was sized from (`crinn serve --tuned`) — 0 when serving an
    /// untuned default configuration. Lets a fleet check which tuning
    /// generation each process runs.
    pub tuned_config_hash: AtomicU64,
    /// Network edge: connections accepted on the socket listener.
    pub connections: AtomicU64,
    /// Network edge: request frames decoded off the wire (valid ones;
    /// hostile input counts under `protocol_errors` instead).
    pub protocol_frames: AtomicU64,
    /// Network edge: hostile or malformed wire input — bad magic,
    /// oversized length, checksum mismatch, undecodable body. Each one
    /// also closes its connection.
    pub protocol_errors: AtomicU64,
    /// Requests dropped unserved at dequeue because their deadline had
    /// already passed — a backed-up queue sheds stale load instead of
    /// serving it late.
    pub deadline_drops: AtomicU64,
    /// Per-tenant admission decisions (token bucket at the network
    /// edge). BTreeMap so snapshots list tenants in a stable order.
    tenants: Mutex<BTreeMap<String, TenantCounters>>,
    /// Reservoir of recent request latencies (seconds).
    latencies: Mutex<Reservoir>,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut r = self.latencies.lock().unwrap();
        r.seen += 1;
        if r.samples.len() < RESERVOIR {
            r.samples.push(latency_s);
        } else {
            // Algorithm R: replace a uniform slot with probability k/n.
            let n = r.seen as usize;
            let j = r.rng.next_below(n);
            if j < RESERVOIR {
                r.samples[j] = latency_s;
            }
        }
    }

    /// Record one drained batch of `items` requests (searches and
    /// mutations alike — everything the batcher handed the worker).
    pub fn record_batch(&self, items: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_items.fetch_add(items as u64, Ordering::Relaxed);
    }

    /// Record one `search_batch` group of `group_len` requests; only
    /// groups that actually shared a call (size > 1) count as batched.
    pub fn record_group(&self, group_len: usize) {
        if group_len > 1 {
            self.batched_queries
                .fetch_add(group_len as u64, Ordering::Relaxed);
        }
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_insert(&self) {
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_delete(&self) {
        self.deletes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_mutation_error(&self) {
        self.mutation_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` filtered searches (one compiled bitset served them all).
    pub fn record_filtered(&self, n: usize) {
        self.filtered_queries.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record `n` filtered searches that routed to the exact fallback.
    pub fn record_filtered_fallback(&self, n: usize) {
        self.filtered_fallbacks.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Update the live-point gauge (called with the index's `live_count`
    /// while the mutation still holds the write lock, so the gauge never
    /// lags the index it describes).
    pub fn set_live_points(&self, live: u64) {
        self.live_points.store(live, Ordering::Relaxed);
    }

    /// Record which tuned-config artifact (by payload hash) shaped this
    /// server's configuration.
    pub fn set_tuned_config_hash(&self, hash: u64) {
        self.tuned_config_hash.store(hash, Ordering::Relaxed);
    }

    /// Record one accepted network connection.
    pub fn record_connection(&self) {
        self.connections.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one valid request frame decoded off the wire.
    pub fn record_frame(&self) {
        self.protocol_frames.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one hostile/malformed piece of wire input.
    pub fn record_protocol_error(&self) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request dropped unserved because its deadline passed.
    pub fn record_deadline_drop(&self) {
        self.deadline_drops.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one admitted request for `tenant`.
    pub fn record_tenant_admit(&self, tenant: &str) {
        self.tenants
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_default()
            .admits += 1;
    }

    /// Record one over-quota rejection for `tenant`.
    pub fn record_tenant_reject(&self, tenant: &str) {
        self.tenants
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_default()
            .rejects += 1;
    }

    /// Snapshot (requests, batches, rejected, mutations, network edge,
    /// per-tenant admission, latency stats).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies.lock().unwrap().samples.clone();
        let tenants: Vec<(String, TenantCounters)> = self
            .tenants
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| (name.clone(), *c))
            .collect();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batch_items: self.batch_items.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            mutation_errors: self.mutation_errors.load(Ordering::Relaxed),
            live_points: self.live_points.load(Ordering::Relaxed),
            filtered_queries: self.filtered_queries.load(Ordering::Relaxed),
            filtered_fallbacks: self.filtered_fallbacks.load(Ordering::Relaxed),
            tuned_config_hash: self.tuned_config_hash.load(Ordering::Relaxed),
            connections: self.connections.load(Ordering::Relaxed),
            protocol_frames: self.protocol_frames.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            deadline_drops: self.deadline_drops.load(Ordering::Relaxed),
            tenants,
            latency: crate::util::bench::Stats::from_samples(lat),
        }
    }
}

/// Point-in-time view.
#[derive(Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub batch_items: u64,
    pub rejected: u64,
    pub batched_queries: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub mutation_errors: u64,
    pub live_points: u64,
    pub filtered_queries: u64,
    pub filtered_fallbacks: u64,
    pub tuned_config_hash: u64,
    pub connections: u64,
    pub protocol_frames: u64,
    pub protocol_errors: u64,
    pub deadline_drops: u64,
    /// Per-tenant admission counters, tenant name ascending.
    pub tenants: Vec<(String, TenantCounters)>,
    pub latency: crate::util::bench::Stats,
}

impl MetricsSnapshot {
    /// Mean requests per drained batch, over *every* request kind the
    /// batcher handled — `batch_items / batches`, not
    /// `requests / batches`, which under-reported whenever mutations
    /// flowed (searches alone in the numerator, every batch in the
    /// denominator).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_items as f64 / self.batches as f64
        }
    }

    /// Flatten the snapshot into `(name, value)` counters — the payload
    /// of a wire `Metrics` reply, also handy for logs. Latencies are
    /// reported in integer microseconds; per-tenant admission counters
    /// appear as `tenant.<name>.admits` / `tenant.<name>.rejects`.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let mut out = vec![
            ("requests".to_string(), self.requests),
            ("batches".to_string(), self.batches),
            ("batch_items".to_string(), self.batch_items),
            ("rejected".to_string(), self.rejected),
            ("batched_queries".to_string(), self.batched_queries),
            ("inserts".to_string(), self.inserts),
            ("deletes".to_string(), self.deletes),
            ("mutation_errors".to_string(), self.mutation_errors),
            ("live_points".to_string(), self.live_points),
            ("filtered_queries".to_string(), self.filtered_queries),
            ("filtered_fallbacks".to_string(), self.filtered_fallbacks),
            ("tuned_config_hash".to_string(), self.tuned_config_hash),
            ("connections".to_string(), self.connections),
            ("protocol_frames".to_string(), self.protocol_frames),
            ("protocol_errors".to_string(), self.protocol_errors),
            ("deadline_drops".to_string(), self.deadline_drops),
            (
                "latency_p50_us".to_string(),
                (self.latency.p50 * 1e6) as u64,
            ),
            (
                "latency_p99_us".to_string(),
                (self.latency.p99 * 1e6) as u64,
            ),
        ];
        for (tenant, c) in &self.tenants {
            out.push((format!("tenant.{tenant}.admits"), c.admits));
            out.push((format!("tenant.{tenant}.rejects"), c.rejects));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(i as f64 * 1e-4);
        }
        m.record_batch(60);
        m.record_batch(40);
        m.record_rejected();
        m.record_group(1); // singleton groups never count as batched
        m.record_group(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.batch_items, 100);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batched_queries, 8);
        assert_eq!(s.latency.n, 100);
        assert_eq!(s.mean_batch_size(), 50.0);
    }

    #[test]
    fn mean_batch_size_counts_mutations() {
        // The regression the accounting fix pins: mutation-only batches
        // used to inflate the denominator while contributing nothing to
        // the numerator. Two batches — one with 4 searches, one with 4
        // mutations — must average 4.0, not 2.0.
        let m = Metrics::new();
        m.record_batch(4);
        for _ in 0..4 {
            m.record_request(1e-4);
        }
        m.record_batch(4);
        for _ in 0..4 {
            m.record_insert();
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 4, "requests still counts searches only");
        assert_eq!(s.batch_items, 8);
        assert_eq!(s.mean_batch_size(), 4.0);
    }

    #[test]
    fn full_reservoir_keeps_absorbing_new_values() {
        // The reservoir-bias regression: the old scheme indexed by
        // `latency.to_bits() % RESERVOIR`, so a stream of equal latencies
        // overwrote a single slot forever and the percentiles went stale.
        // Algorithm R must keep touching many distinct slots: fill the
        // reservoir with 1.0s, then stream 4 * RESERVOIR samples of 2.0
        // — close to 4/5 of the reservoir should now hold 2.0, and
        // certainly far more than one slot.
        let m = Metrics::new();
        for _ in 0..RESERVOIR {
            m.record_request(1.0);
        }
        for _ in 0..4 * RESERVOIR {
            m.record_request(2.0);
        }
        let snap = m.snapshot();
        assert_eq!(snap.latency.n, RESERVOIR);
        let twos = {
            let r = m.latencies.lock().unwrap();
            r.samples.iter().filter(|&&x| x == 2.0).count()
        };
        // Expectation is 4/5 of the reservoir; allow a wide band (it is
        // a fixed-seed deterministic stream, but keep the assertion
        // meaningful rather than exact).
        assert!(
            twos > RESERVOIR / 2,
            "only {twos}/{RESERVOIR} slots absorbed the new value"
        );
        // And the percentiles reflect the newer distribution.
        assert_eq!(snap.latency.p50, 2.0);
    }

    #[test]
    fn reservoir_replaces_across_many_distinct_slots() {
        // Distinct values after the fill must land in distinct slots —
        // the old value-hashed scheme put equal values in one slot and
        // gave similar values heavily clustered slots.
        let m = Metrics::new();
        for _ in 0..RESERVOIR {
            m.record_request(0.5);
        }
        for i in 0..RESERVOIR {
            m.record_request(10.0 + i as f64);
        }
        let replaced = {
            let r = m.latencies.lock().unwrap();
            r.samples.iter().filter(|&&x| x >= 10.0).count()
        };
        // A slot filled at n=k survives the stream up to n=2k with
        // probability prod(1 - 1/n) = k/2k, so about half the reservoir
        // should be replaced.
        assert!(
            replaced > RESERVOIR / 3 && replaced < RESERVOIR,
            "replaced {replaced} of {RESERVOIR}"
        );
    }

    #[test]
    fn mutation_counters_and_live_gauge() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.inserts, s.deletes, s.mutation_errors, s.live_points), (0, 0, 0, 0));
        m.record_insert();
        m.record_insert();
        m.record_delete();
        m.record_mutation_error();
        m.set_live_points(41);
        m.set_live_points(42); // gauge overwrites, never accumulates
        let s = m.snapshot();
        assert_eq!(s.inserts, 2);
        assert_eq!(s.deletes, 1);
        assert_eq!(s.mutation_errors, 1);
        assert_eq!(s.live_points, 42);
    }

    #[test]
    fn tuned_config_hash_gauge() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().tuned_config_hash, 0, "untuned serving reads 0");
        m.set_tuned_config_hash(0xDEAD_BEEF_0000_0001);
        m.set_tuned_config_hash(0xDEAD_BEEF_0000_0002); // gauge overwrites
        assert_eq!(m.snapshot().tuned_config_hash, 0xDEAD_BEEF_0000_0002);
    }

    #[test]
    fn filtered_counters_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.filtered_queries, s.filtered_fallbacks), (0, 0));
        m.record_filtered(3);
        m.record_filtered(1);
        m.record_filtered_fallback(1);
        let s = m.snapshot();
        assert_eq!(s.filtered_queries, 4);
        assert_eq!(s.filtered_fallbacks, 1);
    }

    #[test]
    fn network_and_tenant_counters() {
        let m = Metrics::new();
        m.record_connection();
        m.record_frame();
        m.record_frame();
        m.record_protocol_error();
        m.record_deadline_drop();
        m.record_tenant_admit("acme");
        m.record_tenant_admit("acme");
        m.record_tenant_reject("acme");
        m.record_tenant_admit("zeta");
        let s = m.snapshot();
        assert_eq!(s.connections, 1);
        assert_eq!(s.protocol_frames, 2);
        assert_eq!(s.protocol_errors, 1);
        assert_eq!(s.deadline_drops, 1);
        assert_eq!(
            s.tenants,
            vec![
                ("acme".to_string(), TenantCounters { admits: 2, rejects: 1 }),
                ("zeta".to_string(), TenantCounters { admits: 1, rejects: 0 }),
            ]
        );
        // The flattened counter view carries the per-tenant rows.
        let counters = s.counters();
        let get = |name: &str| {
            counters
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, v)| *v)
                .unwrap_or_else(|| panic!("missing counter {name}"))
        };
        assert_eq!(get("connections"), 1);
        assert_eq!(get("protocol_errors"), 1);
        assert_eq!(get("deadline_drops"), 1);
        assert_eq!(get("tenant.acme.admits"), 2);
        assert_eq!(get("tenant.acme.rejects"), 1);
        assert_eq!(get("tenant.zeta.admits"), 1);
    }
}
