//! Serving metrics: counters + latency reservoir (p50/p99), lock-light.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared serving metrics.
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests answered through a multi-query `search_batch` group (size
    /// > 1) — how much of the traffic actually amortized per-query
    /// overhead, vs. batches that drained a single request.
    pub batched_queries: AtomicU64,
    /// Successful mutations through the update path.
    pub inserts: AtomicU64,
    pub deletes: AtomicU64,
    /// Mutations that failed (immutable backend, bad id, wrong dim, …).
    pub mutation_errors: AtomicU64,
    /// Gauge: live (searchable) points after the most recent mutation —
    /// 0 until the first mutation on a mutable backend.
    pub live_points: AtomicU64,
    /// Searches that carried a filter expression.
    pub filtered_queries: AtomicU64,
    /// Filtered searches whose compiled bitset popcount was at or below
    /// the backend's selectivity crossover — served (entirely, for single
    /// indexes; per matching shard, for routers) by the exact fallback
    /// scan rather than the beam.
    pub filtered_fallbacks: AtomicU64,
    /// Gauge: FNV-1a-64 payload hash of the tuned-config artifact this
    /// server was sized from (`crinn serve --tuned`) — 0 when serving an
    /// untuned default configuration. Lets a fleet check which tuning
    /// generation each process runs.
    pub tuned_config_hash: AtomicU64,
    /// Reservoir of recent request latencies (seconds).
    latencies: Mutex<Vec<f64>>,
}

const RESERVOIR: usize = 65_536;

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_request(&self, latency_s: f64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() >= RESERVOIR {
            // Overwrite pseudo-randomly (cheap reservoir behavior).
            let idx = (latency_s.to_bits() as usize) % RESERVOIR;
            l[idx] = latency_s;
        } else {
            l.push(latency_s);
        }
    }

    pub fn record_batch(&self) {
        self.batches.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one `search_batch` group of `group_len` requests; only
    /// groups that actually shared a call (size > 1) count as batched.
    pub fn record_group(&self, group_len: usize) {
        if group_len > 1 {
            self.batched_queries
                .fetch_add(group_len as u64, Ordering::Relaxed);
        }
    }

    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_insert(&self) {
        self.inserts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_delete(&self) {
        self.deletes.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_mutation_error(&self) {
        self.mutation_errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record `n` filtered searches (one compiled bitset served them all).
    pub fn record_filtered(&self, n: usize) {
        self.filtered_queries.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Record `n` filtered searches that routed to the exact fallback.
    pub fn record_filtered_fallback(&self, n: usize) {
        self.filtered_fallbacks.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// Update the live-point gauge (called with the index's `live_count`
    /// while the mutation still holds the write lock, so the gauge never
    /// lags the index it describes).
    pub fn set_live_points(&self, live: u64) {
        self.live_points.store(live, Ordering::Relaxed);
    }

    /// Record which tuned-config artifact (by payload hash) shaped this
    /// server's configuration.
    pub fn set_tuned_config_hash(&self, hash: u64) {
        self.tuned_config_hash.store(hash, Ordering::Relaxed);
    }

    /// Snapshot (requests, batches, rejected, mutations, latency stats).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies.lock().unwrap().clone();
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            batched_queries: self.batched_queries.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            mutation_errors: self.mutation_errors.load(Ordering::Relaxed),
            live_points: self.live_points.load(Ordering::Relaxed),
            filtered_queries: self.filtered_queries.load(Ordering::Relaxed),
            filtered_fallbacks: self.filtered_fallbacks.load(Ordering::Relaxed),
            tuned_config_hash: self.tuned_config_hash.load(Ordering::Relaxed),
            latency: crate::util::bench::Stats::from_samples(lat),
        }
    }
}

/// Point-in-time view.
#[derive(Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub batched_queries: u64,
    pub inserts: u64,
    pub deletes: u64,
    pub mutation_errors: u64,
    pub live_points: u64,
    pub filtered_queries: u64,
    pub filtered_fallbacks: u64,
    pub tuned_config_hash: u64,
    pub latency: crate::util::bench::Stats,
}

impl MetricsSnapshot {
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(i as f64 * 1e-4);
        }
        m.record_batch();
        m.record_batch();
        m.record_rejected();
        m.record_group(1); // singleton groups never count as batched
        m.record_group(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 2);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.batched_queries, 8);
        assert_eq!(s.latency.n, 100);
        assert_eq!(s.mean_batch_size(), 50.0);
    }

    #[test]
    fn mutation_counters_and_live_gauge() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.inserts, s.deletes, s.mutation_errors, s.live_points), (0, 0, 0, 0));
        m.record_insert();
        m.record_insert();
        m.record_delete();
        m.record_mutation_error();
        m.set_live_points(41);
        m.set_live_points(42); // gauge overwrites, never accumulates
        let s = m.snapshot();
        assert_eq!(s.inserts, 2);
        assert_eq!(s.deletes, 1);
        assert_eq!(s.mutation_errors, 1);
        assert_eq!(s.live_points, 42);
    }

    #[test]
    fn tuned_config_hash_gauge() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().tuned_config_hash, 0, "untuned serving reads 0");
        m.set_tuned_config_hash(0xDEAD_BEEF_0000_0001);
        m.set_tuned_config_hash(0xDEAD_BEEF_0000_0002); // gauge overwrites
        assert_eq!(m.snapshot().tuned_config_hash, 0xDEAD_BEEF_0000_0002);
    }

    #[test]
    fn filtered_counters_accumulate() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.filtered_queries, s.filtered_fallbacks), (0, 0));
        m.record_filtered(3);
        m.record_filtered(1);
        m.record_filtered_fallback(1);
        let s = m.snapshot();
        assert_eq!(s.filtered_queries, 4);
        assert_eq!(s.filtered_fallbacks, 1);
    }
}
