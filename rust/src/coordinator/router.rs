//! Sharded router: partition the base across shard indexes, fan a query
//! out, merge the per-shard top-k — how multi-tenant vector stores
//! (Vearch/Milvus) scale past one index.

use crate::anns::heap::dist_cmp;
use crate::anns::AnnIndex;
use crate::anns::VectorSet;
use crate::dataset::Dataset;
use crate::variants::VariantConfig;
use std::sync::Arc;

/// Below this much fan-out work — total vectors × batch size — the shard
/// fan-out runs sequentially: scoped-thread spawn (~tens of µs) would
/// rival the per-shard search cost and regress serving latency. (For a
/// one-query batch this is the original ≥10k-vector gate.)
pub const PARALLEL_FANOUT_MIN: usize = 10_000;

/// A router over contiguous shards; shard `s` owns base rows
/// `[offsets[s], offsets[s+1])` and ids are remapped back to global.
pub struct ShardedRouter {
    shards: Vec<Arc<dyn AnnIndex>>,
    offsets: Vec<u32>,
    /// The metric every shard shares (merge-time distances are only
    /// comparable because the shards search one metric space).
    metric: crate::distance::Metric,
}

impl ShardedRouter {
    /// Build GLASS shards over a dataset split into `n_shards` ranges.
    pub fn build_glass(ds: &Dataset, config: &VariantConfig, n_shards: usize, seed: u64) -> Self {
        let n = ds.n_base();
        let n_shards = n_shards.clamp(1, n.max(1));
        let mut shards: Vec<Arc<dyn AnnIndex>> = Vec::with_capacity(n_shards);
        let mut offsets = vec![0u32];
        for s in 0..n_shards {
            let lo = n * s / n_shards;
            let hi = n * (s + 1) / n_shards;
            let data = ds.base[lo * ds.dim..hi * ds.dim].to_vec();
            let vs = VectorSet::new(data, ds.dim, ds.metric);
            shards.push(Arc::new(
                crate::anns::glass::GlassIndex::build(vs, config.clone(), seed ^ s as u64)
                    .with_label(&format!("glass-shard{s}")),
            ));
            offsets.push(hi as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric: ds.metric,
        }
    }

    /// Wrap pre-built shards (ids remapped by the given offsets; the last
    /// offset is the total size).
    pub fn from_shards(shards: Vec<Arc<dyn AnnIndex>>, metric: crate::distance::Metric) -> Self {
        let mut offsets = vec![0u32];
        for s in &shards {
            offsets.push(offsets.last().unwrap() + s.len() as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn metric(&self) -> crate::distance::Metric {
        self.metric
    }
}

/// The router is itself an [`AnnIndex`] — it plugs straight into the
/// serving coordinator and eval harness with no wrapper (the
/// distance-carrying trait made the old per-call-site adapter structs,
/// which existed only to rescore ids, redundant), and `search`/`len`/
/// `is_empty` come from the trait like for every other index.
impl AnnIndex for ShardedRouter {
    fn name(&self) -> String {
        format!(
            "sharded-{}x-{}",
            self.n_shards(),
            self.shards.first().map(|s| s.name()).unwrap_or_default()
        )
    }

    /// Single-query fan-out — the batch path with a one-element batch.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        self.search_batch(&[query], k, ef)
            .pop()
            .expect("one result list per query")
    }

    /// Batched fan-out and merge: each shard receives the **whole query
    /// batch** in one [`AnnIndex::search_batch`] call (so the shard reuses
    /// a single pooled scratch context and stays cache-warm across the
    /// batch), then the per-query merges walk shards in index order. The
    /// shard calls (which are independent) run through the thread pool
    /// when there is enough work to amortize scoped-thread spawn
    /// (~tens of µs): the gate scales the [`PARALLEL_FANOUT_MIN`]
    /// total-vector threshold by the batch size, since a 64-query batch
    /// is ~64× the work of the single query the threshold was calibrated
    /// on. Small-index single-query fan-outs stay sequential, as they do
    /// under `CRINN_THREADS=1`. The merge order is fixed either way, so
    /// results are identical for every thread count and batch size.
    ///
    /// The merge sorts on the exact distances the shards carry
    /// ([`AnnIndex::search_with_dists`] returns full-precision distances
    /// for every index type, in the shared metric's units) with local ids
    /// remapped to global — the pre-batch router recomputed every distance
    /// through a caller-provided scorer because the ids-only trait had
    /// discarded them; the distance-carrying trait makes that k×n_shards
    /// rescoring per query redundant.
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        let work = self.len().saturating_mul(queries.len());
        let per_shard: Vec<Vec<Vec<(f32, u32)>>> =
            if self.shards.len() > 1 && work >= PARALLEL_FANOUT_MIN {
                crate::util::threadpool::parallel_map(self.shards.len(), 1, |s| {
                    self.shards[s].search_batch(queries, k, ef)
                })
            } else {
                self.shards
                    .iter()
                    .map(|shard| shard.search_batch(queries, k, ef))
                    .collect()
            };
        (0..queries.len())
            .map(|qi| {
                let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
                for (s, shard_results) in per_shard.iter().enumerate() {
                    let base = self.offsets[s];
                    for &(d, local) in &shard_results[qi] {
                        merged.push((d, base + local));
                    }
                }
                merged.sort_by(dist_cmp);
                merged.truncate(k);
                merged
            })
            .collect()
    }

    fn len(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.memory_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn sharded_matches_unsharded_recall() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 91);
        ds.compute_ground_truth(10);
        let cfg = VariantConfig::glass_baseline();
        let router = ShardedRouter::build_glass(&ds, &cfg, 3, 5);
        assert_eq!(router.n_shards(), 3);
        assert_eq!(router.len(), 1200);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = router.search(ds.query_vec(qi), 10, 96);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "sharded recall {recall}");
    }

    #[test]
    fn router_batch_fanout_matches_per_query_bitwise() {
        // A whole-batch fan-out (one `search_batch` per shard) must return
        // exactly what per-query fan-outs return — distances and ids.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 900, 25, 95);
        ds.compute_ground_truth(10);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 3, 5);
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
        let batched = router.search_batch(&queries, 10, 64);
        let per_query: Vec<Vec<(f32, u32)>> = queries
            .iter()
            .map(|q| router.search_with_dists(q, 10, 64))
            .collect();
        assert_eq!(batched, per_query);
    }

    #[test]
    fn merged_distances_are_exact_and_global() {
        // The merge sorts on shard-carried distances; every returned
        // distance must equal the exact metric distance to the global id.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 96);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            for (d, gid) in router.search_with_dists(q, 10, 64) {
                let want = ds.metric.distance(q, ds.base_vec(gid as usize));
                assert_eq!(d, want, "query {qi} gid {gid}");
            }
        }
    }

    #[test]
    fn ids_remapped_to_global_range() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 92);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        let q = ds.query_vec(0);
        let found = router.search(q, 10, 64);
        assert_eq!(found.len(), 10);
        assert!(found.iter().all(|&i| (i as usize) < 600));
        // Distinct ids.
        let set: std::collections::HashSet<_> = found.iter().collect();
        assert_eq!(set.len(), found.len());
    }
}
