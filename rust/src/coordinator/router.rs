//! Sharded router: partition the base across shard indexes, fan a query
//! out, merge the per-shard top-k — how multi-tenant vector stores
//! (Vearch/Milvus) scale past one index.
//!
//! Two flavors:
//! * [`ShardedRouter`] — static contiguous ranges (shard `s` owns rows
//!   `[offsets[s], offsets[s+1])`), the zero-overhead layout for
//!   build-once serving;
//! * [`MutableShardedRouter`] — interleaved id mapping
//!   (`global = local * n_shards + shard`) so shards can grow
//!   independently under online inserts without ever renumbering an
//!   existing point: mutations are routed to the owning shard
//!   (`shard = global % n_shards`), consolidation fans out per shard, and
//!   global ids stay stable because each shard recycles slots instead of
//!   compacting.

use crate::anns::heap::dist_cmp;
use crate::anns::{AnnIndex, FilterBitset, MutableAnnIndex};
use crate::anns::VectorSet;
use crate::dataset::Dataset;
use crate::variants::VariantConfig;
use std::sync::Arc;

/// Below this much fan-out work — total vectors × batch size — the shard
/// fan-out runs sequentially: scoped-thread spawn (~tens of µs) would
/// rival the per-shard search cost and regress serving latency. (For a
/// one-query batch this is the original ≥10k-vector gate.)
pub const PARALLEL_FANOUT_MIN: usize = 10_000;

/// A router over contiguous shards; shard `s` owns base rows
/// `[offsets[s], offsets[s+1])` and ids are remapped back to global.
pub struct ShardedRouter {
    shards: Vec<Arc<dyn AnnIndex>>,
    offsets: Vec<u32>,
    /// The metric every shard shares (merge-time distances are only
    /// comparable because the shards search one metric space).
    metric: crate::distance::Metric,
}

impl ShardedRouter {
    /// Build GLASS shards over a dataset split into `n_shards` ranges.
    pub fn build_glass(ds: &Dataset, config: &VariantConfig, n_shards: usize, seed: u64) -> Self {
        let n = ds.n_base();
        let n_shards = n_shards.clamp(1, n.max(1));
        let mut shards: Vec<Arc<dyn AnnIndex>> = Vec::with_capacity(n_shards);
        let mut offsets = vec![0u32];
        for s in 0..n_shards {
            let lo = n * s / n_shards;
            let hi = n * (s + 1) / n_shards;
            let data = ds.base[lo * ds.dim..hi * ds.dim].to_vec();
            let vs = VectorSet::new(data, ds.dim, ds.metric);
            shards.push(Arc::new(
                crate::anns::glass::GlassIndex::build(vs, config.clone(), seed ^ s as u64)
                    .with_label(&format!("glass-shard{s}")),
            ));
            offsets.push(hi as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric: ds.metric,
        }
    }

    /// Wrap pre-built shards (ids remapped by the given offsets; the last
    /// offset is the total size).
    pub fn from_shards(shards: Vec<Arc<dyn AnnIndex>>, metric: crate::distance::Metric) -> Self {
        let mut offsets = vec![0u32];
        for s in &shards {
            offsets.push(offsets.last().unwrap() + s.len() as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn metric(&self) -> crate::distance::Metric {
        self.metric
    }
}

/// The router is itself an [`AnnIndex`] — it plugs straight into the
/// serving coordinator and eval harness with no wrapper (the
/// distance-carrying trait made the old per-call-site adapter structs,
/// which existed only to rescore ids, redundant), and `search`/`len`/
/// `is_empty` come from the trait like for every other index.
impl AnnIndex for ShardedRouter {
    fn name(&self) -> String {
        format!(
            "sharded-{}x-{}",
            self.n_shards(),
            self.shards.first().map(|s| s.name()).unwrap_or_default()
        )
    }

    /// Single-query fan-out — the batch path with a one-element batch.
    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        self.search_batch(&[query], k, ef)
            .pop()
            .expect("one result list per query")
    }

    /// Batched fan-out and merge: each shard receives the **whole query
    /// batch** in one [`AnnIndex::search_batch`] call (so the shard reuses
    /// a single pooled scratch context and stays cache-warm across the
    /// batch), then the per-query merges walk shards in index order. The
    /// shard calls (which are independent) run through the thread pool
    /// when there is enough work to amortize scoped-thread spawn
    /// (~tens of µs): the gate scales the [`PARALLEL_FANOUT_MIN`]
    /// total-vector threshold by the batch size, since a 64-query batch
    /// is ~64× the work of the single query the threshold was calibrated
    /// on. Small-index single-query fan-outs stay sequential, as they do
    /// under `CRINN_THREADS=1`. The merge order is fixed either way, so
    /// results are identical for every thread count and batch size.
    ///
    /// The merge sorts on the exact distances the shards carry
    /// ([`AnnIndex::search_with_dists`] returns full-precision distances
    /// for every index type, in the shared metric's units) with local ids
    /// remapped to global — the pre-batch router recomputed every distance
    /// through a caller-provided scorer because the ids-only trait had
    /// discarded them; the distance-carrying trait makes that k×n_shards
    /// rescoring per query redundant.
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        let work = self.len().saturating_mul(queries.len());
        let per_shard: Vec<Vec<Vec<(f32, u32)>>> =
            if self.shards.len() > 1 && work >= PARALLEL_FANOUT_MIN {
                crate::util::threadpool::parallel_map(self.shards.len(), 1, |s| {
                    self.shards[s].search_batch(queries, k, ef)
                })
            } else {
                self.shards
                    .iter()
                    .map(|shard| shard.search_batch(queries, k, ef))
                    .collect()
            };
        (0..queries.len())
            .map(|qi| {
                let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
                for (s, shard_results) in per_shard.iter().enumerate() {
                    let base = self.offsets[s];
                    for &(d, local) in &shard_results[qi] {
                        merged.push((d, base + local));
                    }
                }
                merged.sort_by(dist_cmp);
                merged.truncate(k);
                merged
            })
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        self.search_filtered_batch(&[query], k, ef, filter)
            .pop()
            .expect("one result list per query")
    }

    /// Filtered fan-out: the global bitset is sliced into one local bitset
    /// per shard (global id `offsets[s] + local`), each shard runs its own
    /// filtered batch (including its own selectivity fallback against its
    /// slice's popcount), and the merge is the unfiltered merge verbatim.
    /// Sequential over shards — filtered traffic is correctness-first; the
    /// unfiltered batch path remains the high-throughput read path.
    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let filter = match filter {
            None => return self.search_batch(queries, k, ef),
            Some(f) => f,
        };
        let per_shard: Vec<Vec<Vec<(f32, u32)>>> = self
            .shards
            .iter()
            .enumerate()
            .map(|(s, shard)| {
                let lo = self.offsets[s];
                let hi = self.offsets[s + 1];
                let local =
                    FilterBitset::from_predicate((hi - lo) as usize, |l| filter.matches(lo + l));
                shard.search_filtered_batch(queries, k, ef, Some(&local))
            })
            .collect();
        (0..queries.len())
            .map(|qi| {
                let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
                for (s, shard_results) in per_shard.iter().enumerate() {
                    let base = self.offsets[s];
                    for &(d, local) in &shard_results[qi] {
                        merged.push((d, base + local));
                    }
                }
                merged.sort_by(dist_cmp);
                merged.truncate(k);
                merged
            })
            .collect()
    }

    /// Advisory crossover for the coordinator's fallback counter: the
    /// largest threshold any shard would apply to its slice.
    fn filtered_fallback_threshold(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.filtered_fallback_threshold())
            .max()
            .unwrap_or(0)
    }

    fn len(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.memory_bytes()).sum()
    }
}

/// A router over mutable shards with an interleaved id mapping: global id
/// `g` lives on shard `g % n_shards` as local id `g / n_shards`. Built
/// round-robin over a dataset, global ids coincide with dataset row
/// numbers; after online inserts the id space may grow sparse (shards
/// grow at their own pace) but never reshuffles.
pub struct MutableShardedRouter {
    shards: Vec<Box<dyn MutableAnnIndex>>,
    metric: crate::distance::Metric,
    dim: usize,
    /// Round-robin insert cursor (next shard to receive a point).
    next_shard: usize,
}

impl MutableShardedRouter {
    /// Build mutable GLASS shards over a dataset split round-robin (row
    /// `i` → shard `i % n_shards`), so `global id == dataset row`.
    pub fn build_glass(ds: &Dataset, config: &VariantConfig, n_shards: usize, seed: u64) -> Self {
        let n = ds.n_base();
        let n_shards = n_shards.clamp(1, n.max(1));
        let mut shards: Vec<Box<dyn MutableAnnIndex>> = Vec::with_capacity(n_shards);
        for s in 0..n_shards {
            let mut data = Vec::new();
            let mut i = s;
            while i < n {
                data.extend_from_slice(ds.base_vec(i));
                i += n_shards;
            }
            let vs = VectorSet::new(data, ds.dim, ds.metric);
            shards.push(Box::new(
                crate::anns::glass::GlassIndex::build(vs, config.clone(), seed ^ s as u64)
                    .with_label(&format!("glass-mshard{s}")),
            ));
        }
        MutableShardedRouter {
            shards,
            metric: ds.metric,
            dim: ds.dim,
            next_shard: n % n_shards,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn metric(&self) -> crate::distance::Metric {
        self.metric
    }

    #[inline]
    fn locate(&self, id: u32) -> (usize, u32) {
        let s = self.shards.len() as u32;
        ((id % s) as usize, id / s)
    }

    #[inline]
    fn global(&self, shard: usize, local: u32) -> u32 {
        (local as usize * self.shards.len() + shard) as u32
    }
}

impl AnnIndex for MutableShardedRouter {
    fn name(&self) -> String {
        format!(
            "mutable-sharded-{}x-{}",
            self.n_shards(),
            self.shards.first().map(|s| s.name()).unwrap_or_default()
        )
    }

    fn search_with_dists(&self, query: &[f32], k: usize, ef: usize) -> Vec<(f32, u32)> {
        self.search_batch(&[query], k, ef)
            .pop()
            .expect("one result list per query")
    }

    /// Whole-batch fan-out per shard, merge on shard-carried exact
    /// distances with interleaved id remapping. Sequential over shards —
    /// the mutable router is correctness-first; the static
    /// [`ShardedRouter`] remains the high-throughput read path.
    fn search_batch(&self, queries: &[&[f32]], k: usize, ef: usize) -> Vec<Vec<(f32, u32)>> {
        let per_shard: Vec<Vec<Vec<(f32, u32)>>> = self
            .shards
            .iter()
            .map(|shard| shard.search_batch(queries, k, ef))
            .collect();
        (0..queries.len())
            .map(|qi| {
                let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
                for (s, shard_results) in per_shard.iter().enumerate() {
                    for &(d, local) in &shard_results[qi] {
                        merged.push((d, self.global(s, local)));
                    }
                }
                merged.sort_by(dist_cmp);
                merged.truncate(k);
                merged
            })
            .collect()
    }

    fn search_filtered_with_dists(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<(f32, u32)> {
        self.search_filtered_batch(&[query], k, ef, filter)
            .pop()
            .expect("one result list per query")
    }

    /// Filtered fan-out under the interleaved mapping: one pass over the
    /// global bitset's set ids scatters them to per-shard local bitsets
    /// (`global % n_shards` owns, `global / n_shards` is the local id; ids
    /// beyond a shard's physical size are dropped, matching the deny-safe
    /// out-of-range semantics of [`FilterBitset::matches`]). Each shard
    /// then runs its own filtered batch, and the merge is the unfiltered
    /// merge verbatim.
    fn search_filtered_batch(
        &self,
        queries: &[&[f32]],
        k: usize,
        ef: usize,
        filter: Option<&FilterBitset>,
    ) -> Vec<Vec<(f32, u32)>> {
        let filter = match filter {
            None => return self.search_batch(queries, k, ef),
            Some(f) => f,
        };
        let mut locals: Vec<FilterBitset> = self
            .shards
            .iter()
            .map(|shard| FilterBitset::new(shard.len()))
            .collect();
        for gid in filter.iter_set() {
            let (s, local) = self.locate(gid);
            if (local as usize) < self.shards[s].len() {
                locals[s].set(local);
            }
        }
        let per_shard: Vec<Vec<Vec<(f32, u32)>>> = self
            .shards
            .iter()
            .zip(locals.iter())
            .map(|(shard, local)| shard.search_filtered_batch(queries, k, ef, Some(local)))
            .collect();
        (0..queries.len())
            .map(|qi| {
                let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
                for (s, shard_results) in per_shard.iter().enumerate() {
                    for &(d, local) in &shard_results[qi] {
                        merged.push((d, self.global(s, local)));
                    }
                }
                merged.sort_by(dist_cmp);
                merged.truncate(k);
                merged
            })
            .collect()
    }

    /// Advisory crossover for the coordinator's fallback counter: the
    /// largest threshold any shard would apply to its slice.
    fn filtered_fallback_threshold(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.filtered_fallback_threshold())
            .max()
            .unwrap_or(0)
    }

    /// Total physical slots across shards (count semantics; the global id
    /// *range* can exceed this once shards grow unevenly).
    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    fn memory_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.memory_bytes()).sum()
    }
}

impl MutableAnnIndex for MutableShardedRouter {
    /// Round-robin placement; the returned global id encodes the owning
    /// shard, so deletes route without any lookup table.
    fn insert(&mut self, vec: &[f32]) -> crate::Result<u32> {
        crate::anns::validate_insert_vec(vec, self.dim)?;
        let s = self.next_shard;
        self.next_shard = (self.next_shard + 1) % self.shards.len();
        let local = self.shards[s]
            .insert(vec)
            .map_err(|e| e.context(format!("shard {s}")))?;
        Ok(self.global(s, local))
    }

    fn delete(&mut self, id: u32) -> crate::Result<()> {
        let (s, local) = self.locate(id);
        self.shards[s]
            .delete(local)
            .map_err(|e| e.context(format!("global id {id} (shard {s})")))
    }

    /// Per-shard consolidation. Sound at the router level because shards
    /// recycle slots instead of renumbering: every surviving global id is
    /// untouched.
    fn consolidate(&mut self) -> crate::Result<usize> {
        let mut dropped = 0;
        for (s, shard) in self.shards.iter_mut().enumerate() {
            dropped += shard
                .consolidate()
                .map_err(|e| e.context(format!("shard {s}")))?;
        }
        Ok(dropped)
    }

    fn live_count(&self) -> usize {
        self.shards.iter().map(|s| s.live_count()).sum()
    }

    fn deleted_count(&self) -> usize {
        self.shards.iter().map(|s| s.deleted_count()).sum()
    }

    fn is_deleted(&self, id: u32) -> bool {
        let (s, local) = self.locate(id);
        self.shards[s].is_deleted(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn sharded_matches_unsharded_recall() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 91);
        ds.compute_ground_truth(10);
        let cfg = VariantConfig::glass_baseline();
        let router = ShardedRouter::build_glass(&ds, &cfg, 3, 5);
        assert_eq!(router.n_shards(), 3);
        assert_eq!(router.len(), 1200);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let found = router.search(ds.query_vec(qi), 10, 96);
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "sharded recall {recall}");
    }

    #[test]
    fn router_batch_fanout_matches_per_query_bitwise() {
        // A whole-batch fan-out (one `search_batch` per shard) must return
        // exactly what per-query fan-outs return — distances and ids.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 900, 25, 95);
        ds.compute_ground_truth(10);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 3, 5);
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
        let batched = router.search_batch(&queries, 10, 64);
        let per_query: Vec<Vec<(f32, u32)>> = queries
            .iter()
            .map(|q| router.search_with_dists(q, 10, 64))
            .collect();
        assert_eq!(batched, per_query);
    }

    #[test]
    fn merged_distances_are_exact_and_global() {
        // The merge sorts on shard-carried distances; every returned
        // distance must equal the exact metric distance to the global id.
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 96);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            for (d, gid) in router.search_with_dists(q, 10, 64) {
                let want = ds.metric.distance(q, ds.base_vec(gid as usize));
                assert_eq!(d, want, "query {qi} gid {gid}");
            }
        }
    }

    #[test]
    fn mutable_router_ids_are_dataset_rows_and_distances_exact() {
        // Round-robin build: global id == dataset row, and merged
        // distances are the exact metric values to that row.
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 900, 25, 98);
        ds.compute_ground_truth(10);
        let router = MutableShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 3, 5);
        assert_eq!(router.n_shards(), 3);
        assert_eq!(router.len(), 900);
        assert_eq!(router.live_count(), 900);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            let found = router.search_with_dists(q, 10, 96);
            for &(d, gid) in &found {
                let want = ds.metric.distance(q, ds.base_vec(gid as usize));
                assert_eq!(d, want, "query {qi} gid {gid}");
            }
            let ids: Vec<u32> = found.iter().map(|&(_, i)| i).collect();
            acc += crate::dataset::gt::recall_at_k(&ids, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "mutable sharded recall {recall}");
    }

    #[test]
    fn mutable_router_routes_mutations_to_owning_shard() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 600, 10, 99);
        ds.compute_ground_truth(10);
        let mut router =
            MutableShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        // Delete the top-5 of query 0 — spread across shards by the
        // interleaved mapping — and verify they never surface again.
        let doomed = router.search(ds.query_vec(0), 5, 96);
        for &id in &doomed {
            router.delete(id).unwrap();
            assert!(router.is_deleted(id));
        }
        assert_eq!(router.deleted_count(), 5);
        assert_eq!(router.live_count(), 595);
        let after = router.search(ds.query_vec(0), 10, 96);
        assert!(after.iter().all(|id| !doomed.contains(id)));
        assert!(router.delete(doomed[0]).is_err(), "double delete must error");
        // Insert: the new point is immediately findable under its global
        // id, and the id decodes to a real shard slot.
        let v = ds.query_vec(1).to_vec();
        let id = router.insert(&v).unwrap();
        let top = router.search_with_dists(&v, 1, 96);
        assert_eq!(top[0], (0.0, id));
        // Consolidate fans out per shard; ids of live points are stable.
        let before: Vec<_> = (0..ds.n_queries())
            .map(|qi| router.search(ds.query_vec(qi), 10, 96))
            .collect();
        assert_eq!(router.consolidate().unwrap(), 5);
        assert_eq!(router.deleted_count(), 0);
        for (qi, prev) in before.iter().enumerate() {
            let now = router.search(ds.query_vec(qi), 10, 96);
            let overlap = now.iter().filter(|i| prev.contains(i)).count();
            assert!(
                overlap >= 8,
                "query {qi}: consolidation reshuffled ids ({overlap}/10 overlap)"
            );
        }
        // Recycled inserts: one insert per shard (round-robin covers all
        // four), so every shard holding a freed slot recycles it — at
        // least one of the new ids must be a previously-doomed global id.
        let new_ids: Vec<u32> = (0..4).map(|_| router.insert(&v).unwrap()).collect();
        assert!(
            new_ids.iter().any(|id| doomed.contains(id)),
            "no freed slot was recycled: {new_ids:?} vs doomed {doomed:?}"
        );
    }

    #[test]
    fn filtered_fanout_slices_bitset_per_shard() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 900, 12, 93);
        let router = ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 3, 5);
        let n = router.len();
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
        // filter=None routes to the unfiltered batch path bitwise.
        assert_eq!(
            router.search_filtered_batch(&queries, 10, 64, None),
            router.search_batch(&queries, 10, 64)
        );
        // Wide filter: every merged global id matches the predicate.
        let third = FilterBitset::from_predicate(n, |gid| gid % 3 == 0);
        for q in &queries {
            let found = router.search_filtered(q, 10, 64, Some(&third));
            assert!(!found.is_empty());
            assert!(found.iter().all(|&gid| gid % 3 == 0), "leak in {found:?}");
        }
        // Rare filter: each shard's slice popcount is under its fallback
        // threshold, so every shard answers exactly and the merge equals
        // the global filtered oracle.
        let rare = FilterBitset::from_predicate(n, |gid| gid % 100 == 0);
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        for q in &queries {
            let want = crate::dataset::gt::topk_pairs_for_query_filtered(
                &ds.base,
                q,
                ds.dim,
                ds.metric,
                5,
                &mut ids,
                &mut dists,
                |gid| rare.matches(gid),
            );
            assert_eq!(router.search_filtered_with_dists(q, 5, 64, Some(&rare)), want);
        }
        // Filtered batch == filtered per-query.
        let batched = router.search_filtered_batch(&queries, 10, 64, Some(&third));
        for (qi, q) in queries.iter().enumerate() {
            assert_eq!(batched[qi], router.search_filtered_with_dists(q, 10, 64, Some(&third)));
        }
    }

    #[test]
    fn filtered_mutable_fanout_scatters_interleaved_ids() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 97);
        let mut router =
            MutableShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        let n = router.len();
        let queries: Vec<&[f32]> = (0..ds.n_queries()).map(|qi| ds.query_vec(qi)).collect();
        assert_eq!(
            router.search_filtered_batch(&queries, 10, 96, None),
            router.search_batch(&queries, 10, 96)
        );
        // Rare filter: exact per shard, so the merge equals the global
        // filtered oracle (global id == dataset row after round-robin
        // build).
        let rare = FilterBitset::from_predicate(n, |gid| gid % 60 == 0);
        let (mut ids, mut dists) = (Vec::new(), Vec::new());
        for q in &queries {
            let want = crate::dataset::gt::topk_pairs_for_query_filtered(
                &ds.base,
                q,
                ds.dim,
                ds.metric,
                5,
                &mut ids,
                &mut dists,
                |gid| rare.matches(gid),
            );
            assert_eq!(router.search_filtered_with_dists(q, 5, 96, Some(&rare)), want);
        }
        // Deleting a matching id removes it from filtered results even
        // though the bitset still names it (tombstones conjoin).
        let victim = router.search_filtered(queries[0], 1, 96, Some(&rare))[0];
        router.delete(victim).unwrap();
        for q in &queries {
            let found = router.search_filtered(q, 5, 96, Some(&rare));
            assert!(!found.contains(&victim), "tombstoned id resurfaced");
            assert!(found.iter().all(|&gid| gid % 60 == 0));
        }
        // Filtered batch == filtered per-query after the mutation.
        let wide = FilterBitset::from_predicate(n, |gid| gid % 2 == 1);
        let batched = router.search_filtered_batch(&queries, 10, 96, Some(&wide));
        for (qi, q) in queries.iter().enumerate() {
            assert_eq!(batched[qi], router.search_filtered_with_dists(q, 10, 96, Some(&wide)));
        }
    }

    #[test]
    fn ids_remapped_to_global_range() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 92);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        let q = ds.query_vec(0);
        let found = router.search(q, 10, 64);
        assert_eq!(found.len(), 10);
        assert!(found.iter().all(|&i| (i as usize) < 600));
        // Distinct ids.
        let set: std::collections::HashSet<_> = found.iter().collect();
        assert_eq!(set.len(), found.len());
    }
}
