//! Sharded router: partition the base across shard indexes, fan a query
//! out, merge the per-shard top-k — how multi-tenant vector stores
//! (Vearch/Milvus) scale past one index.

use crate::anns::heap::dist_cmp;
use crate::anns::AnnIndex;
use crate::anns::VectorSet;
use crate::dataset::Dataset;
use crate::variants::VariantConfig;
use std::sync::Arc;

/// Below this many total vectors the shard fan-out runs sequentially:
/// per-query scoped-thread spawn (~tens of µs) would rival the per-shard
/// search cost and regress serving latency.
pub const PARALLEL_FANOUT_MIN: usize = 10_000;

/// A router over contiguous shards; shard `s` owns base rows
/// `[offsets[s], offsets[s+1])` and ids are remapped back to global.
pub struct ShardedRouter {
    shards: Vec<Arc<dyn AnnIndex>>,
    offsets: Vec<u32>,
    /// Per-shard full-precision vectors (for merge-time exact rescoring).
    metric: crate::distance::Metric,
}

impl ShardedRouter {
    /// Build GLASS shards over a dataset split into `n_shards` ranges.
    pub fn build_glass(ds: &Dataset, config: &VariantConfig, n_shards: usize, seed: u64) -> Self {
        let n = ds.n_base();
        let n_shards = n_shards.clamp(1, n.max(1));
        let mut shards: Vec<Arc<dyn AnnIndex>> = Vec::with_capacity(n_shards);
        let mut offsets = vec![0u32];
        for s in 0..n_shards {
            let lo = n * s / n_shards;
            let hi = n * (s + 1) / n_shards;
            let data = ds.base[lo * ds.dim..hi * ds.dim].to_vec();
            let vs = VectorSet::new(data, ds.dim, ds.metric);
            shards.push(Arc::new(
                crate::anns::glass::GlassIndex::build(vs, config.clone(), seed ^ s as u64)
                    .with_label(&format!("glass-shard{s}")),
            ));
            offsets.push(hi as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric: ds.metric,
        }
    }

    /// Wrap pre-built shards (ids remapped by the given offsets; the last
    /// offset is the total size).
    pub fn from_shards(shards: Vec<Arc<dyn AnnIndex>>, metric: crate::distance::Metric) -> Self {
        let mut offsets = vec![0u32];
        for s in &shards {
            offsets.push(offsets.last().unwrap() + s.len() as u32);
        }
        ShardedRouter {
            shards,
            offsets,
            metric,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Fan out and merge. For large indexes the shard searches (which are
    /// independent) run through the thread pool; below
    /// [`PARALLEL_FANOUT_MIN`] total vectors — where a per-shard search is
    /// only ~tens of µs, comparable to scoped-thread spawn cost — the
    /// fan-out stays sequential, as it does under `CRINN_THREADS=1`. The
    /// merge walks shards in index order either way, so results are
    /// identical for every thread count. Each shard returns its local
    /// top-k with ids remapped to global; results re-sorted by exact
    /// distance computed against the caller-provided scorer.
    pub fn search(
        &self,
        query: &[f32],
        k: usize,
        ef: usize,
        score: impl Fn(u32) -> f32,
    ) -> Vec<u32> {
        let per_shard: Vec<Vec<u32>> = if self.shards.len() > 1 && self.len() >= PARALLEL_FANOUT_MIN
        {
            crate::util::threadpool::parallel_map(self.shards.len(), 1, |s| {
                self.shards[s].search(query, k, ef)
            })
        } else {
            self.shards
                .iter()
                .map(|shard| shard.search(query, k, ef))
                .collect()
        };
        let mut merged: Vec<(f32, u32)> = Vec::with_capacity(k * self.shards.len());
        for (s, locals) in per_shard.into_iter().enumerate() {
            let base = self.offsets[s];
            for local in locals {
                let global = base + local;
                merged.push((score(global), global));
            }
        }
        merged.sort_by(dist_cmp);
        merged.truncate(k);
        merged.into_iter().map(|(_, i)| i).collect()
    }

    pub fn metric(&self) -> crate::distance::Metric {
        self.metric
    }

    pub fn len(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn sharded_matches_unsharded_recall() {
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 1200, 40, 91);
        ds.compute_ground_truth(10);
        let cfg = VariantConfig::glass_baseline();
        let router = ShardedRouter::build_glass(&ds, &cfg, 3, 5);
        assert_eq!(router.n_shards(), 3);
        assert_eq!(router.len(), 1200);
        let mut acc = 0.0;
        for qi in 0..ds.n_queries() {
            let q = ds.query_vec(qi);
            let found = router.search(q, 10, 96, |gid| {
                ds.metric.distance(q, ds.base_vec(gid as usize))
            });
            acc += crate::dataset::gt::recall_at_k(&found, &ds.gt[qi], 10);
        }
        let recall = acc / ds.n_queries() as f64;
        assert!(recall > 0.85, "sharded recall {recall}");
    }

    #[test]
    fn ids_remapped_to_global_range() {
        let sp = synth::spec("demo-64").unwrap();
        let ds = synth::generate_counts(sp, 600, 10, 92);
        let router =
            ShardedRouter::build_glass(&ds, &VariantConfig::glass_baseline(), 4, 5);
        let q = ds.query_vec(0);
        let found = router.search(q, 10, 64, |gid| {
            ds.metric.distance(q, ds.base_vec(gid as usize))
        });
        assert_eq!(found.len(), 10);
        assert!(found.iter().all(|&i| (i as usize) < 600));
        // Distinct ids.
        let set: std::collections::HashSet<_> = found.iter().collect();
        assert_eq!(set.len(), found.len());
    }
}
