//! Vector file IO: the `.fvecs` / `.ivecs` formats used by SIFT/GIST
//! distributions, plus a compact binary dataset cache so generated synthetic
//! datasets (and their ground truth) persist across benchmark runs.
//!
//! fvecs layout: for each vector, a little-endian i32 dimension followed by
//! `dim` little-endian f32 components. ivecs is identical with i32 data.

use crate::dataset::Dataset;
use crate::distance::Metric;
use crate::bail;
use crate::util::error::{Context, Error, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

/// Read an entire `.fvecs` file. Returns (flat data, dim).
pub fn read_fvecs(path: &Path) -> Result<(Vec<f32>, usize)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut data = Vec::new();
    let mut dim = 0usize;
    loop {
        let mut dbuf = [0u8; 4];
        match r.read_exact(&mut dbuf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(dbuf);
        if d <= 0 {
            bail!("bad fvecs dim {d}");
        }
        let d = d as usize;
        if dim == 0 {
            dim = d;
        } else if dim != d {
            bail!("inconsistent fvecs dims {dim} vs {d}");
        }
        let mut vbuf = vec![0u8; d * 4];
        r.read_exact(&mut vbuf)?;
        data.extend(
            vbuf.chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
    }
    Ok((data, dim))
}

/// Write a `.fvecs` file from flat row-major data.
pub fn write_fvecs(path: &Path, data: &[f32], dim: usize) -> Result<()> {
    assert!(dim > 0 && data.len() % dim == 0);
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    for row in data.chunks_exact(dim) {
        w.write_all(&(dim as i32).to_le_bytes())?;
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Read an `.ivecs` file (ground-truth id lists). Returns (flat, dim).
pub fn read_ivecs(path: &Path) -> Result<(Vec<i32>, usize)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut data = Vec::new();
    let mut dim = 0usize;
    loop {
        let mut dbuf = [0u8; 4];
        match r.read_exact(&mut dbuf) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let d = i32::from_le_bytes(dbuf);
        if d <= 0 {
            bail!("bad ivecs dim {d}");
        }
        let d = d as usize;
        if dim == 0 {
            dim = d;
        } else if dim != d {
            bail!("inconsistent ivecs dims {dim} vs {d}");
        }
        let mut vbuf = vec![0u8; d * 4];
        r.read_exact(&mut vbuf)?;
        data.extend(
            vbuf.chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
    }
    Ok((data, dim))
}

/// Write an `.ivecs` file.
pub fn write_ivecs(path: &Path, data: &[i32], dim: usize) -> Result<()> {
    assert!(dim > 0 && data.len() % dim == 0);
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for row in data.chunks_exact(dim) {
        w.write_all(&(dim as i32).to_le_bytes())?;
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    w.flush()?;
    Ok(())
}

/// Persist a full dataset (base, queries, gt) under `dir/<name>.*`.
pub fn save_dataset(ds: &Dataset, dir: &Path) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    write_fvecs(&dir.join(format!("{}.base.fvecs", ds.name)), &ds.base, ds.dim)?;
    write_fvecs(
        &dir.join(format!("{}.query.fvecs", ds.name)),
        &ds.queries,
        ds.dim,
    )?;
    if !ds.gt.is_empty() {
        let k = ds.gt_k;
        let flat: Vec<i32> = ds
            .gt
            .iter()
            .flat_map(|row| {
                let mut r: Vec<i32> = row.iter().map(|&x| x as i32).collect();
                r.resize(k, -1);
                r
            })
            .collect();
        write_ivecs(&dir.join(format!("{}.gt.ivecs", ds.name)), &flat, k)?;
    }
    let meta = format!(
        "{{\"name\":\"{}\",\"dim\":{},\"metric\":\"{}\",\"gt_k\":{}}}",
        ds.name,
        ds.dim,
        ds.metric.name(),
        ds.gt_k
    );
    std::fs::write(dir.join(format!("{}.meta.json", ds.name)), meta)?;
    Ok(())
}

/// Load a dataset previously written by [`save_dataset`].
pub fn load_dataset(name: &str, dir: &Path) -> Result<Dataset> {
    let meta_raw = std::fs::read_to_string(dir.join(format!("{name}.meta.json")))?;
    let meta = crate::util::json::parse(&meta_raw).map_err(Error::msg)?;
    let metric = Metric::from_name(
        meta.get("metric")
            .and_then(|m| m.as_str())
            .context("metric")?,
    )
    .context("bad metric")?;
    let (base, dim) = read_fvecs(&dir.join(format!("{name}.base.fvecs")))?;
    let (queries, qdim) = read_fvecs(&dir.join(format!("{name}.query.fvecs")))?;
    if dim != qdim {
        bail!("base dim {dim} != query dim {qdim}");
    }
    let gt_k = meta.get("gt_k").and_then(|v| v.as_usize()).unwrap_or(0);
    let gt = if gt_k > 0 {
        let (flat, k) = read_ivecs(&dir.join(format!("{name}.gt.ivecs")))?;
        flat.chunks_exact(k)
            .map(|row| row.iter().filter(|&&x| x >= 0).map(|&x| x as u32).collect())
            .collect()
    } else {
        vec![]
    };
    Ok(Dataset {
        name: name.to_string(),
        dim,
        metric,
        base,
        queries,
        gt,
        gt_k,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth;

    #[test]
    fn fvecs_roundtrip() {
        let dir = std::env::temp_dir().join("crinn_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.fvecs");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write_fvecs(&path, &data, 8).unwrap();
        let (back, dim) = read_fvecs(&path).unwrap();
        assert_eq!(dim, 8);
        assert_eq!(back, data);
    }

    #[test]
    fn ivecs_roundtrip() {
        let dir = std::env::temp_dir().join("crinn_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ivecs");
        let data: Vec<i32> = (0..30).collect();
        write_ivecs(&path, &data, 10).unwrap();
        let (back, dim) = read_ivecs(&path).unwrap();
        assert_eq!(dim, 10);
        assert_eq!(back, data);
    }

    #[test]
    fn dataset_roundtrip_with_gt() {
        let dir = std::env::temp_dir().join(format!("crinn_ds_{}", std::process::id()));
        let sp = synth::spec("demo-64").unwrap();
        let mut ds = synth::generate_counts(sp, 120, 6, 5);
        ds.compute_ground_truth(5);
        save_dataset(&ds, &dir).unwrap();
        let back = load_dataset("demo-64", &dir).unwrap();
        assert_eq!(back.dim, ds.dim);
        assert_eq!(back.base, ds.base);
        assert_eq!(back.queries, ds.queries);
        assert_eq!(back.gt, ds.gt);
        assert_eq!(back.metric, ds.metric);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn read_rejects_corrupt() {
        let dir = std::env::temp_dir().join("crinn_test_io");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.fvecs");
        std::fs::write(&path, [255u8, 255, 255, 255, 0, 0]).unwrap();
        assert!(read_fvecs(&path).is_err());
    }
}
