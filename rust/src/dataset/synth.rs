//! Synthetic generators matching the paper's Table 2 datasets.
//!
//! Construction: sample points on a `d_latent`-dimensional Gaussian-mixture
//! manifold (controls LID), embed into the ambient dimension `D` with a
//! random near-orthogonal linear map, add a small full-rank noise floor
//! (keeps distances non-degenerate), then normalize for angular metrics.
//! LID rises with `d_latent` and with the noise floor; the per-dataset
//! presets below were tuned so the measured Levina–Bickel LID lands near
//! Table 2's values (asserted in tests with generous tolerance).
//!
//! Scale: counts default to 1/20 of the paper's (single-core sandbox);
//! `--full-scale` restores them.

use crate::dataset::Dataset;
use crate::distance::Metric;
use crate::util::rng::Rng;

/// Generator parameters for one synthetic dataset.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: &'static str,
    pub dim: usize,
    /// Latent manifold dimension — the LID control.
    pub d_latent: usize,
    pub metric: Metric,
    /// Paper's base/query counts (Table 2).
    pub full_base: usize,
    pub full_queries: usize,
    /// Number of mixture clusters.
    pub clusters: usize,
    /// Cluster center spread relative to within-cluster scale.
    pub center_spread: f32,
    /// Full-rank noise floor (fraction of signal scale).
    pub noise: f32,
    /// Paper's reported LID (for Table 2 comparison output).
    pub paper_lid: f64,
}

/// The six Table-2 presets (+ a tiny `demo-64` used by examples/tests).
pub const SPECS: &[SynthSpec] = &[
    SynthSpec {
        name: "sift-128-euclidean",
        dim: 128,
        d_latent: 12,
        metric: Metric::L2,
        full_base: 1_000_000,
        full_queries: 10_000,
        clusters: 64,
        center_spread: 3.0,
        noise: 0.18,
        paper_lid: 9.3,
    },
    SynthSpec {
        name: "gist-960-euclidean",
        dim: 960,
        d_latent: 28,
        metric: Metric::L2,
        full_base: 1_000_000,
        full_queries: 1_000,
        clusters: 48,
        center_spread: 2.5,
        noise: 0.22,
        paper_lid: 20.5,
    },
    SynthSpec {
        name: "mnist-784-euclidean",
        dim: 784,
        d_latent: 18,
        metric: Metric::L2,
        full_base: 60_000,
        full_queries: 10_000,
        clusters: 10,
        center_spread: 2.0,
        noise: 0.2,
        paper_lid: 14.1,
    },
    SynthSpec {
        name: "glove-25-angular",
        dim: 25,
        d_latent: 13,
        metric: Metric::Angular,
        full_base: 1_183_514,
        full_queries: 10_000,
        clusters: 32,
        center_spread: 1.5,
        noise: 0.25,
        paper_lid: 9.9,
    },
    SynthSpec {
        name: "glove-100-angular",
        dim: 100,
        d_latent: 16,
        metric: Metric::Angular,
        full_base: 1_183_514,
        full_queries: 10_000,
        clusters: 32,
        center_spread: 1.5,
        noise: 0.25,
        paper_lid: 12.3,
    },
    SynthSpec {
        name: "nytimes-256-angular",
        dim: 256,
        d_latent: 16,
        metric: Metric::Angular,
        full_base: 290_000,
        full_queries: 10_000,
        clusters: 24,
        center_spread: 1.2,
        noise: 0.3,
        paper_lid: 12.5,
    },
    SynthSpec {
        name: "demo-64",
        dim: 64,
        d_latent: 10,
        metric: Metric::L2,
        full_base: 20_000,
        full_queries: 500,
        clusters: 16,
        center_spread: 2.5,
        noise: 0.2,
        paper_lid: 8.0,
    },
];

/// Look up a preset by name.
pub fn spec(name: &str) -> Option<&'static SynthSpec> {
    SPECS.iter().find(|s| s.name == name)
}

/// Names of the six paper datasets (Fig. 1 order).
pub fn paper_dataset_names() -> Vec<&'static str> {
    SPECS.iter().take(6).map(|s| s.name).collect()
}

/// Generate a dataset from a preset at `scale` (1.0 = paper scale).
pub fn generate(spec: &SynthSpec, scale: f64, seed: u64) -> Dataset {
    let n_base = ((spec.full_base as f64 * scale) as usize).max(100);
    let n_queries = ((spec.full_queries as f64 * scale) as usize).clamp(50, spec.full_queries);
    generate_counts(spec, n_base, n_queries, seed)
}

/// Generate with explicit counts.
pub fn generate_counts(spec: &SynthSpec, n_base: usize, n_queries: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let d = spec.dim;
    let dl = spec.d_latent;

    // Random embedding matrix [dl, d]; rows ~ N(0, 1/dl) — near-orthogonal
    // in expectation for dl << d (Johnson–Lindenstrauss regime).
    let emb_scale = 1.0 / (dl as f32).sqrt();
    let embed: Vec<f32> = (0..dl * d)
        .map(|_| rng.next_gaussian_f32() * emb_scale)
        .collect();

    // Cluster centers in latent space.
    let centers: Vec<f32> = (0..spec.clusters * dl)
        .map(|_| rng.next_gaussian_f32() * spec.center_spread)
        .collect();
    // Unnormalized cluster weights (Zipf-ish: real corpora are unbalanced).
    let weights: Vec<f64> = (0..spec.clusters)
        .map(|i| 1.0 / (1.0 + i as f64).sqrt())
        .collect();
    let wsum: f64 = weights.iter().sum();

    let sample_into = |out: &mut Vec<f32>, n: usize, rng: &mut Rng| {
        let mut latent = vec![0f32; dl];
        for _ in 0..n {
            // Pick a cluster by weight.
            let mut u = rng.next_f64() * wsum;
            let mut c = 0;
            for (i, w) in weights.iter().enumerate() {
                if u < *w {
                    c = i;
                    break;
                }
                u -= *w;
            }
            let center = &centers[c * dl..(c + 1) * dl];
            for (l, cv) in latent.iter_mut().zip(center) {
                *l = cv + rng.next_gaussian_f32();
            }
            // Embed: x = latent @ embed + noise.
            let start = out.len();
            out.resize(start + d, 0.0);
            let x = &mut out[start..start + d];
            for (li, &lv) in latent.iter().enumerate() {
                let row = &embed[li * d..(li + 1) * d];
                for (xi, rv) in x.iter_mut().zip(row) {
                    *xi += lv * rv;
                }
            }
            for xi in x.iter_mut() {
                *xi += spec.noise * rng.next_gaussian_f32();
            }
        }
    };

    let mut base = Vec::with_capacity(n_base * d);
    sample_into(&mut base, n_base, &mut rng);
    let mut queries = Vec::with_capacity(n_queries * d);
    sample_into(&mut queries, n_queries, &mut rng);

    let mut ds = Dataset {
        name: spec.name.to_string(),
        dim: d,
        metric: spec.metric,
        base,
        queries,
        gt: vec![],
        gt_k: 0,
    };
    if spec.metric.requires_normalization() {
        ds.normalize_all();
    }
    ds
}

/// Convenience: generate + ground truth in one call (benches/examples).
pub fn generate_with_gt(name: &str, n_base: usize, n_queries: usize, k: usize, seed: u64) -> Dataset {
    let sp = spec(name).unwrap_or_else(|| panic!("unknown dataset {name:?}"));
    let mut ds = generate_counts(sp, n_base, n_queries, seed);
    ds.compute_ground_truth(k);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_paper() {
        let names = paper_dataset_names();
        assert_eq!(names.len(), 6);
        assert!(names.contains(&"sift-128-euclidean"));
        assert!(names.contains(&"nytimes-256-angular"));
    }

    #[test]
    fn generate_shapes_and_determinism() {
        let sp = spec("demo-64").unwrap();
        let a = generate_counts(sp, 500, 20, 42);
        let b = generate_counts(sp, 500, 20, 42);
        assert_eq!(a.n_base(), 500);
        assert_eq!(a.n_queries(), 20);
        assert_eq!(a.base, b.base);
        assert_eq!(a.queries, b.queries);
        let c = generate_counts(sp, 500, 20, 43);
        assert_ne!(a.base, c.base);
    }

    #[test]
    fn angular_datasets_are_normalized() {
        let sp = spec("glove-25-angular").unwrap();
        let ds = generate_counts(sp, 200, 10, 7);
        for i in 0..ds.n_base() {
            let n = crate::distance::norm(ds.base_vec(i));
            assert!((n - 1.0).abs() < 1e-4, "vector {i} norm {n}");
        }
    }

    #[test]
    fn lid_tracks_latent_dim() {
        // Higher d_latent must produce measurably higher LID.
        let mut lo = spec("demo-64").unwrap().clone();
        lo.d_latent = 4;
        let mut hi = lo.clone();
        hi.d_latent = 24;
        let a = generate_counts(&lo, 2000, 10, 1);
        let b = generate_counts(&hi, 2000, 10, 1);
        let la = crate::dataset::lid::estimate_lid(&a.base, a.dim, a.metric, 20, 200, 5);
        let lb = crate::dataset::lid::estimate_lid(&b.base, b.dim, b.metric, 20, 200, 5);
        assert!(lb > la + 2.0, "lid lo={la:.2} hi={lb:.2}");
    }

    #[test]
    fn generated_lid_in_paper_ballpark_sift() {
        let sp = spec("sift-128-euclidean").unwrap();
        let ds = generate_counts(sp, 4000, 10, 11);
        let lid = crate::dataset::lid::estimate_lid(&ds.base, ds.dim, ds.metric, 20, 300, 3);
        // Generous band: match to within ~2.5x (LID estimates drift with n).
        assert!(lid > 4.0 && lid < 25.0, "sift-like LID {lid}");
    }
}
