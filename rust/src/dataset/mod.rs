//! Datasets: the six Table-2 benchmarks as synthetic equivalents, plus IO
//! and ground truth.
//!
//! The real ann-benchmarks HDF5 files are not available in this sandbox;
//! per DESIGN.md §2 we generate Gaussian-mixture datasets whose *measured*
//! statistics match Table 2: ambient dimension `D`, local intrinsic
//! dimension (`LID`, verified with the Levina–Bickel MLE in [`lid`]),
//! metric, and (scaled) base/query counts. The standard `.fvecs`/`.ivecs`
//! loaders in [`io`] let the real files drop in unchanged when present.

pub mod gt;
pub mod io;
pub mod lid;
pub mod synth;

use crate::distance::Metric;

/// An ANNS workload: base vectors, query vectors, and (optionally) the
/// exact ground-truth neighbors for recall computation.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub dim: usize,
    pub metric: Metric,
    /// Row-major `[n_base, dim]`.
    pub base: Vec<f32>,
    /// Row-major `[n_queries, dim]`.
    pub queries: Vec<f32>,
    /// `gt[q]` = indices of the exact k nearest base vectors of query `q`,
    /// nearest first. Populated by [`Dataset::compute_ground_truth`].
    pub gt: Vec<Vec<u32>>,
    /// k used for the stored ground truth.
    pub gt_k: usize,
}

impl Dataset {
    pub fn n_base(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.base.len() / self.dim
        }
    }

    pub fn n_queries(&self) -> usize {
        if self.dim == 0 {
            0
        } else {
            self.queries.len() / self.dim
        }
    }

    /// Base vector `i`.
    #[inline]
    pub fn base_vec(&self, i: usize) -> &[f32] {
        &self.base[i * self.dim..(i + 1) * self.dim]
    }

    /// Query vector `q`.
    #[inline]
    pub fn query_vec(&self, q: usize) -> &[f32] {
        &self.queries[q * self.dim..(q + 1) * self.dim]
    }

    /// L2-normalize all vectors (required for `Metric::Angular`).
    pub fn normalize_all(&mut self) {
        let dim = self.dim;
        for v in self.base.chunks_mut(dim) {
            crate::distance::normalize(v);
        }
        for v in self.queries.chunks_mut(dim) {
            crate::distance::normalize(v);
        }
    }

    /// Compute exact ground truth (parallel brute force) for recall@k.
    pub fn compute_ground_truth(&mut self, k: usize) {
        self.gt = gt::brute_force_topk(
            &self.base,
            &self.queries,
            self.dim,
            self.metric,
            k,
        );
        self.gt_k = k;
    }

    /// Measured statistics in Table 2's columns.
    pub fn stats(&self, lid_k: usize, lid_sample: usize, seed: u64) -> DatasetStats {
        DatasetStats {
            name: self.name.clone(),
            dim: self.dim,
            metric: self.metric,
            n_base: self.n_base(),
            n_queries: self.n_queries(),
            lid: lid::estimate_lid(&self.base, self.dim, self.metric, lid_k, lid_sample, seed),
        }
    }
}

/// Table-2 row for a dataset (measured, not configured).
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub name: String,
    pub dim: usize,
    pub metric: Metric,
    pub n_base: usize,
    pub n_queries: usize,
    pub lid: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            dim: 2,
            metric: Metric::L2,
            base: vec![0.0, 0.0, 1.0, 0.0, 0.0, 1.0, 5.0, 5.0],
            queries: vec![0.1, 0.0],
            gt: vec![],
            gt_k: 0,
        }
    }

    #[test]
    fn accessors() {
        let d = tiny();
        assert_eq!(d.n_base(), 4);
        assert_eq!(d.n_queries(), 1);
        assert_eq!(d.base_vec(3), &[5.0, 5.0]);
        assert_eq!(d.query_vec(0), &[0.1, 0.0]);
    }

    #[test]
    fn ground_truth_ordering() {
        let mut d = tiny();
        d.compute_ground_truth(3);
        assert_eq!(d.gt[0], vec![0, 1, 2]);
        assert_eq!(d.gt_k, 3);
    }

    #[test]
    fn normalize_all_unit() {
        let mut d = tiny();
        d.metric = Metric::Angular;
        d.normalize_all();
        for i in 1..d.n_base() {
            let n = crate::distance::norm(d.base_vec(i));
            assert!((n - 1.0).abs() < 1e-5);
        }
    }
}
